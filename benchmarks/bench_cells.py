"""Beyond-paper: configuration cells as a benchmark surface.

Two families of rows:

  * ``cell_lstm_*`` — the paper's Table-2 configuration grid walked
    through the session API (``repro.build(...).report()``): compute unit
    x HardSigmoid* method x ALU mode x fixed-point format.  No timing —
    these are analytical plan/energy cells, cheap enough for --smoke —
    so ``us_per_call`` is 0.0 (keeping that column microseconds-only for
    trend tooling) and ``derived`` is the projected dynamic power in mW at
    the paper's operating point — the energy-model output that actually
    varies across the grid (GOP/s/W is swamped by static power at this
    model size; the hs/alu axes don't enter the analytic energy model, so
    those cells legitimately repeat).  Weight bytes are recoverable from
    the name's ``a<frac>b<total>`` fixed-point tag.
  * ``cell_<arch>_*`` — the 40-cell LM roofline table read from
    results/dryrun.json (produced by the multi-pod dry-run sweep);
    ``us_per_call`` is the roofline-projected TPU step latency, ``derived``
    the useful-FLOPs ratio.
"""

import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")


def _lstm_grid_rows():
    import repro
    from repro.core.accelerator import AcceleratorConfig
    from repro.core.fixed_point import FXP_4_8, FXP_8_16
    from repro.core.qlstm import QLSTMConfig

    rows = []
    model = QLSTMConfig()
    for unit in ("mxu", "vpu"):
        for alu in ("pipelined", "per_step"):
            for hs in ("arithmetic", "1to1", "step"):
                for fxp in (FXP_4_8, FXP_8_16):
                    acc = AcceleratorConfig(compute_unit=unit, alu_mode=alu,
                                            hs_method=hs, fxp=fxp)
                    rep = repro.build(model, acc).report()
                    name = (f"cell_lstm_{unit}_{alu}_{hs}_"
                            f"a{fxp.frac_bits}b{fxp.total_bits}_"
                            f"{rep['backend']}")
                    rows.append((name, 0.0,
                                 round(rep["energy"]["dynamic_w"] * 1e3, 4)))
    return rows


def run():
    rows = _lstm_grid_rows()
    if not os.path.exists(RESULTS):
        rows.append(("cells_missing_run_dryrun_first", 0.0, 0))
        return rows
    with open(RESULTS) as f:
        rs = json.load(f)
    for r in rs:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        t = r["roofline"]
        rows.append((f"cell_{r['arch']}_{r['shape']}_{t['bound']}",
                     round(t["step_s"] * 1e6, 1),
                     round(r.get("useful_flops_ratio") or 0, 3)))
    return sorted(rows)
