"""Beyond-paper: the 40-cell roofline table as a benchmark surface.

Reads results/dryrun.json (produced by the multi-pod dry-run sweep) and
emits each single-pod cell's roofline-projected step time and the dominant
term — the §Roofline deliverable in CSV form.  `us_per_call` is the
projected TPU step latency; `derived` is the useful-FLOPs ratio.
"""

import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")


def run():
    rows = []
    if not os.path.exists(RESULTS):
        return [("cells_missing_run_dryrun_first", 0.0, 0)]
    with open(RESULTS) as f:
        rs = json.load(f)
    for r in rs:
        if r.get("status") != "ok" or r.get("mesh") != "16x16":
            continue
        t = r["roofline"]
        rows.append((f"cell_{r['arch']}_{r['shape']}_{t['bound']}",
                     round(t["step_s"] * 1e6, 1),
                     round(r.get("useful_flops_ratio") or 0, 3)))
    return sorted(rows)
