"""Design-space sweep benchmark — the paper's Table-4 comparison as a
search, not a hand-picked pair of configurations.

Two entry points:

  * ``write_sweep(out_path, smoke=...)`` — run the sweep through
    ``repro.explore`` and write the ``BENCH_pareto.json`` artifact
    (``--sweep [--smoke]`` in ``benchmarks/run.py``).  Smoke mode is the
    deterministic 4-point space (fixed-point format x ALU mode) CI runs on
    CPU; full mode walks ``explore.paper_space()`` (24 timed points).
  * ``run()`` — the harness-shaped row view of the smoke sweep
    (``name,us_per_call,derived`` with derived = GOP/s/W; Pareto-front
    members get a ``*pareto`` name suffix) so the ``pareto`` suite plots on
    the same trend tooling as every other benchmark.
"""

import json
import sys


def sweep_payload(smoke: bool = False, iters: int = 20, seed: int = 0):
    from repro import explore
    # The smoke sweep walks the whole cell zoo: 4 deterministic points per
    # cell.  LSTM labels stay suffix-free, so pre-cell-axis artifacts and
    # trend lines keep their names; gru/rglru points land on the xla
    # backend (no fused kernel) and are labelled "<base>_<cell>".
    space = explore.smoke_space(cell=("lstm", "gru", "rglru")) if smoke \
        else explore.paper_space(batch=256)
    # 3-objective front: the paper's GOP/s + GOP/s/W pair plus quantisation
    # fidelity, so the wide (8,16) baseline format earns its place on the
    # front through accuracy rather than vanishing behind (4,8)'s speed.
    objectives = dict(explore.DEFAULT_OBJECTIVES, int_float_mse="min")
    return explore.sweep(space, iters=iters, seed=seed, objectives=objectives,
                         log=lambda s: print(s, file=sys.stderr))


def write_sweep(out_path: str = "BENCH_pareto.json", smoke: bool = False,
                iters: int = 20, seed: int = 0) -> dict:
    payload = sweep_payload(smoke=smoke, iters=iters, seed=seed)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in payload["points"])
    print(f"[sweep] wrote {len(payload['points'])} points ({n_ok} ok, "
          f"{len(payload['front'])} on the Pareto front) to {out_path}",
          file=sys.stderr)
    return payload


def _rows(payload):
    rows = []
    for r in payload["points"]:
        if r["status"] != "ok":
            rows.append((f"pareto_{r['label']}_{r['status']}", 0.0, 0))
            continue
        m = r["metrics"]
        name = f"pareto_{r['label']}" + ("*pareto" if r["pareto"] else "")
        rows.append((name, round(m["us_per_wave"], 2),
                     round(m["gops_per_watt"], 4)))
    return rows


def run():
    return _rows(sweep_payload(smoke=True, iters=5))
