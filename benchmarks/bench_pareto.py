"""Design-space sweep benchmark — the paper's Table-4 comparison as a
search, not a hand-picked pair of configurations.

Entry points:

  * ``write_sweep(out_path, smoke=..., strategy=...)`` — run the sweep
    through ``repro.explore`` and write the ``BENCH_pareto.json`` artifact
    (``--sweep [--smoke]`` in ``benchmarks/run.py``, or this module's own
    CLI: ``python -m benchmarks.bench_pareto --smoke --strategy halving
    --rungs 2 out.json``).  Smoke mode is the deterministic per-cell space
    CI runs on CPU; full mode walks ``explore.paper_space()``.
  * ``strategy="halving"`` switches to the serving-aware search: every
    point is scored by a short real ``StreamServer``/``ClusterServer`` run
    under a pinned ``ServingScenario``, with seeded successive halving
    promoting the top ``1/eta`` per rung on the SLO-constrained objective
    ("max samples/s s.t. p99 within deadline").  The payload is schema v2
    with per-point ``operating_point`` records and the full halving trace
    (checked in CI by ``tools/check_pareto_schema.py``).
  * ``run()`` — the harness-shaped row view of the smoke sweep
    (``name,us_per_call,derived``; Pareto-front members get a ``*pareto``
    name suffix) so the ``pareto`` suite plots on the same trend tooling
    as every other benchmark.
"""

import argparse
import json
import sys


# The pinned serving operating point CI's search-smoke measures under:
# small enough to finish in seconds on forced-host XLA devices, a deadline
# loose enough that wave assembly never times out on a loaded CI runner,
# and an SLO generous enough to stay feasible while still exercising the
# constrained-ranking path.
SMOKE_SCENARIO = dict(streams=4, windows_per_stream=4, deadline_ms=250.0,
                      seed=0, name="smoke-serving")
SMOKE_SLO = "p99_ms<=5000"


def _smoke_space(serving: bool):
    from repro import explore
    if not serving:
        return explore.smoke_space(cell=("lstm", "gru", "rglru"))
    # The serving smoke walks the cell zoo AND the new serving axes: a
    # 2-replica point (feasible under forced-host device counts >= 2,
    # pruned as infeasible on a single-device runner) and pinned host
    # residency alongside the auto default.
    return explore.smoke_space(cell=("lstm", "gru", "rglru"),
                               replicas=(1, 2),
                               state_residency=("auto",))


def sweep_payload(smoke: bool = False, iters: int = 20, seed: int = 0,
                  strategy: str = "full", eta: int = 2, rungs=None):
    from repro import explore
    log = lambda s: print(s, file=sys.stderr)  # noqa: E731
    if strategy == "halving":
        space = _smoke_space(serving=True) if smoke \
            else explore.paper_space(batch=256)
        scenario = explore.ServingScenario(**SMOKE_SCENARIO) if smoke \
            else explore.ServingScenario(streams=16, windows_per_stream=8,
                                         deadline_ms=10.0, name="paper-serving")
        return explore.sweep(space, scenario=scenario, strategy="halving",
                             objective="samples_per_s", constraint=SMOKE_SLO,
                             eta=eta, rungs=rungs, seed=seed, log=log)
    # The smoke sweep walks the whole cell zoo: 4 deterministic points per
    # cell.  LSTM labels stay suffix-free, so pre-cell-axis artifacts and
    # trend lines keep their names; gru/rglru points land on the xla
    # backend (no fused kernel) and are labelled "<base>_<cell>".
    space = explore.smoke_space(cell=("lstm", "gru", "rglru")) if smoke \
        else explore.paper_space(batch=256)
    # 3-objective front: the paper's GOP/s + GOP/s/W pair plus quantisation
    # fidelity, so the wide (8,16) baseline format earns its place on the
    # front through accuracy rather than vanishing behind (4,8)'s speed.
    objectives = dict(explore.DEFAULT_OBJECTIVES, int_float_mse="min")
    return explore.sweep(space, iters=iters, seed=seed, objectives=objectives,
                         log=log)


def write_sweep(out_path: str = "BENCH_pareto.json", smoke: bool = False,
                iters: int = 20, seed: int = 0, strategy: str = "full",
                eta: int = 2, rungs=None) -> dict:
    payload = sweep_payload(smoke=smoke, iters=iters, seed=seed,
                            strategy=strategy, eta=eta, rungs=rungs)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in payload["points"])
    print(f"[sweep] wrote {len(payload['points'])} points ({n_ok} ok, "
          f"{len(payload['front'])} on the Pareto front) to {out_path}",
          file=sys.stderr)
    if payload.get("front_reason"):
        print(f"[sweep] empty front: {payload['front_reason']}",
              file=sys.stderr)
    return payload


def _rows(payload):
    serving = payload.get("scenario") is not None
    rows = []
    for r in payload["points"]:
        if r["status"] != "ok":
            rows.append((f"pareto_{r['label']}_{r['status']}", 0.0, 0))
            continue
        m = r["metrics"]
        name = f"pareto_{r['label']}" + ("*pareto" if r["pareto"] else "")
        if serving:
            # Serving rows have no per-wave closed-loop time; report tail
            # latency as the time column and achieved rate as derived.
            rows.append((name, round(m["p99_ms"] * 1e3, 2),
                         round(m["samples_per_s"], 1)))
        else:
            rows.append((name, round(m["us_per_wave"], 2),
                         round(m["gops_per_watt"], 4)))
    return rows


def run():
    return _rows(sweep_payload(smoke=True, iters=5))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="design-space sweep -> BENCH_pareto.json (schema v2)")
    ap.add_argument("out", nargs="?", default="BENCH_pareto.json")
    ap.add_argument("--smoke", action="store_true",
                    help="deterministic CPU-sized space")
    ap.add_argument("--strategy", choices=("full", "halving"),
                    default="full",
                    help="halving = serving-aware successive halving")
    ap.add_argument("--rungs", type=int, default=None)
    ap.add_argument("--eta", type=int, default=2)
    ap.add_argument("--iters", type=int, default=None,
                    help="offline timing iterations (default 5 smoke / 20)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    iters = args.iters if args.iters is not None else (5 if args.smoke
                                                       else 20)
    payload = write_sweep(args.out, smoke=args.smoke, iters=iters,
                          seed=args.seed, strategy=args.strategy,
                          eta=args.eta, rungs=args.rungs)
    print("name,us_per_call,derived")
    for n, us, d in _rows(payload):
        print(f"{n},{us:.2f},{d}")


if __name__ == "__main__":
    main()
