"""Figures 4/5: resource utilisation vs hidden size, with the AUTO
spill point.

FPGA resources -> TPU analogues: BRAM% -> weight bytes vs an (emulated)
fast-memory budget with the AUTO BRAM->LUTRAM spill reproduced as
vmem->hbm; DSP% -> MXU tile fill fraction.  Two sweeps like the paper's two
figures: compute_unit = vpu ('without DSPs') and mxu ('with DSPs').
`derived` = weight KiB at that hidden size; spill rows mark the AUTO
decision flip.
"""

from repro.core.accelerator import (AcceleratorConfig, lstm_weight_bytes,
                                    plan, resolve_weight_memory)
from repro.core.qlstm import QLSTMConfig

# Scaled budget reproducing the paper's BRAM exhaustion near hidden=130
# (XC7S15: 10x 18Kb BRAM): weight bytes at the paper's spill point.
FPGA_SCALE_BUDGET = lstm_weight_bytes(
    QLSTMConfig(hidden_size=130), AcceleratorConfig())


def run():
    rows = []
    for unit in ("vpu", "mxu"):
        for h in (20, 60, 100, 130, 180, 200):
            model = QLSTMConfig(hidden_size=h)
            acc = AcceleratorConfig(compute_unit=unit,
                                    vmem_budget=FPGA_SCALE_BUDGET)
            p = plan(model, acc)
            spilled = 0 if p["vmem_resident"] else 1
            rows.append((f"f45_{unit}_h{h}_weights_kib_spill{spilled}",
                         0.0, round(p["weight_bytes"] / 1024, 2)))
        # MXU fill (the DSP-occupancy analogue) at the paper's model size
        p20 = plan(QLSTMConfig(hidden_size=20),
                   AcceleratorConfig(compute_unit="mxu"))
        rows.append((f"f45_mxu_fill_h20", 0.0,
                     round(p20["mxu_fill_fraction"], 4)))
    # Real TPU budget: no spill until far larger hidden sizes
    acc_tpu = AcceleratorConfig()
    h = 200
    while resolve_weight_memory(QLSTMConfig(hidden_size=h), acc_tpu) == "vmem" \
            and h < 60000:
        h *= 2
    rows.append(("f45_tpu_vmem_spill_hidden", 0.0, h))
    return rows
