"""Streaming-serving benchmark — achieved samples/s vs the paper's §6
headline (32 873 samples/s at 11.89 GOP/s/W on the XC7S15).

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
      [--stateful-backend ref,xla,pallas] [out.json]

Two scenarios through `repro.serving`:

  * ``stateless`` — the ``Accelerator.serve`` wave path (the paper's
    single-stream real-time deployment, batched).
  * ``stateful[<backend>]`` — many named client streams multiplexed
    through ``StreamServer`` with cross-window (h, c) carry (the
    ROADMAP's many-user scenario; one window per stream per wave), once
    per requested stateful engine, so the artifact records per-backend
    samples/s and GOP/s/W.  ``--stateful-backend`` takes a comma list of
    ``ref`` | ``xla`` | ``pallas``; the default is the plan's
    ``stateful_backend`` (the fused pallas kernel — off-TPU it runs
    interpret mode, so CI's ``--smoke`` measures the pallas-interpret
    point and the numbers track the trajectory, not the FPGA's).

Writes ``BENCH_serving.json``: per-scenario achieved samples/s, per-wave
latency p50/p95/p99, GOP/s/W at the measured operating point, and the
paper reference numbers.  Render with
``python -m repro.analysis.report --serving BENCH_serving.json``.
CI runs ``--smoke`` (small waves, CPU interpret mode) and uploads the
artifact.
"""

import json
import sys

PAPER_SAMPLES_PER_S = 32873.0     # §6, XC7S15 @ 204 MHz
PAPER_GOPS_PER_WATT = 11.89       # Table 4

# 2: stateful scenarios keyed "stateful[<backend>]" with a "backend" field
# (was one "stateful" key with the implicit plan engine).
SCHEMA_VERSION = 2

STATEFUL_BACKENDS = ("ref", "xla", "pallas")


def _scenario_stateless(sess, n_windows, batch):
    """Ordered stateless serving (the Accelerator.serve path)."""
    import numpy as np
    rng = np.random.default_rng(0)
    model = sess.model
    x = rng.uniform(0, 1, (n_windows, model.seq_len,
                           model.input_size)).astype(np.float32)
    from repro.serving import ServingConfig, StreamServer
    cfg = ServingConfig(batch=batch, stateful=False, deadline_s=None)
    with StreamServer(sess, cfg) as srv:
        # Warm-up wave compiles the datapath outside the measured interval.
        for w in x[:batch]:
            srv.submit(None, w)
        srv.drain()
    with StreamServer(sess, cfg) as srv:
        for w in x:
            srv.submit(None, w)
        srv.drain()
        return srv.metrics_summary()


def _scenario_stateful(sess, n_streams, windows_per_stream, batch,
                       backend=None):
    """Multiplexed named streams with cross-window carry on ``backend``
    (None = the plan's ``stateful_backend``)."""
    import numpy as np
    rng = np.random.default_rng(1)
    model = sess.model
    xs = rng.uniform(0, 1, (n_streams, windows_per_stream, model.seq_len,
                            model.input_size)).astype(np.float32)
    from repro.serving import StreamServer
    with StreamServer(sess, batch=batch, deadline_s=0.05, backend=backend,
                      max_streams=max(16, n_streams)) as srv:
        srv.submit("warmup", xs[0, 0])      # compile outside the clock
        srv.drain()
        srv.end_stream("warmup")
        srv.reset_metrics()                 # compile stays outside the clock
        for w in range(windows_per_stream):
            for s in range(n_streams):
                srv.submit(f"s{s}", xs[s, w])
        srv.drain()
        summary = srv.metrics_summary()
    summary["backend"] = backend or sess.plan["stateful_backend"]
    return summary


def _row(name, summary):
    return (f"serving_{name}", summary["latency_ms"]["p50"] * 1e3,
            round(summary["samples_per_s"], 1))


def run(smoke: bool = False, out_path: str = "BENCH_serving.json",
        stateful_backends=None):
    """Measure the stateless scenario plus one stateful scenario per
    requested engine; write the JSON payload and return the CSV-ish rows
    the benchmark harness prints."""
    import repro
    sess = repro.build().quantize()     # the paper's default configuration
    backends = tuple(stateful_backends) if stateful_backends \
        else (sess.plan["stateful_backend"],)
    for b in backends:
        if b not in STATEFUL_BACKENDS:
            raise SystemExit(f"unknown stateful backend {b!r}; "
                             f"choose from {STATEFUL_BACKENDS}")

    scenarios = {}
    if smoke:
        scenarios["stateless"] = _scenario_stateless(sess, n_windows=64,
                                                     batch=16)
        for b in backends:
            scenarios[f"stateful[{b}]"] = _scenario_stateful(
                sess, n_streams=8, windows_per_stream=4, batch=8, backend=b)
    else:
        scenarios["stateless"] = _scenario_stateless(sess, n_windows=4096,
                                                     batch=256)
        for b in backends:
            scenarios[f"stateful[{b}]"] = _scenario_stateful(
                sess, n_streams=128, windows_per_stream=16, batch=64,
                backend=b)

    payload = {
        "suite": "serving",
        "schema_version": SCHEMA_VERSION,
        "smoke": smoke,
        "paper": {"samples_per_s": PAPER_SAMPLES_PER_S,
                  "gops_per_watt": PAPER_GOPS_PER_WATT},
        "scenarios": scenarios,
    }
    for s in payload["scenarios"].values():
        s["vs_paper_samples_per_s"] = s["samples_per_s"] / PAPER_SAMPLES_PER_S
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[serving] wrote {out_path}", file=sys.stderr)
    return [_row(k, v) for k, v in payload["scenarios"].items()]


def main(argv):
    """CLI: ``[--smoke] [--stateful-backend ref,xla,pallas] [out.json]``."""
    smoke = "--smoke" in argv
    stateful_backends = None
    paths = []
    it = iter(a for a in argv if a != "--smoke")
    for a in it:
        if a == "--stateful-backend" or a.startswith("--stateful-backend="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            stateful_backends = [b for b in val.split(",") if b]
            if not stateful_backends:
                raise SystemExit(
                    "--stateful-backend needs a comma list of "
                    f"{','.join(STATEFUL_BACKENDS)}")
        elif a.startswith("--"):
            raise SystemExit(f"unknown flag {a!r}")
        else:
            paths.append(a)
    rows = run(smoke=smoke, out_path=paths[0] if paths
               else "BENCH_serving.json", stateful_backends=stateful_backends)
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.2f},{d}")


if __name__ == "__main__":
    main(sys.argv[1:])
