"""Streaming-serving benchmark — achieved samples/s vs the paper's §6
headline (32 873 samples/s at 11.89 GOP/s/W on the XC7S15).

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
      [--stateful-backend ref,xla,pallas] [--state-residency host,device]
      [--fault-rate F] [--chaos] [--replicas 1,2,4] [out.json]

Three scenario families through `repro.serving`:

  * ``stateless`` — the ``Accelerator.serve`` wave path (the paper's
    single-stream real-time deployment, batched).
  * ``stateful[<backend>]`` — many named client streams multiplexed
    through ``StreamServer`` with cross-window (h, c) carry (the
    ROADMAP's many-user scenario; one window per stream per wave), once
    per requested stateful engine, so the artifact records per-backend
    samples/s and GOP/s/W.  ``--stateful-backend`` takes a comma list of
    ``ref`` | ``xla`` | ``pallas``; the default is the plan's
    ``stateful_backend`` (the fused pallas kernel — off-TPU it runs
    interpret mode, so CI's ``--smoke`` measures the pallas-interpret
    point and the numbers track the trajectory, not the FPGA's).

  * ``cluster[rN]`` (``--replicas`` comma list) — the same multiplexed
    load through ``repro.build_cluster``: N device-pinned replica servers
    behind the consistent-hash front door, schedulers running in
    parallel.  The artifact records the cluster-AGGREGATE samples/s (over
    the common wall), the per-replica breakdown (each replica's own
    p50/p95/p99), and the ring block.  On CPU, scaling needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before jax
    initialises (how CI runs the ``--replicas 1,2`` smoke).

``--state-residency`` (comma list of ``auto`` | ``host`` | ``device``,
default ``auto``) runs each stateful scenario once per requested carry
placement: ``host`` ships every wave's (h, c) batch across the
host/device boundary (the legacy ``StateStore``), ``device`` keeps the
carries in the accelerator-resident slot table and ships only (B,)
slot-id vectors (``ServingConfig.state_residency``; docs/SERVING.md
§State residency).  Each scenario's summary carries the resolved
``state_residency`` and the ``state_transfer`` byte counters — on the
device point ``to_device_bytes == from_device_bytes == 0`` is the
artifact's proof that the per-wave state traffic is gone.

Chaos axes (the PR-6 reliability layer, ``repro.serving.faults``):
``--fault-rate F`` runs the stateful scenarios under a seeded
:class:`FaultInjector` raising ``InjectedFault`` from a fraction ``F`` of
wave executions — the benchmark then measures the RESILIENT throughput
(retries/backoff absorb the faults) and each scenario's summary carries
the ``faults`` block (retries, sheds, degradations, injected counts).
``--chaos`` additionally injects latency spikes and state loss/corruption
at fixed small rates, the full drill described in docs/SERVING.md
§Reliability.

Writes ``BENCH_serving.json``: per-scenario achieved samples/s, per-wave
latency p50/p95/p99, GOP/s/W at the measured operating point, the
``faults``/``health`` reliability blocks, and the paper reference
numbers.  Render with
``python -m repro.analysis.report --serving BENCH_serving.json``.
CI runs ``--smoke --fault-rate 0.1`` (small waves, CPU interpret mode,
seeded chaos) and uploads the artifact.
"""

import json
import sys

PAPER_SAMPLES_PER_S = 32873.0     # §6, XC7S15 @ 204 MHz
PAPER_GOPS_PER_WATT = 11.89       # Table 4

# 2: stateful scenarios keyed "stateful[<backend>]" with a "backend" field
# (was one "stateful" key with the implicit plan engine).
# 3: scenario summaries carry the "faults"/"health" reliability blocks and
# the payload records the chaos axes under "chaos".
# 4: --replicas adds "cluster[rN]" scenarios (ClusterServer over N
# device-pinned replicas): aggregate samples/s over the common wall plus
# "samples_per_s_sum", the per-replica metrics breakdown under "replicas"
# (each with its own p99), and the "ring" routing block.
# 5: --state-residency adds per-placement stateful points keyed
# "stateful[<backend>@<residency>]" (the bare "stateful[<backend>]" key is
# kept for the default auto run); stateful summaries carry the resolved
# "state_residency" and the "state_transfer" per-wave byte counters
# (to_device/from_device pinned at 0 on the device point).
# 6: --cell (comma list from repro.cells.available()) adds one stateful
# point per NON-lstm cell at its plan defaults, keyed
# "stateful[<cell>@<backend>@<residency>]" with both parts resolved (gru/
# rglru resolve to xla@host — no fused kernel); "lstm" in the list is a
# no-op because the default scenarios ARE the lstm points, so every
# pre-v6 key (and its numbers) is byte-identical for a given config.
SCHEMA_VERSION = 6

STATEFUL_BACKENDS = ("ref", "xla", "pallas")
STATE_RESIDENCIES = ("auto", "host", "device")


def _scenario_stateless(sess, n_windows, batch):
    """Ordered stateless serving (the Accelerator.serve path)."""
    import numpy as np
    rng = np.random.default_rng(0)
    model = sess.model
    x = rng.uniform(0, 1, (n_windows, model.seq_len,
                           model.input_size)).astype(np.float32)
    from repro.serving import ServingConfig, StreamServer
    cfg = ServingConfig(batch=batch, stateful=False, deadline_s=None)
    with StreamServer(sess, cfg) as srv:
        # Warm-up wave compiles the datapath outside the measured interval.
        for w in x[:batch]:
            srv.submit(None, w)
        srv.drain()
    with StreamServer(sess, cfg) as srv:
        for w in x:
            srv.submit(None, w)
        srv.drain()
        return srv.metrics_summary()


def _injector(fault_rate, chaos, seed=42):
    """The seeded chaos harness for the requested axes (None when both are
    off — the plain, uninjected benchmark)."""
    if not fault_rate and not chaos:
        return None
    from repro.serving import FaultConfig, FaultInjector
    cfg = FaultConfig(
        wave_fault_rate=float(fault_rate or 0.0),
        latency_spike_rate=0.05 if chaos else 0.0,
        latency_spike_s=0.002,
        state_loss_rate=0.02 if chaos else 0.0,
        state_corrupt_rate=0.0,    # corruption breaks bit-exactness on
    )                              # purpose; keep it to the chaos TESTS
    return FaultInjector(cfg, seed=seed)


def _scenario_stateful(sess, n_streams, windows_per_stream, batch,
                       backend=None, fault_rate=0.0, chaos=False,
                       residency="auto"):
    """Multiplexed named streams with cross-window carry on ``backend``
    (None = the plan's ``stateful_backend``); ``residency`` places the
    carries (``ServingConfig.state_residency``); ``fault_rate``/``chaos``
    run the scenario under the seeded FaultInjector."""
    import numpy as np
    rng = np.random.default_rng(1)
    model = sess.model
    xs = rng.uniform(0, 1, (n_streams, windows_per_stream, model.seq_len,
                            model.input_size)).astype(np.float32)
    from repro.serving import ResiliencePolicy, ServingConfig, StreamServer
    cfg = ServingConfig(batch=batch, deadline_s=0.05, backend=backend,
                        max_streams=max(16, n_streams),
                        state_residency=residency,
                        resilience=ResiliencePolicy(
                            max_retries=3, backoff_base_s=0.0005))
    with StreamServer(sess, cfg,
                      fault_injector=_injector(fault_rate, chaos)) as srv:
        srv.submit("warmup", xs[0, 0])      # compile outside the clock
        srv.drain()
        srv.end_stream("warmup")
        srv.reset_metrics()                 # compile stays outside the clock
        for w in range(windows_per_stream):
            for s in range(n_streams):
                srv.submit(f"s{s}", xs[s, w])
        srv.drain()
        summary = srv.metrics_summary()
    summary["backend"] = backend or sess.plan["stateful_backend"]
    return summary


def _scenario_cluster(sess, n_replicas, n_streams, windows_per_stream,
                      batch):
    """N device-pinned replica servers behind the consistent-hash front
    door (``repro.build_cluster``): each stream sticks to one replica, the
    replicas' schedulers run in parallel, and the summary reports the
    cluster-aggregate samples/s with the per-replica breakdown.  On CI the
    CPU "devices" come from XLA_FLAGS=--xla_force_host_platform_device_
    count, so the scaling trend is the artifact, not absolute numbers."""
    import numpy as np
    import repro
    rng = np.random.default_rng(2)
    model = sess.model
    xs = rng.uniform(0, 1, (n_streams, windows_per_stream, model.seq_len,
                            model.input_size)).astype(np.float32)
    with repro.build_cluster(sess, n_replicas, batch=batch, deadline_s=0.05,
                             max_streams=max(16, n_streams)) as cluster:
        cluster.warmup(xs[0, 0])            # compile every replica's
        for w in range(windows_per_stream):  # datapath outside the clock
            for s in range(n_streams):
                cluster.submit(f"s{s}", xs[s, w])
        cluster.drain()
        summary = cluster.metrics_summary()
    summary["backend"] = f"cluster[{n_replicas}x" \
                         f"{sess.plan['stateful_backend']}]"
    return summary


def _row(name, summary):
    return (f"serving_{name}", summary["latency_ms"]["p50"] * 1e3,
            round(summary["samples_per_s"], 1))


def run(smoke: bool = False, out_path: str = "BENCH_serving.json",
        stateful_backends=None, fault_rate: float = 0.0,
        chaos: bool = False, replicas=None, state_residencies=None,
        cell_axis=None):
    """Measure the stateless scenario plus one stateful scenario per
    requested engine x state residency (under the seeded chaos axes when
    requested), one cluster scenario per requested replica count, and one
    plan-default stateful point per requested non-lstm cell; write the
    JSON payload and return the CSV-ish rows the benchmark harness
    prints."""
    import dataclasses

    import repro
    from repro import cells as cell_registry
    for c in (cell_axis or ()):
        if c not in cell_registry.available():
            raise SystemExit(f"unknown cell {c!r}; "
                             f"choose from {cell_registry.available()}")
    sess = repro.build().quantize()     # the paper's default configuration
    backends = tuple(stateful_backends) if stateful_backends \
        else (sess.plan["stateful_backend"],)
    for b in backends:
        if b not in STATEFUL_BACKENDS:
            raise SystemExit(f"unknown stateful backend {b!r}; "
                             f"choose from {STATEFUL_BACKENDS}")
    residencies = tuple(state_residencies) if state_residencies else ("auto",)
    for r in residencies:
        if r not in STATE_RESIDENCIES:
            raise SystemExit(f"unknown state residency {r!r}; "
                             f"choose from {STATE_RESIDENCIES}")

    def _skey(b, r):
        # The bare pre-v5 key for the default placement, an explicit
        # "@<residency>" suffix for requested host-vs-device points.
        return f"stateful[{b}]" if r == "auto" else f"stateful[{b}@{r}]"

    scenarios = {}
    if smoke:
        scenarios["stateless"] = _scenario_stateless(sess, n_windows=64,
                                                     batch=16)
        for b in backends:
            for r in residencies:
                scenarios[_skey(b, r)] = _scenario_stateful(
                    sess, n_streams=8, windows_per_stream=4, batch=8,
                    backend=b, fault_rate=fault_rate, chaos=chaos,
                    residency=r)
        for n in (replicas or ()):
            # Enough streams that every replica still fills waves at the
            # largest requested fan-out — the scaling trend needs the
            # per-replica occupancy to survive the split (and enough
            # compute per wave that the parallel schedulers have work to
            # overlap on a multi-core runner).
            scenarios[f"cluster[r{n}]"] = _scenario_cluster(
                sess, n_replicas=n, n_streams=48, windows_per_stream=8,
                batch=12)
    else:
        scenarios["stateless"] = _scenario_stateless(sess, n_windows=4096,
                                                     batch=256)
        for b in backends:
            for r in residencies:
                scenarios[_skey(b, r)] = _scenario_stateful(
                    sess, n_streams=128, windows_per_stream=16, batch=64,
                    backend=b, fault_rate=fault_rate, chaos=chaos,
                    residency=r)
        for n in (replicas or ()):
            scenarios[f"cluster[r{n}]"] = _scenario_cluster(
                sess, n_replicas=n, n_streams=128, windows_per_stream=16,
                batch=32)

    # The cell axis: one stateful point per non-lstm cell at its OWN plan
    # defaults (backend/residency resolved by the registry — gru/rglru
    # have no fused kernel, so they land on xla@host).  "lstm" is skipped:
    # the scenarios above already measure it under the pre-v6 keys, which
    # must stay byte-identical.
    for c in (cell_axis or ()):
        if c == "lstm":
            continue
        sess_c = repro.build(
            dataclasses.replace(sess.model, cell=c)).quantize()
        key = (f"stateful[{c}@{sess_c.plan['stateful_backend']}"
               f"@{sess_c.plan['state_residency']}]")
        if smoke:
            scenarios[key] = _scenario_stateful(
                sess_c, n_streams=8, windows_per_stream=4, batch=8,
                fault_rate=fault_rate, chaos=chaos)
        else:
            scenarios[key] = _scenario_stateful(
                sess_c, n_streams=64, windows_per_stream=8, batch=32,
                fault_rate=fault_rate, chaos=chaos)
        scenarios[key]["cell"] = c

    payload = {
        "suite": "serving",
        "schema_version": SCHEMA_VERSION,
        "smoke": smoke,
        "cells": list(cell_axis or ()),
        "chaos": {"fault_rate": float(fault_rate), "chaos": bool(chaos)},
        "paper": {"samples_per_s": PAPER_SAMPLES_PER_S,
                  "gops_per_watt": PAPER_GOPS_PER_WATT},
        "scenarios": scenarios,
    }
    for s in payload["scenarios"].values():
        s["vs_paper_samples_per_s"] = s["samples_per_s"] / PAPER_SAMPLES_PER_S
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[serving] wrote {out_path}", file=sys.stderr)
    return [_row(k, v) for k, v in payload["scenarios"].items()]


def main(argv):
    """CLI: ``[--smoke] [--stateful-backend ref,xla,pallas]
    [--state-residency auto,host,device] [--cell lstm,gru,rglru]
    [--fault-rate F] [--chaos] [--replicas 1,2,4] [out.json]``."""
    smoke = "--smoke" in argv
    chaos = "--chaos" in argv
    stateful_backends = None
    state_residencies = None
    cell_axis = None
    fault_rate = 0.0
    replicas = None
    paths = []
    it = iter(a for a in argv if a not in ("--smoke", "--chaos"))
    for a in it:
        if a == "--stateful-backend" or a.startswith("--stateful-backend="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            stateful_backends = [b for b in val.split(",") if b]
            if not stateful_backends:
                raise SystemExit(
                    "--stateful-backend needs a comma list of "
                    f"{','.join(STATEFUL_BACKENDS)}")
        elif a == "--state-residency" or a.startswith("--state-residency="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            state_residencies = [r for r in val.split(",") if r]
            if not state_residencies:
                raise SystemExit(
                    "--state-residency needs a comma list of "
                    f"{','.join(STATE_RESIDENCIES)}")
        elif a == "--cell" or a.startswith("--cell="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            cell_axis = [c for c in val.split(",") if c]
            if not cell_axis:
                raise SystemExit("--cell needs a comma list of registered "
                                 "cells (see repro.cells.available())")
        elif a == "--fault-rate" or a.startswith("--fault-rate="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            try:
                fault_rate = float(val)
            except ValueError:
                raise SystemExit(f"--fault-rate needs a float, got {val!r}")
            if not 0.0 <= fault_rate < 1.0:
                raise SystemExit(
                    f"--fault-rate must be in [0, 1), got {fault_rate}")
        elif a == "--replicas" or a.startswith("--replicas="):
            val = a.split("=", 1)[1] if "=" in a else next(it, "")
            try:
                replicas = [int(n) for n in val.split(",") if n]
            except ValueError:
                raise SystemExit(
                    f"--replicas needs a comma list of ints, got {val!r}")
            if not replicas or any(n < 1 for n in replicas):
                raise SystemExit(
                    f"--replicas needs positive counts, got {val!r}")
        elif a.startswith("--"):
            raise SystemExit(f"unknown flag {a!r}")
        else:
            paths.append(a)
    rows = run(smoke=smoke, out_path=paths[0] if paths
               else "BENCH_serving.json", stateful_backends=stateful_backends,
               fault_rate=fault_rate, chaos=chaos, replicas=replicas,
               state_residencies=state_residencies, cell_axis=cell_axis)
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.2f},{d}")


if __name__ == "__main__":
    main(sys.argv[1:])
