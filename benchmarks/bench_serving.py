"""Streaming-serving benchmark — achieved samples/s vs the paper's §6
headline (32 873 samples/s at 11.89 GOP/s/W on the XC7S15).

  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [out.json]

Two scenarios through `repro.serving`:

  * ``stateless`` — the ``Accelerator.serve`` wave path (the paper's
    single-stream real-time deployment, batched).
  * ``stateful``  — many named client streams multiplexed through
    ``StreamServer`` with cross-window (h, c) carry (the ROADMAP's
    many-user scenario; one window per stream per wave).

Writes ``BENCH_serving.json``: per-scenario achieved samples/s, per-wave
latency p50/p95/p99, GOP/s/W at the measured operating point, and the
paper reference numbers.  Render with
``python -m repro.analysis.report --serving BENCH_serving.json``.
CI runs ``--smoke`` (small waves, CPU interpret mode) and uploads the
artifact — the numbers track the perf trajectory, not the FPGA's.
"""

import json
import sys

PAPER_SAMPLES_PER_S = 32873.0     # §6, XC7S15 @ 204 MHz
PAPER_GOPS_PER_WATT = 11.89       # Table 4

SCHEMA_VERSION = 1


def _scenario_stateless(sess, n_windows, batch):
    """Ordered stateless serving (the Accelerator.serve path)."""
    import numpy as np
    rng = np.random.default_rng(0)
    model = sess.model
    x = rng.uniform(0, 1, (n_windows, model.seq_len,
                           model.input_size)).astype(np.float32)
    from repro.serving import ServingConfig, StreamServer
    cfg = ServingConfig(batch=batch, stateful=False, deadline_s=None)
    with StreamServer(sess, cfg) as srv:
        # Warm-up wave compiles the datapath outside the measured interval.
        for w in x[:batch]:
            srv.submit(None, w)
        srv.drain()
    with StreamServer(sess, cfg) as srv:
        for w in x:
            srv.submit(None, w)
        srv.drain()
        return srv.metrics_summary()


def _scenario_stateful(sess, n_streams, windows_per_stream, batch):
    """Multiplexed named streams with cross-window carry."""
    import numpy as np
    rng = np.random.default_rng(1)
    model = sess.model
    xs = rng.uniform(0, 1, (n_streams, windows_per_stream, model.seq_len,
                            model.input_size)).astype(np.float32)
    from repro.serving import StreamServer
    with StreamServer(sess, batch=batch, deadline_s=0.05,
                      max_streams=max(16, n_streams)) as srv:
        srv.submit("warmup", xs[0, 0])      # compile outside the clock
        srv.drain()
        srv.end_stream("warmup")
        srv.reset_metrics()                 # compile stays outside the clock
        for w in range(windows_per_stream):
            for s in range(n_streams):
                srv.submit(f"s{s}", xs[s, w])
        srv.drain()
        return srv.metrics_summary()


def _row(name, summary):
    return (f"serving_{name}", summary["latency_ms"]["p50"] * 1e3,
            round(summary["samples_per_s"], 1))


def run(smoke: bool = False, out_path: str = "BENCH_serving.json"):
    """Measure both scenarios and write the JSON payload; returns the
    CSV-ish rows the benchmark harness prints."""
    import repro
    sess = repro.build().quantize()     # the paper's default configuration

    if smoke:
        stateless = _scenario_stateless(sess, n_windows=64, batch=16)
        stateful = _scenario_stateful(sess, n_streams=8,
                                      windows_per_stream=4, batch=8)
    else:
        stateless = _scenario_stateless(sess, n_windows=4096, batch=256)
        stateful = _scenario_stateful(sess, n_streams=128,
                                      windows_per_stream=16, batch=64)

    payload = {
        "suite": "serving",
        "schema_version": SCHEMA_VERSION,
        "smoke": smoke,
        "paper": {"samples_per_s": PAPER_SAMPLES_PER_S,
                  "gops_per_watt": PAPER_GOPS_PER_WATT},
        "scenarios": {"stateless": stateless, "stateful": stateful},
    }
    for s in payload["scenarios"].values():
        s["vs_paper_samples_per_s"] = s["samples_per_s"] / PAPER_SAMPLES_PER_S
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[serving] wrote {out_path}", file=sys.stderr)
    return [_row(k, v) for k, v in payload["scenarios"].items()]


def main(argv):
    """CLI: ``[--smoke] [out.json]``."""
    smoke = "--smoke" in argv
    paths = [a for a in argv if not a.startswith("--")]
    rows = run(smoke=smoke, out_path=paths[0] if paths
               else "BENCH_serving.json")
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.2f},{d}")


if __name__ == "__main__":
    main(sys.argv[1:])
