"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [table1 table3 table4 fig45 cells]

Prints ``name,us_per_call,derived`` CSV (one row per measurement).
"""

import sys


def main() -> None:
    from benchmarks import (bench_activations, bench_cells, bench_energy,
                            bench_resources, bench_throughput)
    suites = {
        "table1": bench_activations.run,
        "table3": bench_throughput.run,
        "table4": bench_energy.run,
        "fig45": bench_resources.run,
        "cells": bench_cells.run,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for key in want:
        for name, us, derived in suites[key]():
            print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
