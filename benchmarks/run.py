"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [table1 table3 table4 fig45 cells pareto serving]
  PYTHONPATH=src python -m benchmarks.run --smoke [out.json]
  PYTHONPATH=src python -m benchmarks.run --sweep [--smoke] \
      [--strategy=halving --rungs=2 --eta=2] [out.json]
  PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [out.json]

Prints ``name,us_per_call,derived`` CSV (one row per measurement).

``--smoke`` runs a fast subset — the analytical accelerator-grid cells
plus one timed int-datapath measurement per backend through the session
API — and writes it to ``BENCH_smoke.json`` (override with a positional
path) so CI records the perf trajectory.

``--sweep`` runs the design-space exploration (``repro.explore`` over the
Table-4 space; ``--smoke`` restricts it to the deterministic 4-point CPU
space) and writes the scored points + Pareto front to ``BENCH_pareto.json``
(override with a positional path).  ``--strategy=halving`` switches to the
serving-aware successive-halving search (each point scored by a short real
server run; schema v2 with per-point operating points).  Render either
with ``python -m repro.analysis.report --pareto BENCH_pareto.json``.
"""

import json
import sys
import time


def _smoke_rows():
    """Cheap, deterministic-shape rows: plan/energy grid + one timed call
    per backend (small batch so CPU interpret mode stays fast)."""
    import jax
    import repro
    from benchmarks import bench_cells

    rows = list(bench_cells._lstm_grid_rows())

    sess = repro.build().quantize()
    x = jax.random.normal(jax.random.key(0), (32, 6, 1)) * 0.5
    for backend in ("ref", "pallas", "xla"):
        fn = sess.compiled("int", backend)
        fn(x).block_until_ready()           # compile outside the clock
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            out = fn(x)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"smoke_int_b32_{backend}", round(us, 2), 32))
    return rows


def smoke(out_path: str = "BENCH_smoke.json") -> None:
    rows = _smoke_rows()
    payload = {
        "suite": "smoke",
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.2f},{d}")
    print(f"[smoke] wrote {len(rows)} rows to {out_path}", file=sys.stderr)


def sweep(argv) -> None:
    from benchmarks import bench_pareto
    smoke_mode = "--smoke" in argv
    opts = dict(a[2:].split("=", 1) for a in argv
                if a.startswith("--") and "=" in a)
    paths = [a for a in argv if not a.startswith("--")]
    payload = bench_pareto.write_sweep(paths[0] if paths
                                       else "BENCH_pareto.json",
                                       smoke=smoke_mode,
                                       iters=5 if smoke_mode else 20,
                                       strategy=opts.get("strategy", "full"),
                                       eta=int(opts.get("eta", 2)),
                                       rungs=(int(opts["rungs"])
                                              if "rungs" in opts else None))
    print("name,us_per_call,derived")
    for n, us, d in bench_pareto._rows(payload):
        print(f"{n},{us:.2f},{d}")


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "--smoke":
        smoke(*argv[1:2])
        return
    if argv and argv[0] == "--sweep":
        sweep(argv[1:])
        return
    from benchmarks import (bench_activations, bench_cells, bench_energy,
                            bench_pareto, bench_resources, bench_serving,
                            bench_throughput)
    suites = {
        "table1": bench_activations.run,
        "table3": bench_throughput.run,
        "table4": bench_energy.run,
        "fig45": bench_resources.run,
        "cells": bench_cells.run,
        "pareto": bench_pareto.run,
        "serving": bench_serving.run,
    }
    want = argv or list(suites)
    print("name,us_per_call,derived")
    for key in want:
        for name, us, derived in suites[key]():
            print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
