"""Table 1: HardSigmoid* implementation methods x fixed-point configs.

FPGA metrics (logic delay, LUTs) map to: measured CPU wall-clock of the
XLA-compiled integer implementation (delay analogue) and structural cost
(table entries / comparator count — the resource analogue).  The paper's
finding to reproduce: the best method depends on the fixed-point config
(step wins at (4,8); 1to1 wins at higher fractional widths where the step
comparator cascade blows up).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hard_act as ha
from repro.core.fixed_point import FXP_4_8, FXP_6_8, FXP_8_10

CONFIGS = [("(4,8)", FXP_4_8), ("(6,8)", FXP_6_8), ("(8,10)", FXP_8_10)]
METHODS = ("arithmetic", "1to1", "step")
N = 1 << 16


def _time(fn, x, iters=30):
    fn(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    for cname, cfg in CONFIGS:
        x = jnp.asarray(rng.integers(cfg.int_min, cfg.int_max + 1, N)
                        .astype(np.int32))
        for m in METHODS:
            spec = ha.HardSigmoidStarSpec(cfg)
            fn = jax.jit(lambda t, s=spec, m=m: ha.hs_star_int(t, s, m))
            us = _time(fn, x)
            entries = {"arithmetic": 2,  # shift + add
                       "1to1": ha.num_1to1_entries(spec),
                       "step": ha.num_step_entries(spec)}[m]
            rows.append((f"t1_hardsigmoid_{cname}_{m}", us, entries))
    return rows
