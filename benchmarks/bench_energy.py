"""Table 4: power & energy efficiency comparison.

Reproduces the structure of the paper's Table 4 with the TPU energy model
(core/energy.py): static/dynamic power split, energy per inference,
throughput and GOP/s/W, for:
  (a) the [15]-baseline datapath ((8,16), LUT acts, non-pipelined),
  (b) this-work on the MXU ('8 DSPs' column),
  (c) this-work on the VPU ('0 DSPs' column — the paper's headline option).
Latency inputs are the measured CPU relative latencies scaled to the
paper's absolute operating point (28.07 us for this-work), so the relative
energy story matches Table 3/4 while absolute watts come from the TPU
model.  `derived` = GOP/s/W.
"""

from repro.core.accelerator import (AcceleratorConfig, BASELINE_15,
                                    PAPER_DEFAULT, PAPER_NO_MXU, plan)
from repro.core.energy import power_report
from repro.core.qlstm import QLSTMConfig, ops_per_inference
from benchmarks.bench_throughput import _mk, _time


def run():
    cfgs = {
        "t4_baseline15": (BASELINE_15, None),
        "t4_thiswork_mxu": (PAPER_DEFAULT, "mxu"),
        "t4_thiswork_vpu": (PAPER_NO_MXU, "vpu"),
    }
    model = QLSTMConfig()
    ops = ops_per_inference(model)

    # measured relative latency (CPU, XLA-compiled): baseline vs this-work
    from repro.core.qlstm import ActivationConfig, BASELINE_ACTS
    from repro.core.fixed_point import FXP_8_16
    fn_b, xi_b = _mk(QLSTMConfig(acts=BASELINE_ACTS, fxp=FXP_8_16,
                                 alu_mode="per_step"))
    fn_t, xi_t = _mk(QLSTMConfig())
    rel = _time(fn_b, xi_b) / _time(fn_t, xi_t)

    lat_tw = 28.07e-6                       # paper's this-work latency
    lat_by_name = {"t4_baseline15": lat_tw * rel,
                   "t4_thiswork_mxu": lat_tw,
                   "t4_thiswork_vpu": lat_tw}
    rows = []
    for name, (acc, unit) in cfgs.items():
        p = plan(model, acc)
        lat = lat_by_name[name]
        rep = power_report(flops=ops, hbm_bytes=p["weight_bytes"],
                           ici_bytes=0, latency_s=lat,
                           unit=p["compute_unit"],
                           dtype="int8" if acc.fxp.total_bits <= 8 else "bf16")
        rows.append((name + "_gops_per_w", lat * 1e6,
                     round(rep["gops_per_watt"], 4)))
        rows.append((name + "_energy_uj", lat * 1e6,
                     round(rep["energy_j"] * 1e6, 3)))

    # TPU-scale rows: the FPGA amortises 32 mW of static power over one
    # stream; a TPU must amortise ~60 W over BATCHED streams.  At MXU/VPU
    # saturation (weights VMEM-resident, C4's BRAM mode) the energy
    # efficiency is bounded by the unit's ops/J — the paper's DSP-vs-LUT
    # column pair at datacenter scale.
    from repro.core import energy as E
    for name, peak, e_op in [
            ("t4_tpu_saturated_mxu_int8", E.PEAK_INT8_OPS, E.E_MXU_INT8_J_PER_OP),
            ("t4_tpu_saturated_mxu_bf16", E.PEAK_BF16_FLOPS, E.E_MXU_BF16_J_PER_FLOP),
            ("t4_tpu_saturated_vpu", E.PEAK_VPU_FLOPS, E.E_VPU_J_PER_FLOP)]:
        gops = peak / 1e9
        watts = E.P_STATIC_W + peak * e_op
        rows.append((name + "_gops_per_w", 0.0, round(gops / watts, 2)))
    return rows
