"""Table 4: power & energy efficiency comparison.

Reproduces the structure of the paper's Table 4 through
``Accelerator.report()`` (the TPU energy model, core/energy.py):
static/dynamic power split, energy per inference, throughput and GOP/s/W,
for:
  (a) the [15]-baseline datapath ((8,16), LUT acts, non-pipelined),
  (b) this-work on the MXU ('8 DSPs' column),
  (c) this-work on the VPU ('0 DSPs' column — the paper's headline option).
Latency inputs are the measured CPU relative latencies scaled to the
paper's absolute operating point (28.07 us for this-work), so the relative
energy story matches Table 3/4 while absolute watts come from the TPU
model.  `derived` = GOP/s/W.
"""

import repro
from repro.api import PAPER_LATENCY_S
from repro.core.accelerator import (AcceleratorConfig, BASELINE_15,
                                    PAPER_DEFAULT, PAPER_NO_MXU)
from repro.core.fixed_point import FXP_8_16
from repro.core.qlstm import BASELINE_ACTS, QLSTMConfig
from benchmarks.bench_throughput import _mk, _time


def run():
    model = QLSTMConfig()
    cfgs = {
        "t4_baseline15": (QLSTMConfig(acts=BASELINE_ACTS), BASELINE_15),
        "t4_thiswork_mxu": (model, PAPER_DEFAULT),
        "t4_thiswork_vpu": (model, PAPER_NO_MXU),
    }

    # measured relative latency (CPU, XLA-compiled): baseline vs this-work
    fn_b, x_b = _mk(QLSTMConfig(acts=BASELINE_ACTS),
                    AcceleratorConfig(fxp=FXP_8_16, alu_mode="per_step",
                                      hs_method="1to1"))
    fn_t, x_t = _mk(model, PAPER_DEFAULT)
    rel = _time(fn_b, x_b) / _time(fn_t, x_t)

    lat_by_name = {"t4_baseline15": PAPER_LATENCY_S * rel,
                   "t4_thiswork_mxu": PAPER_LATENCY_S,
                   "t4_thiswork_vpu": PAPER_LATENCY_S}
    rows = []
    for name, (mcfg, acfg) in cfgs.items():
        lat = lat_by_name[name]
        rep = repro.build(mcfg, acfg).report(latency_s=lat)["energy"]
        rows.append((name + "_gops_per_w", lat * 1e6,
                     round(rep["gops_per_watt"], 4)))
        rows.append((name + "_energy_uj", lat * 1e6,
                     round(rep["energy_j"] * 1e6, 3)))

    # TPU-scale rows: the FPGA amortises 32 mW of static power over one
    # stream; a TPU must amortise ~60 W over BATCHED streams.  At MXU/VPU
    # saturation (weights VMEM-resident, C4's BRAM mode) the energy
    # efficiency is bounded by the unit's ops/J — the paper's DSP-vs-LUT
    # column pair at datacenter scale.
    from repro.core import energy as E
    for name, peak, e_op in [
            ("t4_tpu_saturated_mxu_int8", E.PEAK_INT8_OPS, E.E_MXU_INT8_J_PER_OP),
            ("t4_tpu_saturated_mxu_bf16", E.PEAK_BF16_FLOPS, E.E_MXU_BF16_J_PER_FLOP),
            ("t4_tpu_saturated_vpu", E.PEAK_VPU_FLOPS, E.E_VPU_J_PER_FLOP)]:
        gops = peak / 1e9
        watts = E.P_STATIC_W + peak * e_op
        rows.append((name + "_gops_per_w", 0.0, round(gops / watts, 2)))
    return rows
