"""Table 3: accelerator throughput across the optimisation ladder.

Columns of the paper's Table 3, re-expressed through the session API
(``repro.build``; each variant is ONE ``AcceleratorConfig`` — the paper's
point that the whole ladder is a parameter sweep):

  [15]-baseline : (8,16) fixed point, 256-entry LUT Sigmoid/Tanh,
                  NON-pipelined ALU (per-product rounding, element-serial).
  hard-*        : HardSigmoid*(method)+HardTanh, still non-pipelined.
  pipelined+step: late-rounding MAC (matmul datapath) + step activations —
                  the full 'this work' configuration (2.04x in the paper).

Measured as XLA-compiled CPU wall-clock per batched inference (the ``xla``
backend override keeps the engine constant across variants so only the
datapath parameters vary); `derived` is the speedup over the [15] baseline
(the paper's 'Improvement' row).
"""

import time

import jax
import jax.numpy as jnp

import repro
from repro.core.accelerator import AcceleratorConfig
from repro.core.fixed_point import FXP_8_16
from repro.core.qlstm import BASELINE_ACTS, QLSTMConfig

BATCH = 256


def _mk(model: QLSTMConfig, accel: AcceleratorConfig, backend: str = "xla"):
    """Build + quantize a session; return (jitted int fn, int-code input).

    Times the raw integer boundary (``infer_int``) on pre-quantised codes —
    the float->int quantise / int->float dequantise boundary conversions
    stay OUTSIDE the clock, so the speedup ratios compare pure datapaths
    (the paper measures the accelerator, not the host-side conversion)."""
    from repro.core import fixed_point as fxp
    sess = repro.build(model, accel).quantize()
    x = jax.random.normal(jax.random.key(1), (BATCH, model.seq_len,
                                              model.input_size)) * 0.5
    xi = fxp.quantize(x, sess.model.fxp)
    fn = jax.jit(lambda v: sess.infer_int(v, backend=backend))
    fn(xi).block_until_ready()
    return fn, xi


def _time(fn, x, iters=20):
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    model = QLSTMConfig()
    variants = [
        ("t3_baseline15_lut_perstep",
         QLSTMConfig(acts=BASELINE_ACTS),
         AcceleratorConfig(fxp=FXP_8_16, alu_mode="per_step",
                           hs_method="1to1")),
        ("t3_hard_arithmetic_perstep", model,
         AcceleratorConfig(alu_mode="per_step", hs_method="arithmetic")),
        ("t3_hard_1to1_perstep", model,
         AcceleratorConfig(alu_mode="per_step", hs_method="1to1")),
        ("t3_hard_step_perstep", model,
         AcceleratorConfig(alu_mode="per_step", hs_method="step")),
        ("t3_pipelined_step_thiswork", model,
         AcceleratorConfig(alu_mode="pipelined", hs_method="step")),
    ]
    rows = []
    base_us = None
    ops = repro.build(model).report()["ops_per_inference"] * BATCH
    for name, mcfg, acfg in variants:
        fn, x = _mk(mcfg, acfg)
        us = _time(fn, x)
        if base_us is None:
            base_us = us
        rows.append((name, us, round(base_us / us, 3)))
    rows.append(("t3_thiswork_gops_cpu", us, round(ops / us / 1e3, 3)))
    return rows
