"""Table 3: accelerator throughput across the optimisation ladder.

Columns of the paper's Table 3, re-expressed:
  [15]-baseline : (8,16) fixed point, 256-entry LUT Sigmoid/Tanh,
                  NON-pipelined ALU (per-product rounding, element-serial).
  hard-*        : HardSigmoid*(method)+HardTanh, still non-pipelined.
  pipelined+step: late-rounding MAC (matmul datapath) + step activations —
                  the full 'this work' configuration (2.04x in the paper).

Measured as XLA-compiled CPU wall-clock per batched inference; `derived` is
the speedup over the [15] baseline (the paper's 'Improvement' row).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core.fixed_point import FXP_4_8, FXP_8_16
from repro.core.qlstm import (ActivationConfig, BASELINE_ACTS, QLSTMConfig,
                              forward_int, init_params, quantize_params,
                              ops_per_inference)

BATCH = 256


def _mk(cfg):
    params = init_params(cfg, jax.random.key(0))
    qp = quantize_params(params, cfg)
    x = jax.random.normal(jax.random.key(1), (BATCH, cfg.seq_len,
                                              cfg.input_size)) * 0.5
    xi = fxp.quantize(x, cfg.fxp)
    fn = jax.jit(lambda xi: forward_int(qp, xi, cfg))
    fn(xi).block_until_ready()
    return fn, xi


def _time(fn, x, iters=20):
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    variants = [
        ("t3_baseline15_lut_perstep",
         QLSTMConfig(acts=BASELINE_ACTS, fxp=FXP_8_16, alu_mode="per_step")),
        ("t3_hard_arithmetic_perstep",
         QLSTMConfig(acts=ActivationConfig(hs_method="arithmetic"),
                     alu_mode="per_step")),
        ("t3_hard_1to1_perstep",
         QLSTMConfig(acts=ActivationConfig(hs_method="1to1"),
                     alu_mode="per_step")),
        ("t3_hard_step_perstep",
         QLSTMConfig(acts=ActivationConfig(hs_method="step"),
                     alu_mode="per_step")),
        ("t3_pipelined_step_thiswork",
         QLSTMConfig(acts=ActivationConfig(hs_method="step"),
                     alu_mode="pipelined")),
    ]
    rows = []
    base_us = None
    ops = ops_per_inference(QLSTMConfig()) * BATCH
    for name, cfg in variants:
        fn, xi = _mk(cfg)
        us = _time(fn, xi)
        if base_us is None:
            base_us = us
        rows.append((name, us, round(base_us / us, 3)))
    rows.append(("t3_thiswork_gops_cpu", us, round(ops / us / 1e3, 3)))
    return rows
