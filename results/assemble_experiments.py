"""Inject §Dry-run and §Roofline tables into EXPERIMENTS.md placeholders."""
import io, json, sys, contextlib
sys.path.insert(0, "src")
from repro.analysis.report import dryrun_table, roofline_table

rs = json.load(open("results/dryrun.json"))
dr = ("### Single-pod 16x16 (256 chips)\n\n" + dryrun_table(rs, "16x16") +
      "\n\n### Multi-pod 2x16x16 (512 chips)\n\n" + dryrun_table(rs, "2x16x16"))
rf = roofline_table(rs)

src = open("EXPERIMENTS.md").read()
src = src.replace("<!-- DRYRUN_TABLES -->", dr)
src = src.replace("<!-- ROOFLINE_TABLE -->", rf)
open("EXPERIMENTS.md", "w").write(src)
print("tables injected:", len(dr), "+", len(rf), "chars")
