"""CI gate for the ``BENCH_pareto.json`` artifact (schema v2).

Usage::

    python tools/check_pareto_schema.py BENCH_pareto.json
    python tools/check_pareto_schema.py --expect-operating-point BENCH.json

Asserts the payload is the schema ``repro.explore.sweep`` promises —
version 2, the required top-level keys, one well-formed row per point —
and, for serving-aware payloads (a ``scenario`` is present, or
``--expect-operating-point`` demands one), that every ok row carries the
serving ``operating_point`` record and a halving sweep carries its full
rung-promotion trace.  Exits 1 with a message naming the first violation,
so a schema drift fails the workflow instead of silently shipping an
artifact the report and ``autotune(payload=...)`` cannot read.
"""

import json
import sys

TOP_KEYS = ("suite", "schema_version", "mode", "strategy", "seed", "space",
            "objectives", "points", "front", "front_reason")
ROW_KEYS = ("label", "config", "status", "pareto")
OK_ROW_KEYS = ("plan", "metrics")
OPERATING_POINT_KEYS = ("scenario", "rung", "fraction", "final", "p99_ms",
                        "deadline_miss_rate", "constraint", "feasible")
HALVING_KEYS = ("eta", "sizes", "fractions", "rungs", "winner_label",
                "winner_feasible", "total_measurements", "budget_bound",
                "objective", "sense", "constraint")
RUNG_KEYS = ("rung", "fraction", "measured", "ranking", "promoted")


def fail(msg: str) -> None:
    print(f"[check_pareto_schema] FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(payload: dict, *, expect_operating_point: bool = False) -> None:
    if payload.get("suite") != "pareto":
        fail(f"suite is {payload.get('suite')!r}, expected 'pareto'")
    if payload.get("schema_version") != 2:
        fail(f"schema_version is {payload.get('schema_version')!r}, "
             f"expected 2")
    for k in TOP_KEYS:
        if k not in payload:
            fail(f"missing top-level key {k!r}")
    serving = payload.get("scenario") is not None
    if expect_operating_point and not serving:
        fail("--expect-operating-point but the payload has no scenario")
    points = payload["points"]
    if not isinstance(points, list) or not points:
        fail("points must be a non-empty list")
    labels = set()
    n_ok = 0
    for i, r in enumerate(points):
        for k in ROW_KEYS:
            if k not in r:
                fail(f"points[{i}] missing {k!r}")
        if r["label"] in labels:
            fail(f"duplicate point label {r['label']!r}")
        labels.add(r["label"])
        if r["status"] == "ok":
            n_ok += 1
            for k in OK_ROW_KEYS:
                if k not in r:
                    fail(f"ok point {r['label']!r} missing {k!r}")
            if serving:
                op = r.get("operating_point")
                if not isinstance(op, dict):
                    fail(f"serving point {r['label']!r} has no "
                         f"operating_point record")
                for k in OPERATING_POINT_KEYS:
                    if k not in op:
                        fail(f"operating_point of {r['label']!r} "
                             f"missing {k!r}")
        elif r["status"] in ("unsupported", "infeasible", "failed"):
            if not r.get("reason"):
                fail(f"{r['status']} point {r['label']!r} carries no reason")
        else:
            fail(f"points[{i}] has unknown status {r['status']!r}")
    for lab in payload["front"]:
        if lab not in labels:
            fail(f"front label {lab!r} is not a swept point")
    if not payload["front"] and n_ok and not payload["front_reason"]:
        fail("empty front over ok points but no front_reason recorded")
    if payload.get("strategy") == "halving":
        tr = payload.get("halving")
        if not isinstance(tr, dict):
            fail("strategy='halving' but no halving trace recorded")
        for k in HALVING_KEYS:
            if k not in tr:
                fail(f"halving trace missing {k!r}")
        if len(tr["sizes"]) != len(tr["rungs"]):
            fail(f"halving trace has {len(tr['sizes'])} sizes but "
                 f"{len(tr['rungs'])} rung records")
        for rec in tr["rungs"]:
            for k in RUNG_KEYS:
                if k not in rec:
                    fail(f"halving rung record missing {k!r}")
        if tr["total_measurements"] > tr["budget_bound"]:
            fail(f"halving spent {tr['total_measurements']} measurements, "
                 f"over the analytic bound {tr['budget_bound']}")
        if tr["fractions"] and tr["fractions"][-1] != 1.0:
            fail(f"final halving rung ran fraction "
                 f"{tr['fractions'][-1]}, expected the full scenario (1.0)")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    expect_op = "--expect-operating-point" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 1:
        fail("usage: check_pareto_schema.py [--expect-operating-point] "
             "BENCH_pareto.json")
    with open(paths[0]) as f:
        payload = json.load(f)
    check(payload, expect_operating_point=expect_op)
    serving = payload.get("scenario") is not None
    print(f"[check_pareto_schema] OK: {paths[0]} — schema v2, "
          f"{len(payload['points'])} points, "
          f"{len(payload['front'])} on the front"
          f"{' (serving-aware)' if serving else ''}")


if __name__ == "__main__":
    main()
