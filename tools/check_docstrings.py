"""Docstring-coverage gate for public surfaces.

  PYTHONPATH=src python tools/check_docstrings.py src/repro/serving [...]

Walks every ``.py`` file under the given paths and fails (exit 1) when a
PUBLIC def/class/module — name not starting with ``_`` and not nested
inside a function — has no docstring.  The CI docs job points this at
``src/repro/serving``, ``src/repro/kernels``, and ``src/repro/backends``
so new surface in those packages cannot land undocumented; point it at
more packages as their docs are brought up to standard.
"""

from __future__ import annotations

import ast
import os
import sys
from typing import Iterator, List, Tuple


def _py_files(path: str) -> Iterator[str]:
    """Yield ``path`` itself (a .py file) or every .py file below it."""
    if os.path.isfile(path):
        yield path
        return
    for root, _dirs, files in os.walk(path):
        for f in sorted(files):
            if f.endswith(".py"):
                yield os.path.join(root, f)


def _public_defs(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Yield (dotted name, node) for every public module-level or
    class-level def/class.  Function-local defs are implementation detail
    and exempt."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                if child.name.startswith("_"):
                    continue
                name = f"{prefix}{child.name}"
                yield name, child
                if isinstance(child, ast.ClassDef):
                    yield from walk(child, f"{name}.")

    yield from walk(tree, "")


def missing_docstrings(path: str) -> List[str]:
    """``file:line: name`` for every public definition without a docstring
    (including the module itself)."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    out = []
    if ast.get_docstring(tree) is None:
        out.append(f"{path}:1: module")
    for name, node in _public_defs(tree):
        if ast.get_docstring(node) is None:
            out.append(f"{path}:{node.lineno}: {name}")
    return out


def main(paths: List[str]) -> int:
    """Check every path; print offenders; 0 iff all public defs documented."""
    if not paths:
        print("usage: check_docstrings.py PATH [PATH ...]", file=sys.stderr)
        return 2
    offenders: List[str] = []
    n_files = 0
    for path in paths:
        for py in _py_files(path):
            n_files += 1
            offenders.extend(missing_docstrings(py))
    for line in offenders:
        print(f"[docstrings] MISSING {line}", file=sys.stderr)
    if offenders:
        print(f"[docstrings] FAIL: {len(offenders)} public definition(s) "
              f"without docstrings in {n_files} file(s)", file=sys.stderr)
        return 1
    print(f"[docstrings] OK: {n_files} file(s), all public definitions "
          f"documented")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
