"""Execute the fenced ``python`` code blocks of markdown docs.

  PYTHONPATH=src python tools/run_doc_blocks.py README.md docs/API.md

Keeps the documented API honest: CI runs every ```python block, so a doc
example that drifts from the real surface fails the build instead of
misleading the next reader.

Conventions:

  * Blocks in one file share a namespace and run top to bottom — later
    blocks may use names defined by earlier ones (like a reader following
    the doc).
  * A block fenced as ```python no-exec is rendered like any other python
    block by GitHub but skipped here — for deliberately illustrative
    fragments (signatures, elided loops).
  * A file contributing zero executed blocks is an error: a doc this tool
    is pointed at is *supposed* to be executable.
"""

from __future__ import annotations

import re
import sys
import traceback

FENCE_OPEN = re.compile(r"^```(\S+)?\s*(.*)$")


def python_blocks(path: str):
    """Yield (start_line, source) for each executable python block."""
    lines = open(path, encoding="utf-8").read().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_OPEN.match(lines[i])
        if not (m and m.group(1)):
            i += 1
            continue
        lang, info, start = m.group(1), m.group(2) or "", i + 1
        body = []
        i += 1
        while i < len(lines) and not lines[i].startswith("```"):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        if lang == "python" and "no-exec" not in info:
            yield start + 1, "\n".join(body)


def run_file(path: str) -> int:
    """Execute all blocks of one doc in a shared namespace; returns the
    number of blocks executed.  Raises on the first failing block."""
    namespace = {"__name__": f"doc:{path}"}
    n = 0
    for line, src in python_blocks(path):
        print(f"[doc-exec] {path}:{line} ({len(src.splitlines())} lines)",
              flush=True)
        code = compile("\n" * (line - 1) + src, path, "exec")
        exec(code, namespace)
        n += 1
    return n


def main(paths) -> int:
    if not paths:
        print("usage: run_doc_blocks.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    status = 0
    for path in paths:
        try:
            n = run_file(path)
        except Exception:
            traceback.print_exc()
            print(f"[doc-exec] FAIL {path}", file=sys.stderr)
            status = 1
            continue
        if n == 0:
            print(f"[doc-exec] FAIL {path}: no executable ```python blocks "
                  f"found", file=sys.stderr)
            status = 1
        else:
            print(f"[doc-exec] OK {path}: {n} blocks")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
