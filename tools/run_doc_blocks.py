"""Execute the fenced ``python`` code blocks of markdown docs.

  PYTHONPATH=src python tools/run_doc_blocks.py README.md docs/API.md

Keeps the documented API honest: CI runs every ```python block, so a doc
example that drifts from the real surface fails the build instead of
misleading the next reader.

Conventions:

  * Blocks in one file share a namespace and run top to bottom — later
    blocks may use names defined by earlier ones (like a reader following
    the doc).
  * A block fenced as ```python no-exec is rendered like any other python
    block by GitHub but skipped here — for deliberately illustrative
    fragments (signatures, elided loops).
  * A file contributing zero executed blocks is an error: a doc this tool
    is pointed at is *supposed* to be executable.
"""

from __future__ import annotations

import os
import re
import sys
import traceback

FENCE_OPEN = re.compile(r"^```(\S+)?\s*(.*)$")


class DocBlockError(Exception):
    """A block failed: carries (path, block index, start line) so the
    failure names exactly which fence to look at."""

    def __init__(self, path: str, index: int, line: int):
        super().__init__(f"{path}: block {index} (starting at line {line}) "
                         f"raised")
        self.path, self.index, self.line = path, index, line


def python_blocks(path: str):
    """Yield (start_line, source) for each executable python block."""
    lines = open(path, encoding="utf-8").read().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_OPEN.match(lines[i])
        if not (m and m.group(1)):
            i += 1
            continue
        lang, info, start = m.group(1), m.group(2) or "", i + 1
        body = []
        i += 1
        while i < len(lines) and not lines[i].startswith("```"):
            body.append(lines[i])
            i += 1
        i += 1  # closing fence
        if lang == "python" and "no-exec" not in info:
            yield start + 1, "\n".join(body)


def run_file(path: str) -> int:
    """Execute all blocks of one doc in a shared namespace; returns the
    number of blocks executed.  Raises :class:`DocBlockError` (chaining
    the real exception) on the first failing block."""
    namespace = {"__name__": f"doc:{path}"}
    n = 0
    for i, (line, src) in enumerate(python_blocks(path)):
        print(f"[doc-exec] {path}:{line} block {i} "
              f"({len(src.splitlines())} lines)", flush=True)
        try:
            # compile() inside the try: a SyntaxError in a block must name
            # its fence like any other failure, not escape uncaught.
            code = compile("\n" * (line - 1) + src, path, "exec")
            exec(code, namespace)
        except Exception as e:
            raise DocBlockError(path, i, line) from e
        n += 1
    return n


def main(paths) -> int:
    """Run every doc; 0 iff each exists and all its blocks execute."""
    if not paths:
        print("usage: run_doc_blocks.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    status = 0
    for path in paths:
        if not os.path.isfile(path):
            # A doc this tool is pointed at that is not on disk is a CI
            # configuration bug (deleted/renamed without updating the
            # invocation) — name it instead of dumping an open() traceback.
            print(f"[doc-exec] FAIL {path}: doc file does not exist "
                  f"(block 0 never ran) — deleted or renamed without "
                  f"updating the caller?", file=sys.stderr)
            status = 1
            continue
        try:
            n = run_file(path)
        except DocBlockError as e:
            traceback.print_exc()
            print(f"[doc-exec] FAIL {e.path}: block {e.index} "
                  f"(starting at line {e.line})", file=sys.stderr)
            status = 1
            continue
        if n == 0:
            print(f"[doc-exec] FAIL {path}: no executable ```python blocks "
                  f"found", file=sys.stderr)
            status = 1
        else:
            print(f"[doc-exec] OK {path}: {n} blocks")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
