"""Per-kernel validation: sweep shapes/dtypes, assert exact equality against
the pure-jnp oracles in kernels/ref.py (interpret=True executes the kernel
body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.accelerator import AcceleratorConfig
from repro.core.fixed_point import FXP_4_8, FXP_8_16, FixedPointConfig
from repro.core.qlstm import ActivationConfig, QLSTMConfig
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand_lstm(T, B, M, H, cfg):
    lo, hi = cfg.int_min, cfg.int_max + 1
    x = RNG.integers(lo, hi, (T, B, M)).astype(np.int8 if cfg.total_bits <= 8
                                               else np.int16)
    wx = RNG.integers(lo // 4, hi // 4, (M, 4 * H)).astype(x.dtype)
    wh = RNG.integers(lo // 8, hi // 8, (H, 4 * H)).astype(x.dtype)
    b = RNG.integers(-200, 200, (4 * H,)).astype(np.int32)
    return map(jnp.asarray, (x, wx, wh, b))


@pytest.mark.parametrize("T,B,M,H", [(3, 2, 1, 4), (7, 13, 3, 20),
                                     (6, 128, 1, 20), (2, 5, 10, 60),
                                     (12, 1, 2, 8)])
def test_qlstm_kernel_shapes(T, B, M, H):
    cfg = FXP_4_8
    x, wx, wh, b = _rand_lstm(T, B, M, H, cfg)
    want = ref.qlstm_seq_ref(x, wx, wh, b, cfg)
    model = QLSTMConfig(input_size=M, hidden_size=H, seq_len=T)
    got = ops.qlstm_seq(x, wx, wh, b, model)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("unit", ["mxu", "vpu"])
@pytest.mark.parametrize("method", ["arithmetic", "step"])
def test_qlstm_kernel_units_and_methods(unit, method):
    cfg = FXP_4_8
    x, wx, wh, b = _rand_lstm(6, 9, 2, 16, cfg)
    want = ref.qlstm_seq_ref(x, wx, wh, b, cfg)
    model = QLSTMConfig(input_size=2, hidden_size=16, seq_len=6,
                        acts=ActivationConfig(hs_method=method))
    got = ops.qlstm_seq(x, wx, wh, b, model,
                        AcceleratorConfig(compute_unit=unit, hs_method=method))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("cfg", [FXP_4_8, FixedPointConfig(6, 8), FXP_8_16])
@pytest.mark.parametrize("method", ["arithmetic", "step"])
def test_qlstm_kernel_stateful_resume_bit_exact(cfg, method):
    """Windowed execution with the carried (h, c) fed back into the kernel
    equals the one-shot run — outputs AND final state, across fxp widths
    and HardSigmoid* methods."""
    from repro.kernels.qlstm_cell import qlstm_seq_pallas
    x, wx, wh, b = _rand_lstm(9, 5, 2, 12, cfg)
    want, (h_w, c_w) = ref.qlstm_seq_ref(x, wx, wh, b, cfg,
                                         return_state=True)
    outs, state = [], (None, None)
    for w in range(3):                       # three windows of T=3
        o, state = qlstm_seq_pallas(x[3 * w:3 * (w + 1)], wx, wh, b,
                                    cfg=cfg, hs_method=method,
                                    h0=state[0], c0=state[1],
                                    return_state=True)
        outs.append(np.asarray(o))
    np.testing.assert_array_equal(np.concatenate(outs), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(state[0]), np.asarray(h_w))
    np.testing.assert_array_equal(np.asarray(state[1]), np.asarray(c_w))


@pytest.mark.parametrize("unit", ["mxu", "vpu"])
@pytest.mark.parametrize("num_layers", [1, 2, 3])
def test_qlstm_multilayer_kernel_vs_layered_ref(num_layers, unit):
    """The fused multi-layer entry — all layers in ONE pallas_call, state
    resident in VMEM — is bit-exact with threading the oracle through the
    stack layer by layer, including the per-layer final state and a
    non-zero initial carry."""
    from repro.kernels.qlstm_cell import qlstm_seq_multilayer_pallas
    cfg = FXP_4_8
    T, B, M, H = 5, 5, 2, 12
    x, wx0, wh0, b0 = _rand_lstm(T, B, M, H, cfg)
    wxs, whs, bs = [wx0], [wh0], [b0]
    for _ in range(num_layers - 1):
        _, wxd, whd, bd = _rand_lstm(T, B, H, H, cfg)
        wxs.append(wxd), whs.append(whd), bs.append(bd)
    h0s = tuple(jnp.asarray(RNG.integers(-100, 100, (B, H)), jnp.int32)
                for _ in range(num_layers))
    c0s = tuple(jnp.asarray(RNG.integers(-100, 100, (B, H)), jnp.int32)
                for _ in range(num_layers))
    got, state = qlstm_seq_multilayer_pallas(
        x, tuple(wxs), tuple(whs), tuple(bs), h0s, c0s, cfg=cfg,
        compute_unit=unit, batch_block=2)        # batch 5 -> padded to 6
    h_t = x
    for li in range(num_layers):
        h_t, (h_l, c_l) = ref.qlstm_seq_ref(
            h_t.astype(x.dtype), wxs[li], whs[li], bs[li], cfg,
            h0=h0s[li], c0=c0s[li], return_state=True)
        np.testing.assert_array_equal(np.asarray(state[li][0]),
                                      np.asarray(h_l))
        np.testing.assert_array_equal(np.asarray(state[li][1]),
                                      np.asarray(c_l))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(h_t))


def test_qlstm_multilayer_kernel_rejects_mismatched_tuples():
    """Per-layer tuples that disagree on the layer count fail loudly."""
    from repro.kernels.qlstm_cell import qlstm_seq_multilayer_pallas
    cfg = FXP_4_8
    x, wx, wh, b = _rand_lstm(3, 2, 1, 4, cfg)
    z = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(ValueError, match="layer count"):
        qlstm_seq_multilayer_pallas(x, (wx,), (wh, wh), (b,), (z,), (z,),
                                    cfg=cfg)


def _slot_battery_weights(num_layers, cfg, T, B, M, H):
    """Per-layer random weights for the slot-kernel battery."""
    x, wx0, wh0, b0 = _rand_lstm(T, B, M, H, cfg)
    wxs, whs, bs = [wx0], [wh0], [b0]
    for _ in range(num_layers - 1):
        _, wxd, whd, bd = _rand_lstm(T, B, H, H, cfg)
        wxs.append(wxd), whs.append(whd), bs.append(bd)
    return x, tuple(wxs), tuple(whs), tuple(bs)


@pytest.mark.parametrize("cfg", [FXP_4_8, FixedPointConfig(6, 8), FXP_8_16])
@pytest.mark.parametrize("method", ["arithmetic", "step"])
@pytest.mark.parametrize("num_layers", [1, 2, 3])
def test_qlstm_slot_kernel_matches_gathered_multilayer(cfg, method,
                                                       num_layers):
    """The slot-battery acceptance sweep: the in-kernel gather/scatter
    entry, over random slot PERMUTATIONS of a pre-filled state table, is
    bit-exact with the multilayer kernel handed the same carries as
    explicit (h, c) arrays — outputs AND the updated table — across fxp
    widths, HardSigmoid methods, and 1-3 layers.  Also pins the table
    conventions: ZERO-row gathers start the recurrence from the reset
    carry, TRASH-row scatters drop a row's final state, and table rows
    the wave never scattered to are byte-identical before/after."""
    from repro.kernels.qlstm_cell import (qlstm_seq_multilayer_pallas,
                                          qlstm_seq_slot_pallas)
    T, B, M, H = 4, 5, 2, 8
    n_data = 6                      # slots 0..5; ZERO = 6, TRASH = 7
    zero_slot, trash_slot = n_data, n_data + 1
    x, wxs, whs, bs = _slot_battery_weights(num_layers, cfg, T, B, M, H)
    rng = np.random.default_rng(7 * num_layers + len(method))
    for trial in range(3):
        table = rng.integers(-100, 100,
                             (n_data + 2, num_layers, 2, H)).astype(np.int32)
        table[zero_slot] = 0
        gather = rng.permutation(n_data)[:B].astype(np.int32)
        scatter = rng.permutation(n_data)[:B].astype(np.int32)
        gather[0] = zero_slot       # a fresh/evicted stream's row
        scatter[1] = trash_slot     # a padding/tombstoned row
        got, new_table = qlstm_seq_slot_pallas(
            x, jnp.asarray(gather), jnp.asarray(scatter), jnp.asarray(table),
            wxs, whs, bs, cfg=cfg, hs_method=method)
        # Oracle: gather the same carries host-side, run the plain
        # multilayer kernel, scatter host-side.
        h0s = tuple(jnp.asarray(table[gather, li, 0])
                    for li in range(num_layers))
        c0s = tuple(jnp.asarray(table[gather, li, 1])
                    for li in range(num_layers))
        want, state = qlstm_seq_multilayer_pallas(
            x, wxs, whs, bs, h0s, c0s, cfg=cfg, hs_method=method)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        expect = table.copy()
        for i in range(B):
            if scatter[i] == trash_slot:
                continue
            for li in range(num_layers):
                expect[scatter[i], li, 0] = np.asarray(state[li][0][i])
                expect[scatter[i], li, 1] = np.asarray(state[li][1][i])
        expect[trash_slot] = np.asarray(new_table)[trash_slot]  # don't-care
        np.testing.assert_array_equal(np.asarray(new_table), expect)
        # The ZERO row survives every wave unwritten.
        assert not np.asarray(new_table)[zero_slot].any()


def test_qlstm_slot_kernel_validates_inputs():
    """Layer-count mismatches and undersized tables fail loudly."""
    from repro.kernels.qlstm_cell import qlstm_seq_slot_pallas
    cfg = FXP_4_8
    x, wx, wh, b = _rand_lstm(3, 2, 1, 4, cfg)
    slots = jnp.zeros((2,), jnp.int32)
    table = jnp.zeros((5, 1, 2, 4), jnp.int32)
    with pytest.raises(ValueError, match="layer count"):
        qlstm_seq_slot_pallas(x, slots, slots, table, (wx, wx), (wh,), (b,),
                              cfg=cfg)
    with pytest.raises(ValueError, match="table"):
        qlstm_seq_slot_pallas(x, slots, slots, jnp.zeros((2, 1, 2, 4),
                                                         jnp.int32),
                              (wx,), (wh,), (b,), cfg=cfg)


def test_qlstm_kernel_int16_datapath():
    """(8,16) — the baseline [15] width — through the same kernel."""
    cfg = FXP_8_16
    x, wx, wh, b = _rand_lstm(4, 3, 1, 8, cfg)
    want = ref.qlstm_seq_ref(x, wx, wh, b, cfg)
    model = QLSTMConfig(input_size=1, hidden_size=8, seq_len=4, fxp=cfg)
    got = ops.qlstm_seq(x, wx, wh, b, model)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5),
       st.integers(0, 2))
@settings(max_examples=25, deadline=None)
def test_quant_matmul_property(mi, ki, ni, blocki):
    m, k, n = mi * 13, ki * 17, ni * 11
    block = [(16, 16, 16), (32, 16, 8), (128, 128, 128)][blocki]
    x = RNG.integers(-128, 128, (m, k)).astype(np.int8)
    w = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    got = ops.quant_matmul(jnp.asarray(x), jnp.asarray(w), block=block)
    np.testing.assert_array_equal(
        np.asarray(got), x.astype(np.int32) @ w.astype(np.int32))


def test_quant_matmul_requant_fused():
    cfg = FXP_4_8
    x = RNG.integers(-128, 128, (50, 70)).astype(np.int8)
    w = RNG.integers(-128, 128, (70, 90)).astype(np.int8)
    got = ops.quant_matmul_requant(jnp.asarray(x), jnp.asarray(w), cfg,
                                   block=(32, 32, 32))
    want = ref.quant_matmul_requant_ref(jnp.asarray(x), jnp.asarray(w), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int8


@pytest.mark.parametrize("cfg", [FXP_4_8, FixedPointConfig(6, 8),
                                 FixedPointConfig(8, 10), FXP_8_16])
@pytest.mark.parametrize("method", ["arithmetic", "1to1", "step"])
def test_hard_act_kernel_all_configs(cfg, method):
    xs = jnp.arange(cfg.int_min, cfg.int_max + 1).reshape(-1, 16) \
        .astype(cfg.storage_dtype)
    got = ops.hard_sigmoid_star_int(xs, cfg, method=method)
    want = ref.hard_act_ref(xs, cfg, method)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hard_tanh_kernel():
    cfg = FXP_4_8
    xs = jnp.arange(-128, 128).reshape(16, 16).astype(jnp.int8)
    got = ops.hard_tanh_int(xs, cfg)
    want = ref.hard_tanh_ref(xs, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash_attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,s,hd,causal,window", [
    (64, 64, 32, True, None),
    (64, 64, 32, False, None),
    (96, 96, 16, True, 24),       # SWA
    (40, 72, 32, False, None),    # padded, cross-attention shapes
    (128, 128, 64, True, None),
])
def test_flash_attention_vs_ref(t, s, hd, causal, window):
    if causal and t != s:
        pytest.skip("causal requires t == s here")
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (3, t, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (3, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (3, s, hd)).astype(np.float32))
    from repro.kernels.flash_attention import flash_attention_pallas
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mha_flash_gqa_matches_model_attention():
    """The Pallas kernel agrees with the model's chunked-jnp attention
    (layers.flash_attention) — kernel and pure-JAX paths are interchangeable."""
    from repro.models.layers import flash_attention as jnp_attn
    rng = np.random.default_rng(8)
    b, t, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (b, t, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, t, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, t, kv, hd)).astype(np.float32))
    got = ops.mha_flash(q, k, v, causal=True, scale=hd ** -0.5,
                        block_q=16, block_k=16)
    want = jnp_attn(q, k, v, causal=True, scale=hd ** -0.5,
                    q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rglru_scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,bsz,w", [(5, 3, 8), (16, 7, 32), (9, 128, 16)])
def test_rglru_kernel_vs_ref(t, bsz, w):
    from repro.kernels.rglru_scan import rglru_seq_pallas
    rng = np.random.default_rng(11)
    log_a = jnp.asarray(-np.abs(rng.normal(0, 1, (t, bsz, w))).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (t, bsz, w)).astype(np.float32))
    got = rglru_seq_pallas(log_a, b, batch_block=4)
    want = ref.rglru_seq_ref(log_a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_rglru_kernel_matches_model_recurrence():
    """The fused kernel computes exactly the model's RG-LRU recurrence given
    the model's own decays/inputs."""
    from repro.kernels.rglru_scan import rglru_seq_pallas
    from repro.models import rglru as RG
    from repro.configs import ARCH_CONFIGS, reduce_config
    from repro.models import transformer as TT
    cfg = reduce_config(ARCH_CONFIGS["recurrentgemma-2b"])
    p_full, _ = TT.init_model(cfg, jax.random.key(0))
    p = jax.tree.map(lambda x: x[0], p_full["groups"][0]["mixer"])
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.normal(0, 1, (3, 7, cfg.recurrent.lru_width))
                    .astype(np.float32))
    want = RG.rglru_scan(p, x, cfg)
    a, mult, i = RG._decay(p, x, cfg)
    log_a = jnp.log(jnp.maximum(a, 1e-30))
    b = mult * (i * x)
    got = rglru_seq_pallas(jnp.swapaxes(log_a, 0, 1), jnp.swapaxes(b, 0, 1))
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(got, 0, 1)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
