"""The streaming serving subsystem (`repro.serving`).

The load-bearing guarantee is STATEFUL CARRY: feeding a stream
window-by-window through the server, with its (h, c) carried in the
StateStore between windows, is bit-identical on the int path to running
the stream's concatenated sequence through the accelerator in one call.
Plus: deadline-bounded partial waves, LRU eviction semantics, padding
drop, and compat-wrapper parity for ``Accelerator.serve`` /
``WaveBatcher.for_accelerator``."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import backends
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig, init_int_state
from repro.serving import (ServingConfig, StateStore, StreamServer,
                           serve_windows)

MODEL = QLSTMConfig(input_size=1, hidden_size=8, num_layers=2, seq_len=4)


@pytest.fixture(scope="module")
def sess():
    return repro.build(MODEL, seed=0).quantize()


def _windows(n, seed=0, t=4, m=1):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (n, t, m)).astype(np.float32)


# ---------------------------------------------------------------------------
# Stateful carry — the bit-exactness contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_stateful_carry_equals_unbatched_sequence(sess, backend):
    """k windows through run_stateful == one forward over the k*T sequence,
    bit-exact at the integer-code level, multi-layer."""
    from repro.core import fixed_point as fxp
    k = 3
    x = _windows(1, seed=1, t=MODEL.seq_len * k)
    x_int = fxp.quantize(jnp.asarray(x), sess.model.fxp)
    bk = backends.get(backend)
    y_full = bk.run(sess.qparams, x_int, sess.model, sess.accel)

    state = init_int_state(sess.model, 1)
    t = MODEL.seq_len
    for w in range(k):
        y, state = bk.run_stateful(sess.qparams, x_int[:, w * t:(w + 1) * t],
                                   sess.model, sess.accel, state)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_full))


def test_stateful_rejects_mismatched_state_length(sess):
    """A carry built for a different num_layers must fail loudly at the
    boundary — zip() truncation would silently skip whole layers."""
    from repro.core import fixed_point as fxp
    x_int = fxp.quantize(jnp.asarray(_windows(1, seed=2)), sess.model.fxp)
    wrong = init_int_state(MODEL, 1)[:1]          # 1 layer, model has 2
    with pytest.raises(ValueError, match="layer"):
        backends.get("ref").run_stateful(sess.qparams, x_int, sess.model,
                                         sess.accel, wrong)
    from repro.core.qlstm import forward_int_stateful
    with pytest.raises(ValueError, match="layer"):
        forward_int_stateful(sess.qparams, x_int, sess.model, wrong)


def test_stream_server_carry_equals_unbatched_sequence(sess):
    """The same guarantee end-to-end through StreamServer: interleaved
    multiplexed streams each match their own one-shot concatenated run."""
    k, t = 3, MODEL.seq_len
    streams = {f"c{i}": _windows(k, seed=10 + i) for i in range(5)}
    with StreamServer(sess, batch=4, deadline_s=0.005, max_streams=16) as srv:
        for w in range(k):
            for sid, xs in streams.items():
                srv.submit(sid, xs[w])
        results = srv.drain()
    by = {}
    for r in results:
        by.setdefault(r.stream_id, {})[r.seq] = r.y
    for sid, xs in streams.items():
        assert sorted(by[sid]) == list(range(k))  # per-stream order complete
        full = np.asarray(sess.infer(
            jnp.asarray(xs.reshape(1, k * t, 1)), path="int"))
        np.testing.assert_array_equal(by[sid][k - 1], full[0])
        # every intermediate window matches its prefix too
        for w in range(k - 1):
            prefix = np.asarray(sess.infer(
                jnp.asarray(xs[:w + 1].reshape(1, (w + 1) * t, 1)),
                path="int"))
            np.testing.assert_array_equal(by[sid][w], prefix[0])


def test_end_stream_resets_carry(sess):
    """After end_stream, the same id restarts from the zero reset state
    (and its sequence numbering restarts at 0)."""
    x = _windows(2, seed=3)
    fresh = np.asarray(sess.infer(jnp.asarray(x[1:2]), path="int"))
    with StreamServer(sess, batch=2, deadline_s=0.005) as srv:
        assert srv.submit("s", x[0]) == 0
        srv.flush()
        srv.end_stream("s")
        assert srv.submit("s", x[1]) == 0   # fresh stream, fresh numbering
        results = srv.drain()
    np.testing.assert_array_equal(results[-1].y, fresh[0])


def test_end_stream_stateless_restarts_numbering(sess):
    """On a stateless server, end_stream still forgets the stream: its
    ``_seq`` entry is pruned (the only bound on rotating client ids in
    this mode) and numbering restarts at 0."""
    x = _windows(2, seed=17)
    with StreamServer(sess, batch=2, stateful=False,
                      deadline_s=0.005) as srv:
        assert srv.submit("s", x[0]) == 0
        srv.drain()
        srv.end_stream("s")
        assert "s" not in srv._seq
        assert srv.submit("s", x[1]) == 0   # fresh numbering
        srv.drain()


def test_end_stream_during_scatter_is_not_undone(sess):
    """end_stream racing the compute thread's scatter of the same stream's
    in-flight carry: the scatter's tombstone-check + put and end_stream's
    pop are serialised under one lock, so the ended carry can never be
    re-stored afterwards (the TOCTOU this pins down resurrected it).
    Host residency pinned: the race is host-scatter-specific (the device
    path runs its whole allocator transaction before compute, under the
    same lock end_stream takes, so there is no post-compute put to
    race)."""
    import threading
    x = _windows(1, seed=18)
    with StreamServer(sess, batch=2, deadline_s=0.01,
                      state_residency="host") as srv:
        orig_put = srv.states.put
        in_put, release = threading.Event(), threading.Event()

        def slow_put(sid, state):
            in_put.set()               # compute thread is inside _scatter
            release.wait(5.0)
            return orig_put(sid, state)

        srv.states.put = slow_put

        def ender():
            in_put.wait(10.0)
            srv.end_stream("s")        # blocks on the lock until put ends

        t = threading.Thread(target=ender)
        t.start()
        srv.submit("s", x[0])
        in_put.wait(10.0)
        time.sleep(0.05)               # let ender block on _seq_lock
        release.set()
        t.join(10.0)
        srv.flush(timeout=30)
        assert "s" not in srv.states   # the ended carry stayed dead


def test_end_stream_with_window_in_flight(sess):
    """end_stream issued while the stream's window is still pending: the
    in-flight window's carry must NOT be re-stored behind the reset, so
    the next window still starts from zero."""
    x = _windows(2, seed=13)
    fresh = np.asarray(sess.infer(jnp.asarray(x[1:2]), path="int"))
    with StreamServer(sess, batch=2, deadline_s=0.05) as srv:
        srv.submit("s", x[0])
        srv.end_stream("s")        # no flush: window 0 may still be queued
        srv.submit("s", x[1])
        results = srv.drain()
    np.testing.assert_array_equal(results[-1].y, fresh[0])
    assert len(srv.states) <= 1    # no resurrected carry for generation 0


# ---------------------------------------------------------------------------
# Deadline flush / padding semantics
# ---------------------------------------------------------------------------

def test_deadline_flushes_partial_wave(sess):
    """A slow stream is not stuck behind a full-wave quorum: with 3 windows
    pending against batch=8, the deadline flushes a padded partial wave and
    exactly 3 predictions come back (padding dropped)."""
    x = _windows(3, seed=4)
    srv = StreamServer(sess, ServingConfig(batch=8, stateful=False,
                                           deadline_s=0.05))
    try:
        for i in range(3):
            srv.submit(None, x[i])
        results = []
        end = time.perf_counter() + 30.0
        while len(results) < 3 and time.perf_counter() < end:
            results.extend(srv.poll(timeout=1.0))
        assert len(results) == 3
        m = srv.metrics_summary()
        assert m["deadline_flushes"] >= 1
        assert m["samples"] == 3 and m["padded_slots"] >= 5
        want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
        got = np.stack([r.y for r in sorted(results, key=lambda r: r.seq)])
        np.testing.assert_array_equal(got, want)
    finally:
        srv.close(abandon=True)


def test_serve_final_partial_wave_pads_and_drops(sess):
    """Accelerator.serve documented padding semantics: 11 windows at
    batch=4 -> exactly 11 predictions, bit-equal to batched infer; the
    padded slots of the final wave are never yielded."""
    x = _windows(11, seed=5)
    preds = list(sess.serve(iter(x), batch=4))
    assert len(preds) == 11          # never the padding's outputs
    want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
    np.testing.assert_array_equal(np.stack(preds), want)


# ---------------------------------------------------------------------------
# StateStore LRU
# ---------------------------------------------------------------------------

def test_state_store_lru_eviction_order():
    store = StateStore(capacity=2)
    st = [(np.ones(4, np.int32), np.ones(4, np.int32))]
    store.put("a", st)
    store.put("b", st)
    assert store.get("a") is not None    # refresh: b is now LRU
    store.put("c", st)                   # evicts b
    assert "b" not in store and "a" in store and "c" in store
    stats = store.stats()
    assert stats["evictions"] == 1 and stats["live_streams"] == 2
    assert store.get("b") is None        # miss counted
    assert store.stats()["misses"] == 1


def test_eviction_resets_stream_to_zero_state(sess):
    """An evicted stream's next window behaves like a brand new stream:
    its prediction equals the zero-carry (fresh) prediction, not the
    continued-sequence one."""
    xs = {sid: _windows(2, seed=20 + i)
          for i, sid in enumerate(["s1", "s2", "s3"])}
    with StreamServer(sess, batch=4, deadline_s=0.005,
                      max_streams=2) as srv:
        for sid in ("s1", "s2", "s3"):       # 3 carries into capacity 2:
            srv.submit(sid, xs[sid][0])      # s1 is evicted at scatter
        srv.flush()
        assert srv.states.stats()["evictions"] == 1
        assert "s1" not in srv.states
        # eviction forgets s1 entirely: carry AND numbering restart
        assert srv.submit("s1", xs["s1"][1]) == 0
        assert srv.submit("s2", xs["s2"][1]) == 1
        results = srv.drain()
    # results arrive in wave order, so the reborn ("s1", 0) overwrites the
    # first-generation row of the same key
    by = {(r.stream_id, r.seq): r.y for r in results}
    # s1 restarted from zeros -> equals the fresh single-window run
    fresh = np.asarray(sess.infer(jnp.asarray(xs["s1"][1:2]), path="int"))
    np.testing.assert_array_equal(by[("s1", 0)], fresh[0])
    # s2 kept its carry -> equals the concatenated two-window run
    cont = np.asarray(sess.infer(
        jnp.asarray(xs["s2"].reshape(1, 2 * MODEL.seq_len, 1)), path="int"))
    np.testing.assert_array_equal(by[("s2", 1)], cont[0])


def test_eviction_with_window_in_flight_keeps_numbering(sess):
    """A victim with a window still in the pipeline keeps its sequence
    numbering (pruning it would hand out duplicate (stream_id, seq) keys
    for the undelivered in-flight results); a victim that stays evicted
    with nothing in flight is forgotten entirely."""
    xs = {sid: _windows(2, seed=40 + i) for i, sid in enumerate("ab")}
    with StreamServer(sess, batch=2, deadline_s=None,
                      max_streams=1) as srv:
        # waves assemble oldest-first, one per stream: {a0,b0} then {a1,b1}
        assert srv.submit("a", xs["a"][0]) == 0
        assert srv.submit("b", xs["b"][0]) == 0
        assert srv.submit("a", xs["a"][1]) == 1   # numbering survives the
        assert srv.submit("b", xs["b"][1]) == 1   # wave-1 eviction of "a"
        srv.drain(timeout=30)
        # store capacity 1: wave 2 leaves only "b" live; "a" has nothing
        # in flight any more, so it is forgotten entirely
        assert srv.submit("a", xs["a"][0]) == 0   # fresh stream
        assert srv.submit("b", xs["b"][0]) == 2   # continued stream
        srv.drain(timeout=30)


@pytest.mark.slow
def test_max_results_backpressure_and_abandon(sess):
    """max_results bounds computed-but-unpolled results: with a concurrent
    poller every prediction still arrives; with a stalled consumer,
    close(abandon=True) must not hang on the full results queue."""
    import threading
    x = _windows(12, seed=41)
    got = []
    with StreamServer(sess, batch=2, stateful=False, deadline_s=0.005,
                      max_results=2) as srv:
        stop = threading.Event()

        def consume():
            while not stop.is_set() or len(got) < 12:
                got.extend(srv.poll(timeout=0.05))
                if len(got) >= 12:
                    return

        t = threading.Thread(target=consume)
        t.start()
        for w in x:
            srv.submit(None, w)
        srv.flush(timeout=60)
        stop.set()
        t.join(30)
    assert len(got) == 12
    want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
    np.testing.assert_array_equal(
        np.stack([r.y for r in sorted(got, key=lambda r: r.seq)]), want)
    # stalled consumer: results queue fills; abandon must still return
    srv2 = StreamServer(sess, batch=2, stateful=False, deadline_s=0.005,
                        max_results=1)
    for w in x[:6]:
        srv2.submit(None, w)
    time.sleep(0.5)                    # let the pipeline wedge on results
    srv2.close(abandon=True)           # must not hang


def test_same_wave_eviction_keeps_restored_stream_consistent(sess):
    """More distinct streams per wave than max_streams: a stream evicted
    by an earlier slot's put but re-stored by its own later slot of the
    SAME wave was never really forgotten — it must keep both its carry
    and its sequence numbering (carry-without-numbering would report a
    continued stream as seq 0)."""
    xs = {sid: _windows(2, seed=30 + i)
          for i, sid in enumerate(["a", "b", "c"])}
    with StreamServer(sess, batch=4, deadline_s=0.005,
                      max_streams=2) as srv:
        for w in range(2):
            for sid in ("a", "b", "c"):
                srv.submit(sid, xs[sid][w])
            srv.flush(timeout=30)
        # every live carry still has its numbering (forgotten means BOTH)
        live = {sid for sid in ("a", "b", "c") if sid in srv.states}
        assert all(sid in srv._seq for sid in live), (live, dict(srv._seq))
        # a surviving stream continues: next window is seq 2, and its
        # prediction equals the three-window concatenated run
        survivor = sorted(live)[-1]
        assert srv.submit(survivor, xs[survivor][0]) == 2
        results = srv.drain()
    cont = np.asarray(sess.infer(jnp.asarray(np.concatenate(
        [xs[survivor][0], xs[survivor][1], xs[survivor][0]])[None]),
        path="int"))
    last = [r for r in results if r.stream_id == survivor and r.seq == 2][0]
    np.testing.assert_array_equal(last.y, cont[0])


# ---------------------------------------------------------------------------
# Compat wrappers / selection
# ---------------------------------------------------------------------------

def test_serve_compat_parity_with_serve_windows(sess):
    """Accelerator.serve is a thin wrapper over serving.serve_windows."""
    x = _windows(9, seed=6)
    a = np.stack(list(sess.serve(iter(x), batch=4)))
    b = np.stack(list(serve_windows(sess, iter(x), batch=4)))
    np.testing.assert_array_equal(a, b)


def test_wave_batcher_delegates_to_serving(sess):
    from repro.launch.batcher import WaveBatcher
    x = _windows(7, seed=7)
    b = WaveBatcher.for_accelerator(sess, batch_size=4)
    rids = [b.submit_window(w) for w in x]
    out = b.run()
    want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
    np.testing.assert_array_equal(np.stack([out[r] for r in rids]), want)


def test_stateful_requires_int_path():
    with pytest.raises(ValueError, match="stateful"):
        ServingConfig(path="float", stateful=True)


def test_stateful_backend_selection(sess):
    """Plan metadata: the stateful resolution now follows the stateless
    one — fused configs carry state on the fused pallas kernel itself
    (its VMEM scratch is seeded from the carry), per-step configs on
    xla; every engine is stateful-capable."""
    assert sess.plan["stateful_backend"] == "pallas"
    assert sess.plan["stateful_backend"] == sess.plan["backend"]
    assert set(sess.report()["stateful_backends"]) == {"ref", "pallas", "xla"}
    sess.compiled_stateful("pallas")    # explicit request resolves too
    per_step = repro.build(MODEL,
                           AcceleratorConfig(alu_mode="per_step")).quantize()
    assert per_step.plan["stateful_backend"] == "xla"
    assert per_step.report()["stateful_backends"] == ("xla",)
    # per-step cannot run the fused kernel, stateful or not
    with pytest.raises(backends.BackendUnsupported, match="alu_mode"):
        per_step.compiled_stateful("pallas")
    # a session PINNED to pallas carries state on pallas itself
    pinned = repro.build(MODEL, AcceleratorConfig(backend="pallas")).quantize()
    assert pinned.plan["stateful_backend"] == "pallas"
    pinned.compiled_stateful()          # resolves, no raise


@pytest.mark.parametrize("num_layers", [1, 2, 3])
def test_stream_server_carry_on_pallas_matches_concatenated(num_layers):
    """The serving hot path on the fused kernel: windowed streaming with
    the carry held by StreamServer, executed by the stateful pallas
    backend, is bit-identical to the one-shot concatenated run — per
    layer count."""
    model = QLSTMConfig(input_size=1, hidden_size=8, num_layers=num_layers,
                        seq_len=4)
    s = repro.build(model, seed=0).quantize()
    assert s.plan["stateful_backend"] == "pallas"
    k, t = 3, model.seq_len
    xs = _windows(k, seed=20 + num_layers)
    with StreamServer(s, batch=2, deadline_s=0.005,
                      backend="pallas") as srv:
        for w in range(k):
            srv.submit("s", xs[w])
        by = {r.seq: r.y for r in srv.drain()}
    full = np.asarray(s.infer(jnp.asarray(xs.reshape(1, k * t, 1)),
                              path="int", backend="ref"))
    np.testing.assert_array_equal(by[k - 1], full[0])


@pytest.mark.slow
def test_saturated_stateful_pipeline_does_not_deadlock(sess):
    """One stream, full-wave-only scheduling (deadline_s=None), tiny
    max_pending: a full wave can never assemble (one window per stream per
    wave), so saturation must flush partial waves instead of blocking
    submit forever."""
    x = _windows(6, seed=11)
    with StreamServer(sess, batch=4, deadline_s=None, max_pending=2) as srv:
        for w in x:                      # would deadlock without the
            srv.submit("lone", w)        # saturation flush
        results = srv.drain(timeout=60)
    assert len(results) == 6
    full = np.asarray(sess.infer(
        jnp.asarray(x.reshape(1, 6 * MODEL.seq_len, 1)), path="int"))
    last = [r for r in results if r.seq == 5][0]
    np.testing.assert_array_equal(last.y, full[0])


def test_unconsumed_serve_generator_leaks_no_threads(sess):
    """serve() allocates the server lazily: an abandoned, never-iterated
    generator must not leave scheduler threads behind."""
    import threading
    before = threading.active_count()
    for _ in range(3):
        sess.serve(iter(_windows(4)), batch=2)   # never iterated
    assert threading.active_count() == before


def test_serve_validates_at_call_site(sess):
    unquantised = repro.build(MODEL, seed=1)
    with pytest.raises(RuntimeError, match="quantize"):
        unquantised.serve(iter(_windows(2)), batch=2)
    with pytest.raises(ValueError, match="path"):
        sess.serve(iter(_windows(2)), batch=2, path="nope")


def test_window_shape_mismatch_rejected(sess):
    with StreamServer(sess, batch=2, stateful=False) as srv:
        srv.submit(None, _windows(1)[0])
        with pytest.raises(ValueError, match="shape"):
            srv.submit(None, np.zeros((5, 1), np.float32))


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_metrics_summary_shape(sess):
    x = _windows(10, seed=8)
    with StreamServer(sess, batch=4, deadline_s=0.005, max_streams=4) as srv:
        t0 = time.perf_counter()
        for i, w in enumerate(x):
            srv.submit(f"s{i % 2}", w)
        srv.flush()
        m = srv.metrics_summary()
    assert m["samples"] == 10 and m["waves"] >= 3
    assert m["samples_per_s"] > 0
    assert 0 < m["latency_ms"]["p50"] <= m["latency_ms"]["p99"]
    assert m["latency_ms"]["p99"] / 1e3 <= time.perf_counter() - t0 + 1.0
    assert m["gops_per_watt"] > 0 and m["ops_per_inference"] > 0
    assert m["state"]["live_streams"] == 2
    assert m["stateful"] is True and m["sessions"] == 1


def test_metrics_sink_is_bounded():
    """A long-lived server records one wave forever: the sink keeps only a
    rolling window of records for the percentile reductions, while the
    counts and samples/s stay lifetime-exact."""
    from repro.serving import MetricsSink, WaveRecord
    sink = MetricsSink(window=8)
    sink.note_submit(0.0)
    for i in range(100):
        sink.record_wave(WaveRecord(
            t_done=float(i + 1), compute_s=0.01,
            latency_s=0.001 * (i + 1), occupancy=3, batch=4,
            deadline_flush=(i % 10 == 0)))
    assert len(sink.waves) == 8                      # bounded retention
    m = sink.summary()
    assert m["waves"] == 100 and m["samples"] == 300  # lifetime counters
    assert m["deadline_flushes"] == 10 and m["padded_slots"] == 100
    assert m["samples_per_s"] == pytest.approx(3.0)   # lifetime wall rate
    # percentiles describe the window (latencies 93..100 ms), not history
    assert 92.0 < m["latency_ms"]["p50"] < 101.0


def test_multi_session_round_robin(sess):
    """Waves round-robin across replica sessions; results unchanged."""
    replica = repro.build(MODEL, params=sess.params, seed=0).quantize()
    x = _windows(8, seed=9)
    with StreamServer([sess, replica], batch=2, stateful=False) as srv:
        for w in x:
            srv.submit(None, w)
        results = srv.drain()
    want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
    got = np.stack([r.y for r in sorted(results, key=lambda r: r.seq)])
    np.testing.assert_array_equal(got, want)
    assert srv.metrics_summary()["sessions"] == 2


def test_multi_session_round_robin_is_wave_level(sess):
    """The round-robin assigns WAVES, not streams: with 2 sessions and
    batch=1, one stateful stream's consecutive windows execute on both
    sessions (``routed_replica`` = the session index) — correct only
    because the carry is host-side in the shared StateStore (the module-
    docstring caveat; ``ClusterServer`` is the pinned-routing answer)."""
    replica = repro.build(MODEL, params=sess.params, seed=0).quantize()
    k = 4
    xs = _windows(k, seed=23)
    with StreamServer([sess, replica], batch=1, deadline_s=0.005) as srv:
        for w in xs:
            srv.submit("one", w)
        results = srv.drain()
    assert {r.routed_replica for r in results} == {0, 1}
    # ...and the shared host-side carry keeps it bit-exact anyway.
    full = np.asarray(sess.infer(
        jnp.asarray(xs.reshape(1, k * MODEL.seq_len, 1)), path="int"))
    last = max(results, key=lambda r: r.seq)
    np.testing.assert_array_equal(last.y, full[0])


def test_non_replica_sessions_rejected(sess):
    """Same config but different weights is NOT a replica set: round-robin
    would silently interleave bit-incompatible models."""
    other = repro.build(MODEL, seed=42).quantize()
    with pytest.raises(ValueError, match="replicas"):
        StreamServer([sess, other], batch=2)


def test_invalid_scheduler_bounds_rejected(sess):
    with pytest.raises(ValueError, match="max_pending"):
        StreamServer(sess, batch=2, max_pending=0)
    with pytest.raises(ValueError, match="queue_depth"):
        StreamServer(sess, batch=2, queue_depth=0)


# ---------------------------------------------------------------------------
# Device-resident state: SlotAllocator properties + DeviceStateStore
# ---------------------------------------------------------------------------

from hypothesis_compat import given, settings, st  # noqa: E402
from repro.serving import DeviceStateStore, SlotAllocator  # noqa: E402

_DUMMY_STATE = [(np.zeros(4, np.int32), np.zeros(4, np.int32))]

_ops_strategy = st.lists(
    st.tuples(st.sampled_from(["lookup", "assign", "release"]),
              st.integers(0, 7)),
    max_size=120)


def _drive(alloc, ops, on_assign=None):
    """Replay an op sequence, checking the structural invariants after
    every step: live slots unique and in range, occupancy bounded by
    capacity, high-water bounded by peak occupancy."""
    peak = 0
    for op, sid in ops:
        if op == "lookup":
            alloc.lookup(sid)
        elif op == "assign":
            slot, evicted = alloc.assign(sid)
            assert 0 <= slot < alloc.capacity
            if on_assign is not None:
                on_assign(sid, slot, evicted)
        else:
            alloc.release(sid)
        live = alloc.live()
        peak = max(peak, len(live))
        assert len(live) <= alloc.capacity
        slots = list(live.values())
        assert len(slots) == len(set(slots))           # unique live slots
        assert all(0 <= s < alloc.capacity for s in slots)
        assert alloc.high_water <= peak or alloc.high_water <= len(live)
    assert alloc.high_water <= peak if ops else alloc.high_water == 0


@given(st.integers(1, 5), _ops_strategy)
@settings(max_examples=120, deadline=None)
def test_slot_allocator_live_slots_unique_property(capacity, ops):
    """PROPERTY: under any lookup/assign/release sequence, live streams
    hold pairwise-distinct in-range slots, occupancy never exceeds
    capacity, and the high-water mark never exceeds peak occupancy (slots
    are not burned by churn)."""
    _drive(SlotAllocator(capacity), ops)


@given(st.integers(1, 5), _ops_strategy)
@settings(max_examples=120, deadline=None)
def test_slot_allocator_freed_slots_reused_before_growth(capacity, ops):
    """PROPERTY: a fresh assignment always reuses the most recently freed
    slot (LIFO) when one exists; the high-water mark only grows when the
    free list is empty."""
    alloc = SlotAllocator(capacity)
    shadow_free = []                 # mirrors the LIFO free list
    for op, sid in ops:
        if op == "lookup":
            alloc.lookup(sid)
        elif op == "assign":
            fresh = sid not in alloc
            hw = alloc.high_water
            slot, evicted = alloc.assign(sid)
            if fresh:
                if shadow_free:
                    assert slot == shadow_free.pop()   # LIFO reuse first
                    assert alloc.high_water == hw
                elif not evicted:
                    assert slot == hw and alloc.high_water == hw + 1
                else:
                    assert alloc.high_water == hw      # victim's slot
            else:
                assert alloc.high_water == hw
        else:
            if sid in alloc:
                shadow_free.append(alloc.release(sid))
            else:
                assert alloc.release(sid) is None


@given(st.integers(1, 5), _ops_strategy)
@settings(max_examples=120, deadline=None)
def test_slot_allocator_lru_matches_statestore_oracle(capacity, ops):
    """PROPERTY: the allocator IS the StateStore's LRU policy with rows
    swapped for slot ids — identical op sequences produce identical live
    sets, identical eviction victims in identical order, and identical
    hit/miss/eviction counters."""
    alloc = SlotAllocator(capacity)
    store = StateStore(capacity)
    for op, sid in ops:
        if op == "lookup":
            assert (alloc.lookup(sid) is not None) == \
                (store.get(sid) is not None)
        elif op == "assign":
            _, evicted = alloc.assign(sid)
            assert evicted == store.put(sid, _DUMMY_STATE)
        else:
            assert (alloc.release(sid) is not None) == \
                (store.pop(sid) is not None)
        assert set(alloc.live()) == set(store._states)
        a, s = alloc, store.stats()
        assert (a.hits, a.misses, a.evictions) == \
            (s["hits"], s["misses"], s["evictions"])


def test_slot_allocator_matches_statestore_oracle_seeded():
    """The LRU-oracle property replayed deterministically (the hypothesis
    variant skips on bare interpreters): 2000 seeded ops over a tight id
    space against every small capacity."""
    rng = np.random.default_rng(123)
    for capacity in (1, 2, 3, 5):
        alloc, store = SlotAllocator(capacity), StateStore(capacity)
        shadow_free = []
        for _ in range(2000):
            op = ("lookup", "assign", "release")[rng.integers(3)]
            sid = int(rng.integers(8))
            if op == "lookup":
                assert (alloc.lookup(sid) is not None) == \
                    (store.get(sid) is not None)
            elif op == "assign":
                fresh = sid not in alloc
                hw = alloc.high_water
                slot, evicted = alloc.assign(sid)
                assert evicted == store.put(sid, _DUMMY_STATE)
                if fresh and shadow_free:
                    assert slot == shadow_free.pop() and \
                        alloc.high_water == hw
            else:
                slot = alloc.release(sid)
                assert (slot is not None) == \
                    (store.pop(sid) is not None)
                if slot is not None:
                    shadow_free.append(slot)
            assert set(alloc.live()) == set(store._states)
            live_slots = list(alloc.live().values())
            assert len(live_slots) == len(set(live_slots))
            s = store.stats()
            assert (alloc.hits, alloc.misses, alloc.evictions) == \
                (s["hits"], s["misses"], s["evictions"])


def test_slot_allocator_validates_capacity():
    with pytest.raises(ValueError, match="capacity"):
        SlotAllocator(0)


def test_state_residency_resolution(sess):
    """plan()["state_residency"], the ServingConfig knob, and their
    interaction: auto follows the plan for a single session, multi-session
    auto falls back to host, explicit device + replicas is an error, and
    the config validates its values."""
    assert sess.plan["state_residency"] == "device"
    srv = StreamServer(sess, batch=2)
    assert srv.state_residency == "device"
    assert isinstance(srv.states, DeviceStateStore)
    assert srv.health()["state_residency"] == "device"
    srv.close()
    srv = StreamServer(sess, batch=2, state_residency="host")
    assert srv.state_residency == "host"
    assert isinstance(srv.states, StateStore)
    srv.close()
    replica = repro.build(MODEL, params=sess.params, seed=0).quantize()
    srv = StreamServer([sess, replica], batch=2)       # auto, 2 sessions
    assert srv.state_residency == "host"
    srv.close()
    with pytest.raises(ValueError, match="single session"):
        StreamServer([sess, replica], batch=2, state_residency="device")
    with pytest.raises(ValueError, match="host|device"):
        ServingConfig(state_residency="gpu")
    with pytest.raises(ValueError, match="stateful"):
        ServingConfig(stateful=False, state_residency="device")
    srv = StreamServer(sess, batch=2, stateful=False)  # stateless: None
    assert srv.state_residency is None
    srv.close()


def test_device_store_rejects_host_only_surfaces(sess):
    """The device store is not a drop-in for code reaching into the host
    store's (h, c) surfaces — it says so instead of half-working."""
    store = DeviceStateStore(sess, capacity=4)
    with pytest.raises(AttributeError, match="state_residency='host'"):
        store.put("s", _DUMMY_STATE)
    assert store.zero_slot == 4 and store.trash_slot == 5
    assert store.table.shape == (6, MODEL.num_layers, 2, MODEL.hidden_size)


def test_device_vs_host_bit_exact_under_eviction_churn(sess):
    """The serving-level battery: more streams than slots (forced LRU
    evictions, slot reuse, mid-stream resets) — the device path's
    results, reset flags, and state counters all match the host path
    wave for wave, and both match the fresh/continued oracle."""
    k, n_streams, cap = 3, 6, 4
    xs = {f"s{i}": _windows(k, seed=70 + i) for i in range(n_streams)}

    def run(residency):
        rows, srv_stats = {}, None
        with StreamServer(sess, batch=4, deadline_s=0.005, max_streams=cap,
                          state_residency=residency) as srv:
            for w in range(k):
                for sid in xs:
                    srv.submit(sid, xs[sid][w])
                srv.flush(timeout=60)
            for r in srv.drain(timeout=60):
                rows[(r.stream_id, r.seq, r.state_reset)] = np.asarray(r.y)
            stats = srv.states.stats()
            srv_stats = {q: stats[q] for q in ("hits", "misses",
                                               "evictions", "live_streams")}
        return rows, srv_stats

    host_rows, host_stats = run("host")
    dev_rows, dev_stats = run("device")
    assert host_stats == dev_stats and host_stats["evictions"] > 0
    assert host_rows.keys() == dev_rows.keys()     # same reset flags
    for key in host_rows:
        np.testing.assert_array_equal(host_rows[key], dev_rows[key])
