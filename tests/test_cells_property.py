"""Property tests for the cell registry contract (`repro.cells`).

Random ``(cell, fxp, hs_method, layers, hidden)`` draws must round-trip
the registry — the declared state shape matches what ``init_state``
builds and what ``run_int_stateful`` returns, the param tree survives
quantisation structurally — and keep the int path ref<->xla bit-exact on
short sequences.  Runs under hypothesis when installed (CI's
requirements-dev env); skips per-test on a bare interpreter via
``hypothesis_compat``.  A seeded plain-pytest sample of the same
properties always runs, so the contract is never entirely unguarded.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro import backends, cells
from repro.core import fixed_point as fxp
from repro.core.accelerator import (AcceleratorConfig, HS_METHODS,
                                    resolve_model)
from repro.core.fixed_point import FXP_4_8, FXP_8_16, FixedPointConfig
from repro.core.qlstm import QLSTMConfig

CELLS = ("lstm", "gru", "rglru")
FXPS = (FXP_4_8, FixedPointConfig(6, 10), FXP_8_16)


def _draw_case(cell, fp, hs_method, layers, hidden, seed, t=3):
    """Build one resolved (model, qparams, x_int) case for a draw."""
    base = QLSTMConfig(input_size=2, hidden_size=hidden, num_layers=layers,
                       seq_len=t, out_features=2, cell=cell)
    accel = AcceleratorConfig(fxp=fp, hs_method=hs_method)
    m = resolve_model(base, accel, warn=False)
    spec = cells.get(cell)
    params = spec.init_params(m, jax.random.key(seed))
    qp = spec.quantize_params(params, m)
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, (2, t, 2)).astype(np.float32)
    x_int = fxp.quantize(jnp.asarray(x), fp)
    return m, accel, spec, params, qp, x_int


def _check_registry_roundtrip(cell, fp, layers, hidden, seed):
    """State shape and param tree survive the registry round-trip."""
    m, _, spec, params, qp, x_int = _draw_case(
        cell, fp, "arithmetic", layers, hidden, seed)
    assert cells.state_shape(m) == (layers, spec.state_arity, hidden)
    state = cells.init_state(m, batch=2)
    assert len(state) == layers
    assert all(len(layer) == spec.state_arity for layer in state)
    # Quantisation preserves the tree structure: same layer count, same
    # per-layer keys, int32 codes throughout the recurrent stack.
    assert len(qp["layers"]) == len(params["layers"]) == layers
    for qlayer, flayer in zip(qp["layers"], params["layers"]):
        assert set(qlayer) >= set(flayer) - {"lam"}
        for v in qlayer.values():
            assert jnp.asarray(v).dtype == jnp.int32
    # The stateful runner returns the declared shape back.
    y, out = spec.run_int_stateful(qp, x_int, m, state)
    assert y.shape == (2, m.out_features)
    assert len(out) == layers
    for layer in out:
        assert len(layer) == spec.state_arity
        for a in layer:
            assert a.shape == (2, hidden) and a.dtype == jnp.int32


def _check_ref_xla_bit_exact(cell, fp, hs_method, layers, hidden, seed):
    """Short-sequence int path: oracle == general datapath, bit-for-bit."""
    m, accel, _, _, qp, x_int = _draw_case(
        cell, fp, hs_method, layers, hidden, seed)
    y_ref = backends.get("ref").run(qp, x_int, m, accel)
    y_xla = backends.get("xla").run(qp, x_int, m, accel)
    np.testing.assert_array_equal(
        np.asarray(y_ref), np.asarray(y_xla),
        err_msg=f"{cell} {fp} {hs_method} L{layers} H{hidden} s{seed}")


@pytest.mark.property
@given(st.sampled_from(CELLS), st.sampled_from(FXPS),
       st.integers(1, 3), st.integers(2, 12), st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_registry_roundtrip_property(cell, fp, layers, hidden, seed):
    _check_registry_roundtrip(cell, fp, layers, hidden, seed)


@pytest.mark.property
@given(st.sampled_from(CELLS), st.sampled_from(FXPS),
       st.sampled_from(HS_METHODS), st.integers(1, 3), st.integers(2, 10),
       st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_ref_xla_bit_exact_property(cell, fp, hs_method, layers, hidden,
                                    seed):
    _check_ref_xla_bit_exact(cell, fp, hs_method, layers, hidden, seed)


# -- seeded fallback sample: always runs, hypothesis or not -----------------

@pytest.mark.parametrize("cell", CELLS)
def test_registry_roundtrip_sampled(cell):
    rng = np.random.default_rng(hash(cell) % (2 ** 32))
    for _ in range(4):
        fp = FXPS[rng.integers(len(FXPS))]
        _check_registry_roundtrip(cell, fp, int(rng.integers(1, 4)),
                                  int(rng.integers(2, 13)),
                                  int(rng.integers(2 ** 16)))


@pytest.mark.parametrize("cell", CELLS)
def test_ref_xla_bit_exact_sampled(cell):
    rng = np.random.default_rng(hash(cell) % (2 ** 32) + 1)
    for _ in range(4):
        fp = FXPS[rng.integers(len(FXPS))]
        hs = HS_METHODS[rng.integers(len(HS_METHODS))]
        _check_ref_xla_bit_exact(cell, fp, hs, int(rng.integers(1, 4)),
                                 int(rng.integers(2, 11)),
                                 int(rng.integers(2 ** 16)))
