"""Deterministic battery for the successive-halving search core.

Everything here drives ``repro.explore.halving`` with synthetic
measurement tables — no server, no timing, no randomness — so every
assertion is exact: rung promotion against a hand-computed oracle,
budget accounting against the analytic bound, and bit-identical traces
across repeated runs.  The live serving-sweep integration (real
``StreamServer`` runs under a scenario) lives in ``tests/test_explore.py``.
"""

import math

import pytest

from repro.explore import (ExploreError, parse_constraint, rung_schedule,
                           successive_halving)


def table_measure(table):
    """A measure() over a per-item metrics table, recording call order."""
    calls = []

    def measure(item, rung, fraction):
        calls.append((item, rung, fraction))
        return table[item]

    return measure, calls


# ---------------------------------------------------------------------------
# rung_schedule: sizes, fractions, and the analytic budget
# ---------------------------------------------------------------------------

def test_rung_schedule_halves_until_one_survivor():
    sizes, fractions = rung_schedule(12, eta=3)
    assert sizes == [12, 4, 2, 1]
    assert fractions[-1] == 1.0
    assert fractions == [3.0 ** (r - 3) for r in range(4)]
    # strictly increasing cost per rung
    assert all(a < b for a, b in zip(fractions, fractions[1:]))


def test_rung_schedule_explicit_rungs_and_degenerates():
    sizes, fractions = rung_schedule(24, eta=2, rungs=2)
    assert sizes == [24, 12]
    assert fractions == [0.5, 1.0]
    # one candidate: a single full-scenario rung
    assert rung_schedule(1, eta=2) == ([1], [1.0])
    # one rung: everything measured once, at the full scenario
    assert rung_schedule(7, eta=2, rungs=1) == ([7], [1.0])


def test_rung_schedule_rejects_bad_inputs():
    with pytest.raises(ExploreError, match="empty candidate set"):
        rung_schedule(0)
    with pytest.raises(ValueError, match="eta"):
        rung_schedule(4, eta=1)
    with pytest.raises(ValueError, match="rungs"):
        rung_schedule(4, rungs=0)


# ---------------------------------------------------------------------------
# promotion against a hand-computed oracle
# ---------------------------------------------------------------------------

def test_promotion_matches_hand_computed_oracle():
    # 4 items, eta=2 -> sizes [4, 2, 1].  Objective maximised:
    #   scores a=3, b=1, c=4, d=2
    # rung 0 ranking: c, a, d, b -> promote [c, a]
    # rung 1 ranking: c, a       -> promote [c]
    # rung 2 winner: c
    table = {"a": {"v": 3.0}, "b": {"v": 1.0},
             "c": {"v": 4.0}, "d": {"v": 2.0}}
    measure, calls = table_measure(table)
    res = successive_halving(["a", "b", "c", "d"], measure, objective="v",
                             eta=2, labels=list("abcd"))
    assert res["sizes"] == [4, 2, 1]
    assert [r["promoted"] for r in res["rungs"]] == [["c", "a"], ["c"], []]
    assert [r["measured"] for r in res["rungs"]] == \
        [["a", "b", "c", "d"], ["c", "a"], ["c"]]
    assert res["winner_label"] == "c"
    assert res["winner_feasible"] is True
    # measure() saw exactly the promoted survivors at each rung
    assert [c[0] for c in calls] == ["a", "b", "c", "d", "c", "a", "c"]
    assert [c[1] for c in calls] == [0, 0, 0, 0, 1, 1, 2]


def test_sense_min_inverts_the_ranking():
    table = {i: {"lat": v} for i, v in enumerate([5.0, 2.0, 9.0, 4.0])}
    measure, _ = table_measure(table)
    res = successive_halving(list(table), measure, objective="lat",
                             sense="min", eta=2)
    assert res["winner"] == 1          # the smallest latency
    assert res["rungs"][0]["promoted"] == ["1", "3"]


def test_constrained_ranking_puts_infeasible_below_feasible():
    # b has the best throughput but violates the SLO; a is the best
    # feasible point and must win.  Infeasible points order by violation.
    slo = parse_constraint("p99_ms<=5")
    table = {
        "a": {"v": 10.0, "p99_ms": 4.0},      # feasible
        "b": {"v": 99.0, "p99_ms": 9.0},      # violation 4
        "c": {"v": 50.0, "p99_ms": 6.0},      # violation 1
        "d": {"v": 5.0, "p99_ms": 1.0},       # feasible
    }
    measure, _ = table_measure(table)
    res = successive_halving(list(table), measure, objective="v",
                             constraint=slo, eta=2,
                             labels=list(table))
    assert res["rungs"][0]["ranking"] == ["a", "d", "c", "b"]
    assert res["winner_label"] == "a"
    assert res["winner_feasible"] is True


def test_all_infeasible_still_terminates_least_violating_first():
    slo = parse_constraint("p99_ms<=1")
    table = {"x": {"v": 1.0, "p99_ms": 7.0},
             "y": {"v": 1.0, "p99_ms": 3.0}}
    measure, _ = table_measure(table)
    res = successive_halving(["x", "y"], measure, objective="v",
                             constraint=slo, labels=["x", "y"])
    assert res["winner_label"] == "y"        # closest to the bound
    assert res["winner_feasible"] is False


# ---------------------------------------------------------------------------
# budget accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,eta,rungs", [(8, 2, None), (9, 3, None),
                                         (24, 2, 2), (5, 4, 3), (1, 2, None)])
def test_budget_never_exceeds_analytic_bound(n, eta, rungs):
    table = {i: {"v": float(i)} for i in range(n)}
    measure, calls = table_measure(table)
    res = successive_halving(list(range(n)), measure, objective="v",
                             eta=eta, rungs=rungs)
    sizes, _ = rung_schedule(n, eta, rungs)
    assert res["total_measurements"] == len(calls) == sum(sizes)
    assert res["total_measurements"] <= res["budget_bound"] == sum(sizes)


# ---------------------------------------------------------------------------
# determinism + degenerate spaces
# ---------------------------------------------------------------------------

def test_identical_runs_produce_identical_traces():
    table = {i: {"v": float((i * 7) % 5)} for i in range(10)}
    runs = []
    for _ in range(2):
        measure, _ = table_measure(table)
        runs.append(successive_halving(list(range(10)), measure,
                                       objective="v", eta=2))
    assert runs[0] == runs[1]


def test_ties_break_by_input_index():
    table = {i: {"v": 1.0} for i in range(4)}    # all tied
    measure, _ = table_measure(table)
    res = successive_halving(list(range(4)), measure, objective="v", eta=2)
    assert res["rungs"][0]["promoted"] == ["0", "1"]
    assert res["winner"] == 0
    assert res["winner_feasible"] is True


def test_single_item_space_terminates():
    measure, calls = table_measure({"only": {"v": 1.0}})
    res = successive_halving(["only"], measure, objective="v",
                             labels=["only"])
    assert res["sizes"] == [1]
    assert res["fractions"] == [1.0]
    assert res["winner_label"] == "only"
    assert len(calls) == 1


def test_failed_measurements_rank_last_and_never_win_feasibly():
    table = {"ok": {"v": 1.0}, "dead": None, "nan": {"v": float("nan")}}

    def measure(item, rung, fraction):
        return table[item]

    res = successive_halving(list(table), measure, objective="v",
                             labels=list(table))
    assert res["winner_label"] == "ok"
    assert res["rungs"][0]["ranking"][0] == "ok"
    # a space of only failures still terminates, flagged infeasible
    res2 = successive_halving(["dead"], lambda *a: None, objective="v",
                              labels=["dead"])
    assert res2["winner_feasible"] is False
    assert res2["results"] == {}


def test_empty_item_list_raises_explore_error():
    with pytest.raises(ExploreError, match="0 points survived"):
        successive_halving([], lambda *a: {}, objective="v")


def test_fractions_are_geometric_and_end_full():
    for n, eta in [(16, 2), (27, 3), (100, 4)]:
        sizes, fractions = rung_schedule(n, eta)
        assert fractions[-1] == 1.0
        for a, b in zip(fractions, fractions[1:]):
            assert math.isclose(b / a, eta)
