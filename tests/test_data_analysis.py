"""Data pipeline determinism + HLO collective parser + energy model."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes
from repro.core import energy
from repro.data.lm_data import SyntheticLM
from repro.data.pipeline import Pipeline
from repro.data.timeseries import make_windows, pems_like_dataset


def test_pems_windows_shapes_and_range():
    d = pems_like_dataset(seq_len=6)
    x, y = d["train"]
    assert x.shape[1:] == (6, 1) and y.shape[1:] == (1,)
    assert 0.0 <= x.min() and x.max() <= 1.0
    # windows are shifted views of the same series
    np.testing.assert_allclose(x[1, :-1, 0], x[0, 1:, 0])


def test_lm_data_step_keyed_determinism():
    src = SyntheticLM(1000, seed=5)
    a = src.batch(3, 4, 8)
    b = src.batch(3, 4, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(4, 4, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_prefetch_order():
    seen = []
    src = SyntheticLM(100, seed=1)

    def source(step):
        seen.append(step)
        return src.batch(step, 2, 4)

    p = Pipeline(source, start_step=10, prefetch=2)
    b0 = next(p)
    b1 = next(p)
    p.close()
    exp0 = src.batch(10, 2, 4)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]), exp0["tokens"])
    assert seen[:2] == [10, 11]


HLO = """
  %ag = bf16[64,512]{1,0} all-gather(bf16[4,512]{1,0} %p), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%add
  %ars = (f32[256]{0}, f32[256]{0}) all-reduce-start(f32[256]{0} %y)
  %ard = f32[256]{0} all-reduce-done(%ars)
  %rs = s8[32,16]{1,0} reduce-scatter(s8[512,16]{1,0} %z), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %w)
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 64 * 512 * 2
    # plain all-reduce + async start (tuple halved => one payload)
    assert out["all-reduce"] == 128 * 4 + 256 * 4
    assert out["reduce-scatter"] == 32 * 16
    assert out["collective-permute"] == 8 * 8 * 2
    assert out["count"] == 4 + 1  # -done excluded
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "collective-permute"))


def test_roofline_terms_and_bound():
    t = energy.roofline_terms(flops=197e12, hbm_bytes=0, collective_bytes=0)
    assert t.compute_s == pytest.approx(1.0)
    assert t.bound == "compute"
    t2 = energy.roofline_terms(flops=0, hbm_bytes=819e9, collective_bytes=0)
    assert t2.memory_s == pytest.approx(1.0)
    assert t2.bound == "memory"


def test_power_report_static_dynamic_split():
    rep = energy.power_report(flops=1e12, hbm_bytes=1e9, ici_bytes=0,
                              latency_s=0.01, dtype="int8")
    assert rep["static_w"] == energy.P_STATIC_W
    assert rep["total_w"] > rep["static_w"]
    assert rep["gops_per_watt"] > 0
    # int8 ops burn less than bf16 flops (C1's energy argument)
    rep_bf16 = energy.power_report(flops=1e12, hbm_bytes=1e9, ici_bytes=0,
                                   latency_s=0.01, dtype="bf16")
    assert rep["dynamic_w"] < rep_bf16["dynamic_w"]


def test_model_flops():
    assert energy.model_flops_train(1e9, 1e6) == 6e15
    assert energy.model_flops_decode(1e9, 128) == pytest.approx(2.56e11)
