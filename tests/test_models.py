"""Per-architecture smoke tests (REDUCED configs — §ARCHITECTURES
requirement): one forward/train step on CPU asserting shapes + no NaNs,
plus the substrate-level equivalences (chunked vs sequential wkv, RG-LRU
scan vs step, prefill vs decode, MoE dispatch conservation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS, ASSIGNED_ARCHS, reduce_config
from repro.core.quant import QuantConfig
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models import transformer as T

KEY = jax.random.key(0)


def _batch_for(cfg, b, s, key=KEY, kind="train"):
    batch = {}
    if kind == "train":
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    else:
        batch["inputs_embeds"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.bfloat16)
    if cfg.attn and cfg.attn.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch["position_ids"] = jnp.stack([pos] * 3)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config, one value_and_grad train step: finite loss + grads."""
    cfg = reduce_config(ARCH_CONFIGS[arch])
    params, axes = T.init_model(cfg, KEY)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch_for(cfg, 2, 16)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.forward_train(p, batch, cfg), has_aux=True)(params)
    assert bool(jnp.isfinite(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = reduce_config(ARCH_CONFIGS[arch])
    params, _ = T.init_model(cfg, KEY)
    b = 2
    cache = T.init_cache(cfg, b, 32)
    batch = _batch_for(cfg, b, 1, kind="decode")
    batch["cache_pos"] = jnp.asarray(0, jnp.int32)
    if "position_ids" in batch:
        batch["position_ids"] = batch["position_ids"][:, :, :1]
    logits, new_cache = T.forward_decode(params, cache, batch, cfg)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert set(new_cache) == set(cache)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b", "mixtral-8x7b",
                                  "rwkv6-7b", "recurrentgemma-2b"])
def test_prefill_decode_consistency(arch):
    """Sequentially decoding the prompt reproduces the prefill logits."""
    cfg = reduce_config(ARCH_CONFIGS[arch]).replace(remat="none")
    params, _ = T.init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    pre = T.forward_prefill(params, {"tokens": toks}, cfg)
    cache = T.init_cache(cfg, 2, 32)
    for t in range(8):
        logits, cache = T.forward_decode(
            params, cache,
            {"tokens": toks[:, t:t + 1], "cache_pos": jnp.asarray(t, jnp.int32)},
            cfg)
    err = float(jnp.max(jnp.abs(pre[:, -1] - logits[:, 0])))
    assert err < 0.3, (arch, err)  # bf16 accumulation tolerance


def test_wkv_chunked_equals_sequential():
    rng = np.random.default_rng(0)
    b, t, h, n = 2, 37, 3, 8   # deliberately not a chunk multiple
    r, k, v = (jnp.asarray(rng.normal(0, 1, (b, t, h, n)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.normal(-1, 1, (b, t, h, n)).astype(np.float32))
    u = jnp.asarray(rng.normal(0, 1, (h, n)).astype(np.float32))
    y_seq, s_seq = RW.wkv_sequential(r, k, v, w, u)
    y_chk, s_chk = RW.wkv_chunked(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_stepwise():
    cfg = reduce_config(ARCH_CONFIGS["recurrentgemma-2b"])
    p_full, _ = T.init_model(cfg, KEY)
    p = jax.tree.map(lambda x: x[0], p_full["groups"][0]["mixer"])
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (2, 9, cfg.recurrent.lru_width))
                    .astype(np.float32))
    y_scan = RG.rglru_scan(p, x, cfg)
    h = jnp.zeros((2, cfg.recurrent.lru_width))
    outs = []
    for t in range(9):
        h = RG.rglru_step(p, x[:, t:t + 1], h, cfg)
        outs.append(h)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=2e-5, atol=2e-5)


def test_moe_conservation_and_aux():
    """Every kept token claim contributes exactly its gate weight; aux loss
    is ~1 for balanced routing."""
    from repro.models.moe import moe_apply
    cfg = reduce_config(ARCH_CONFIGS["mixtral-8x7b"])
    params, _ = T.init_model(cfg, KEY)
    p = jax.tree.map(lambda x: x[0], params["blocks"]["mlp"])
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert 0.5 < float(aux) < 4.0  # balanced-ish at init


def test_quantized_serve_params_close():
    cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"]).replace(
        quant=QuantConfig("w8"), remat="none")
    params, axes = T.init_model(cfg, KEY)
    qp, qa = T.quantize_model_params(params, axes, cfg)
    toks = jax.random.randint(jax.random.key(3), (2, 8), 0, cfg.vocab_size)
    lf = T.forward_prefill(params, {"tokens": toks}, cfg.replace(quant=QuantConfig("none")))
    lq = T.forward_prefill(qp, {"tokens": toks}, cfg)
    # int8 weights: logits track the float model closely (pre-softcap space)
    denom = float(jnp.std(lf)) + 1e-9
    assert float(jnp.max(jnp.abs(lq - lf))) / denom < 0.35


def test_int8_kv_cache_decode_close_to_bf16():
    cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"]).replace(remat="none")
    cfg_q = cfg.replace(quant=QuantConfig("none", quantize_kv=True))
    params, _ = T.init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.key(4), (2, 6), 0, cfg.vocab_size)
    outs = {}
    for name, c in [("bf16", cfg), ("int8kv", cfg_q)]:
        cache = T.init_cache(c, 2, 16)
        for t in range(6):
            logits, cache = T.forward_decode(
                params, cache,
                {"tokens": toks[:, t:t + 1],
                 "cache_pos": jnp.asarray(t, jnp.int32)}, c)
        outs[name] = logits
    err = float(jnp.max(jnp.abs(outs["bf16"] - outs["int8kv"])))
    assert err < 0.5, err


def test_swa_ring_buffer_wrap_matches_full_cache():
    """Mixtral-style uniform-SWA decode with a RING cache (size=window) must
    match decoding with a full-length cache once positions exceed the
    window — the mechanism behind the long_500k cell."""
    cfg = reduce_config(ARCH_CONFIGS["mixtral-8x7b"]).replace(remat="none")
    assert cfg.uniform_window == 8
    params, _ = T.init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.key(5), (1, 14), 0, cfg.vocab_size)

    # ring cache: allocated at exactly the window size
    ring = T.init_cache(cfg, 1, 14)
    assert ring["k"].shape[2] == 8
    # full cache: sized to the whole sequence (window masking only)
    full = T.init_cache(cfg.replace(
        attn=dataclasses_replace(cfg.attn, window=None)), 1, 14)

    for t in range(14):
        b = {"tokens": toks[:, t:t + 1], "cache_pos": jnp.asarray(t, jnp.int32)}
        lr, ring = T.forward_decode(params, ring, b, cfg)
        lf, full = T.forward_decode(
            params, full, b,
            cfg.replace(attn=dataclasses_replace(cfg.attn, window=8)))
    err = float(jnp.max(jnp.abs(lr - lf)))
    assert err < 1e-3, err


def dataclasses_replace(obj, **kw):
    import dataclasses
    return dataclasses.replace(obj, **kw)
