"""HardSigmoid*/HardTanh (C2): the paper's Table-1 structure facts and the
three-method bit-identity."""

import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import hard_act as ha
from repro.core.fixed_point import FXP_4_8, FXP_6_8, FXP_8_10, FixedPointConfig


def test_paper_table_entry_counts():
    """(4,8): 96 one-to-one entries and 14 step entries — §5.1."""
    spec = ha.HardSigmoidStarSpec(FXP_4_8)
    assert ha.num_1to1_entries(spec) == 96
    assert ha.num_step_entries(spec) == 14


specs = st.sampled_from([
    ha.HardSigmoidStarSpec(FXP_4_8),
    ha.HardSigmoidStarSpec(FXP_6_8),
    ha.HardSigmoidStarSpec(FXP_8_10),
    ha.HardSigmoidStarSpec(FixedPointConfig(5, 8), slope_shift=2),
    ha.HardSigmoidStarSpec(FXP_4_8, slope_shift=4, bound=2.0),
])


@given(specs)
@settings(max_examples=20, deadline=None)
def test_three_methods_bit_identical(spec):
    xs = jnp.arange(spec.cfg.int_min, spec.cfg.int_max + 1)
    a = ha.hs_star_int(xs, spec, "arithmetic")
    b = ha.hs_star_int(xs, spec, "1to1")
    c = ha.hs_star_int(xs, spec, "step")
    assert bool(jnp.all(a == b)) and bool(jnp.all(b == c))


@given(specs)
@settings(max_examples=20, deadline=None)
def test_hs_star_monotone_and_bounded(spec):
    xs = jnp.arange(spec.cfg.int_min, spec.cfg.int_max + 1)
    y = np.asarray(ha.hs_star_int(xs, spec, "arithmetic"))
    assert (np.diff(y) >= 0).all()
    assert y.min() >= 0 and y.max() <= spec.one_int


def test_int_matches_float_within_one_lsb():
    spec = ha.HardSigmoidStarSpec(FXP_4_8)
    cfg = spec.cfg
    xs = jnp.arange(cfg.int_min, cfg.int_max + 1)
    yi = np.asarray(ha.hs_star_int(xs, spec)) * cfg.scale
    yf = np.asarray(ha.hard_sigmoid_star(xs * cfg.scale, 0.125, 3.0))
    assert np.max(np.abs(yi - yf)) <= cfg.scale + 1e-7


def test_hard_tanh_int_is_two_comparators():
    cfg = FXP_4_8
    xs = jnp.arange(cfg.int_min, cfg.int_max + 1)
    y = np.asarray(ha.hard_tanh_int(xs, cfg))
    assert y.min() == -16 and y.max() == 16   # +-1.0 at 4 fractional bits
    mid = (xs >= -16) & (xs <= 16)
    np.testing.assert_array_equal(y[np.asarray(mid)], np.asarray(xs)[np.asarray(mid)])


def test_baseline_lut_sigmoid_256_entries():
    """The baseline [15] uses a full 2^8-entry table."""
    cfg = FXP_4_8
    table = ha._lut_act_table_np("sigmoid", cfg)
    assert len(table) == 256
    y = np.asarray(ha.lut_sigmoid_int(jnp.arange(-128, 128), cfg)) * cfg.scale
    xf = np.arange(-128, 128) * cfg.scale
    assert np.max(np.abs(y - 1 / (1 + np.exp(-xf)))) <= cfg.scale / 2 + 1e-7


def test_hard_variants_close_to_soft():
    x = jnp.linspace(-4, 4, 201)
    assert float(jnp.max(jnp.abs(ha.hard_silu(x) - x * (1 / (1 + jnp.exp(-x)))))) < 0.3
    assert float(jnp.max(jnp.abs(ha.hard_sigmoid(x) - 1 / (1 + jnp.exp(-x))))) < 0.12
