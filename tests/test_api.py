"""The unified session API: lifecycle, backend parity, config unification.

The heart is the parity sweep: every combination of datapath x backend x
HardSigmoid* method x ALU mode must agree BIT-EXACTLY on the integer path
through ``Accelerator.infer`` — the paper's claim that one parameterised
design has many interchangeable implementations."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import backends
from repro.core.accelerator import (AcceleratorConfig, BASELINE_15,
                                    resolve_model)
from repro.core.fixed_point import FXP_8_16
from repro.core.qlstm import ActivationConfig, BASELINE_ACTS, QLSTMConfig


def _x(b=8, t=6, m=1, seed=1):
    return jax.random.normal(jax.random.key(seed), (b, t, m)) * 0.5


# ---------------------------------------------------------------------------
# Backend parity — the acceptance criterion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", ["float", "qat", "int"])
@pytest.mark.parametrize("hs_method", ["arithmetic", "1to1", "step"])
@pytest.mark.parametrize("alu_mode", ["pipelined", "per_step"])
def test_paths_and_backends_parity(path, hs_method, alu_mode):
    """path x backend x hs_method x alu_mode sweep.

    Int path: every backend able to run the configuration returns
    bit-identical outputs.  Float/QAT paths: backend-independent by
    construction — assert the engines' int results stay within 1 LSB of
    the QAT simulation (the datapath-faithfulness contract)."""
    acc_cfg = AcceleratorConfig(hs_method=hs_method, alu_mode=alu_mode)
    sess = repro.build(QLSTMConfig(), acc_cfg).quantize()
    x = _x()

    if path in ("float", "qat"):
        y = sess.infer(x, path=path)
        assert y.shape == (8, 1) and bool(jnp.all(jnp.isfinite(y)))
        return

    names = backends.supported_backends(sess.model, sess.accel)
    assert "xla" in names  # the general engine runs every Table-2 point
    if alu_mode == "pipelined":
        assert set(names) == {"ref", "pallas", "xla"}
    outs = {n: np.asarray(sess.infer(x, path="int", backend=n))
            for n in names}
    ref_name = names[0]
    for n, out in outs.items():
        np.testing.assert_array_equal(
            out, outs[ref_name],
            err_msg=f"backend {n} != {ref_name} for hs={hs_method}, "
                    f"alu={alu_mode}")
    # datapath faithfulness: int within 1 LSB of the QAT fake-quant graph
    yq = np.asarray(sess.infer(x, path="qat"))
    assert np.abs(outs[ref_name] - yq).max() <= sess.model.fxp.scale + 1e-7


@pytest.mark.parametrize("unit", ["mxu", "vpu"])
def test_parity_multilayer_and_units(unit):
    """Stacked layers through the fused kernel agree with the oracle."""
    model = QLSTMConfig(input_size=2, hidden_size=8, num_layers=2, seq_len=4)
    sess = repro.build(model, AcceleratorConfig(compute_unit=unit)).quantize()
    x = _x(b=5, t=4, m=2)
    outs = [np.asarray(sess.infer(x, path="int", backend=n))
            for n in ("ref", "pallas", "xla")]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_explicit_unsupported_backend_raises():
    sess = repro.build(QLSTMConfig(),
                       AcceleratorConfig(alu_mode="per_step")).quantize()
    with pytest.raises(backends.BackendUnsupported):
        sess.infer(_x(), path="int", backend="pallas")
    with pytest.raises(backends.BackendUnsupported):
        sess.infer(_x(), path="int", backend="ref")
    # a config-level impossible engine fails at build, not first infer
    with pytest.raises(backends.BackendUnsupported):
        repro.build(QLSTMConfig(),
                    AcceleratorConfig(alu_mode="per_step", backend="pallas"))


def test_auto_backend_follows_plan():
    assert repro.build().plan["backend"] == "pallas"
    assert repro.build(QLSTMConfig(),
                       AcceleratorConfig(alu_mode="per_step")
                       ).plan["backend"] == "xla"
    assert repro.build(QLSTMConfig(acts=BASELINE_ACTS),
                       BASELINE_15).plan["backend"] == "xla"
    # explicit override sticks
    assert repro.build(QLSTMConfig(),
                       AcceleratorConfig(backend="ref")
                       ).plan["backend"] == "ref"


# ---------------------------------------------------------------------------
# Lifecycle
# ---------------------------------------------------------------------------

def test_lifecycle_train_quantize_infer_serve_report():
    from repro.data.timeseries import pems_like_dataset
    data = pems_like_dataset(seq_len=6, n_days=4)
    sess = repro.build(seed=0)
    sess.train_qat(data, steps=5, batch=16, log=lambda *_: None).quantize()
    assert sess.train_summary["step"] == 5

    xte, yte = data["test"]
    y = sess.infer(jnp.asarray(xte[:32]), path="int")
    assert y.shape == (32, 1)

    # serve: wave-batched streaming matches batched infer, in order
    preds = list(sess.serve(iter(xte[:37]), batch=16))
    want = np.asarray(sess.infer(jnp.asarray(xte[:37]), path="int"))
    assert len(preds) == 37
    np.testing.assert_array_equal(np.stack(preds), want)

    rep = sess.report()
    assert rep["quantized"] and rep["plan"]["backend"] in ("pallas", "xla")
    assert rep["ops_per_inference"] > 0 and rep["energy"]["total_w"] > 0


def test_int_path_requires_quantize():
    sess = repro.build()
    with pytest.raises(RuntimeError, match="quantize"):
        sess.infer(_x(), path="int")


def test_train_invalidates_quantization():
    from repro.data.timeseries import pems_like_dataset
    data = pems_like_dataset(seq_len=6, n_days=4)
    sess = repro.build().quantize()
    assert sess.qparams is not None
    sess.train_qat(data, steps=2, batch=8, log=lambda *_: None)
    assert sess.qparams is None  # stale codes dropped


# ---------------------------------------------------------------------------
# Config unification / deprecation shim
# ---------------------------------------------------------------------------

def test_accelerator_config_is_source_of_truth():
    sess = repro.build(QLSTMConfig(),
                       AcceleratorConfig(hs_method="arithmetic",
                                         fxp=FXP_8_16,
                                         alu_mode="per_step", ht_max=2.0))
    assert sess.model.acts.hs_method == "arithmetic"
    assert sess.model.fxp == FXP_8_16
    assert sess.model.alu_mode == "per_step"
    assert sess.model.acts.ht_max == 2.0


def test_legacy_model_knobs_still_work_with_warning():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        m = resolve_model(QLSTMConfig(alu_mode="per_step",
                                      acts=ActivationConfig(hs_method="1to1")),
                          AcceleratorConfig())
    assert m.alu_mode == "per_step" and m.acts.hs_method == "1to1"
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_pipelined_alu_alias():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        acc = AcceleratorConfig(pipelined_alu=False)
    assert acc.alu_mode == "per_step" and acc.pipelined_alu is False
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert AcceleratorConfig().pipelined_alu is True


def test_serve_int_shim_matches_session():
    """The deprecated lstm_model.serve_int delegates to the same engines."""
    from repro.models import lstm_model
    cfg = QLSTMConfig()
    sess = repro.build(cfg, seed=3)
    x = _x(seed=4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        y_old = lstm_model.serve_int(sess.params, x, cfg)
    y_new = sess.quantize().infer(x, path="int")
    np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))


# ---------------------------------------------------------------------------
# WaveBatcher LSTM-accelerator mode
# ---------------------------------------------------------------------------

def test_wave_batcher_lstm_mode():
    from repro.launch.batcher import WaveBatcher
    sess = repro.build(seed=0).quantize()
    rng = np.random.default_rng(0)
    windows = rng.uniform(0, 1, (11, 6, 1)).astype(np.float32)

    b = WaveBatcher.for_accelerator(sess, batch_size=4)
    rids = [b.submit_window(w) for w in windows]
    out = b.run()
    assert set(out) == set(rids)
    want = np.asarray(sess.infer(jnp.asarray(windows), path="int"))
    got = np.stack([out[r] for r in rids])
    np.testing.assert_array_equal(got, want)
