"""The paper's model: QAT <-> integer-datapath parity, ALU modes,
multi-layer scaling (§6.2's 5-layer claim)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixed_point as fxp
from repro.core.accelerator import (AcceleratorConfig, BASELINE_15,
                                    PAPER_DEFAULT, lstm_weight_bytes, plan)
from repro.core.qlstm import (BASELINE_ACTS, QLSTMConfig, forward_float,
                              forward_int, forward_qat, init_params,
                              ops_per_inference, quantize_params)
from repro.models import lstm_model


def _setup(cfg, seed=0, b=16):
    params = init_params(cfg, jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (b, cfg.seq_len,
                                                     cfg.input_size)) * 0.5
    return params, x


def test_qat_matches_int_datapath():
    """forward_qat simulates the hardware: dequant(forward_int) must agree
    to within 1 LSB at the output."""
    cfg = QLSTMConfig()
    params, x = _setup(cfg)
    yq = forward_qat(params, x, cfg)
    yi = fxp.dequantize(forward_int(quantize_params(params, cfg),
                                    fxp.quantize(x, cfg.fxp), cfg), cfg.fxp)
    assert float(jnp.max(jnp.abs(yq - yi))) <= cfg.fxp.scale + 1e-7


def test_per_step_vs_pipelined_alu_differ_but_close():
    cfg_p = QLSTMConfig(alu_mode="pipelined")
    cfg_s = QLSTMConfig(alu_mode="per_step")
    params, x = _setup(cfg_p)
    qp = quantize_params(params, cfg_p)
    xi = fxp.quantize(x, cfg_p.fxp)
    yp = forward_int(qp, xi, cfg_p)
    ys = forward_int(qp, xi, cfg_s)
    # late rounding is a different (more accurate) datapath; outputs are
    # close in value
    diff = np.abs(np.asarray(yp) - np.asarray(ys)) * cfg_p.fxp.scale
    assert diff.max() <= 0.5


def test_multilayer_five_layers_hidden_60():
    """§6.2: the design supports 5 layers x hidden 60 without DSPs."""
    cfg = QLSTMConfig(input_size=4, hidden_size=60, num_layers=5, seq_len=3)
    params, x = _setup(cfg, b=2)
    y = forward_float(params, x, cfg)
    assert y.shape == (2, 1) and bool(jnp.all(jnp.isfinite(y)))
    yi = forward_int(quantize_params(params, cfg),
                     fxp.quantize(x, cfg.fxp), cfg)
    assert yi.shape == (2, 1)
    # no-DSP plan must keep all weights on-chip (BRAM/VMEM analogue)
    p = plan(cfg, AcceleratorConfig(compute_unit="vpu"))
    assert p["vmem_resident"] and p["compute_unit"] == "vpu"


def test_baseline_15_acts_run():
    cfg = QLSTMConfig(acts=BASELINE_ACTS, fxp=fxp.FXP_8_16,
                      alu_mode="per_step")
    params, x = _setup(cfg, b=4)
    yi = forward_int(quantize_params(params, cfg), fxp.quantize(x, cfg.fxp),
                     cfg)
    assert bool(jnp.all(jnp.isfinite(yi)))


def test_ops_counting_matches_paper_scale():
    """Paper: 0.740 GOP/s at 28.07us latency => ~20.8k ops/inference for the
    hidden-20 model.  Our convention counts within 15%."""
    ops = ops_per_inference(QLSTMConfig())
    assert abs(ops - 0.740e9 * 28.07e-6) / (0.740e9 * 28.07e-6) < 0.15


def test_weight_bytes_accounting():
    cfg = QLSTMConfig()
    by = lstm_weight_bytes(cfg, PAPER_DEFAULT)
    # (1+20)*80 + 20*1 dense + biases at 2 bytes
    assert by == (21 * 80) + 4 * 20 * 2 + 20 * 1 + 1 * 2


def test_serve_int_kernel_equals_oracle():
    cfg = QLSTMConfig()
    params, x = _setup(cfg, b=8)
    yk = lstm_model.serve_int(params, x, cfg, use_kernel=True)
    yo = lstm_model.serve_int(params, x, cfg, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yo), atol=1e-7)
