"""Multi-device distribution tests, run in SUBPROCESSES with fake host
devices (XLA_FLAGS must be set before jax import, and the main pytest
process must keep seeing 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(py: str, devices: int = 8, timeout: int = 420) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", py], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_fsdp_tp_train_step_matches_single_device():
    """The same batch on a (2 data x 4 model) mesh and on one device must
    give the same loss — sharding is semantics-preserving."""
    res = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCH_CONFIGS, reduce_config
        from repro.data.lm_data import SyntheticLM
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.sharding.partition import param_shardings, rules_context
        from repro.training.step import TrainPlan, init_train_state, make_train_step
        from repro.training.optimizer import OptConfig

        cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"])
        plan = TrainPlan(opt=OptConfig(lr=1e-3), microbatches=2)
        src = SyntheticLM(cfg.vocab_size, seed=3)
        d = src.batch(0, 8, 16)

        params, axes = T.init_model(cfg, jax.random.key(0))
        state = init_train_state(params, plan)
        step = jax.jit(make_train_step(cfg, plan))
        _, m1 = step(state, {k: jnp.asarray(v) for k, v in d.items()})
        loss_1dev = float(m1["loss"])

        mesh = make_host_mesh(model_parallel=4)   # 2 x 4
        shard = param_shardings(axes, mesh, cfg.sharding_overrides, params)
        with rules_context(mesh, cfg.sharding_overrides):
            sp = jax.device_put(params, shard)
            sstate = init_train_state(sp, plan)
            bspec = NamedSharding(mesh, P("data", None))
            sbatch = {k: jax.device_put(jnp.asarray(v), bspec)
                      for k, v in d.items()}
            sstep = jax.jit(make_train_step(cfg, plan))
            new_state, m8 = sstep(sstate, sbatch)
            jax.block_until_ready(m8["loss"])
        print(json.dumps({"l1": loss_1dev, "l8": float(m8["loss"]),
                          "gn8": float(m8["grad_norm"]),
                          "gn1": float(m1["grad_norm"])}))
    """))
    # The model computes in bfloat16, so the (2 data x 4 model) mesh's
    # different reduction order legitimately moves the loss by a few bf16
    # ULPs (~1e-4 relative on this graph).  Compare RELATIVE, like the
    # grad-norm check below — an absolute bound on a ~41 loss demanded
    # more precision than bf16 arithmetic defines.
    assert abs(res["l1"] - res["l8"]) / max(abs(res["l1"]), 1e-9) < 2e-3, res
    assert abs(res["gn1"] - res["gn8"]) / max(res["gn1"], 1e-9) < 5e-2, res


def test_elastic_checkpoint_resharding(tmp_path):
    """Save on a 4x2 mesh, restore onto a 2x1 mesh (different device count)
    — values must survive exactly (elastic restart)."""
    ck = str(tmp_path / "ck")
    res = _run(textwrap.dedent(f"""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCH_CONFIGS, reduce_config
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.sharding.partition import param_shardings
        from repro.training import checkpoint as ckpt

        cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"])
        params, axes = T.init_model(cfg, jax.random.key(1))
        mesh = make_host_mesh(model_parallel=2)  # 4 x 2
        shard = param_shardings(axes, mesh, (), params)
        sp = jax.device_put(params, shard)
        ckpt.save({ck!r}, sp, 3)
        print(json.dumps({{"sum": float(sum(jnp.sum(x.astype(jnp.float32))
                                           for x in jax.tree.leaves(sp)))}}))
    """), devices=8)
    res2 = _run(textwrap.dedent(f"""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import ARCH_CONFIGS, reduce_config
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.sharding.partition import param_shardings
        from repro.training import checkpoint as ckpt

        cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"])
        params, axes = T.init_model(cfg, jax.random.key(99))  # different init
        mesh = make_host_mesh(model_parallel=1)  # 2 x 1 — ELASTIC resize
        shard = param_shardings(axes, mesh, (), params)
        restored = ckpt.restore({ck!r}, params, shardings=shard)
        ok = all(r.sharding.mesh.size == 2 for r in jax.tree.leaves(restored))
        print(json.dumps({{"sum": float(sum(jnp.sum(x.astype(jnp.float32))
                                            for x in jax.tree.leaves(restored))),
                           "resharded": bool(ok)}}))
    """), devices=2)
    assert res2["resharded"]
    assert abs(res["sum"] - res2["sum"]) < 1e-3


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery end-to-end on an 8-device fake mesh (the 512-
    device production sweep runs via launch/dryrun.py; this guards the
    mechanism in CI)."""
    res = _run(textwrap.dedent("""
        import json
        import jax
        from repro.launch import dryrun as D
        from repro.configs import SHAPES, ARCH_CONFIGS
        import repro.launch.mesh as M

        def small_mesh(*, multi_pod=False):
            shape = (2, 2, 2) if multi_pod else (2, 4)
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            return jax.make_mesh(shape, axes,
                                 devices=jax.devices()[:8 if multi_pod else 8])
        M.make_production_mesh = small_mesh
        D.make_production_mesh = small_mesh
        rec = D.run_cell("qwen1.5-0.5b", "train_4k", False)
        print(json.dumps({"status": rec["status"],
                          "bound": rec["roofline"]["bound"],
                          "flops": rec["flops_per_device"],
                          "colls": rec["collectives"]["total"]}))
    """), devices=8, timeout=560)
    assert res["status"] == "ok"
    assert res["flops"] > 0 and res["colls"] > 0


def test_sigterm_preemption_checkpoint_and_resume(tmp_path):
    """Process-level fault injection: SIGTERM a training process mid-run;
    it must checkpoint-and-exit; a fresh process must resume and finish
    with the same final state as an uninterrupted run."""
    import signal
    import time as _time

    ck_a = str(tmp_path / "a")
    ck_b = str(tmp_path / "b")

    script = """
import json, sys
import jax, jax.numpy as jnp
from repro.configs import ARCH_CONFIGS, reduce_config
from repro.data.lm_data import SyntheticLM
from repro.models import transformer as T
from repro.training.optimizer import OptConfig
from repro.training.step import TrainPlan, init_train_state, make_train_step
from repro.training.train_loop import LoopConfig, Trainer

ckpt_dir, total, slow = sys.argv[1], int(sys.argv[2]), sys.argv[3] == "slow"
cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"])
plan = TrainPlan(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=40))
params, _ = T.init_model(cfg, jax.random.key(0))
state = init_train_state(params, plan)
step = jax.jit(make_train_step(cfg, plan))
src = SyntheticLM(cfg.vocab_size, seed=13)

def batch_fn(i):
    import time
    if slow:
        time.sleep(0.15)   # widen the preemption window
    d = src.batch(i, 4, 16)
    return {k: jnp.asarray(v) for k, v in d.items()}

tr = Trainer(step, state, batch_fn,
             LoopConfig(total_steps=total, ckpt_dir=ckpt_dir, ckpt_every=100,
                        log_every=1000), log=lambda s: None)
start = tr.maybe_resume()
out = tr.run(start_step=start)
s = tr.state
tot = float(sum(jnp.sum(x.astype(jnp.float32)) for x in
                jax.tree.leaves(s["params"])))
print(json.dumps({"step": out["step"], "preempted": out["preempted"],
                  "psum": tot}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC

    # uninterrupted reference: 12 steps
    ref_out = subprocess.run([sys.executable, "-c", script, ck_a, "12", "fast"],
                             env=env, capture_output=True, text=True,
                             timeout=420)
    assert ref_out.returncode == 0, ref_out.stderr[-2000:]
    ref = json.loads(ref_out.stdout.strip().splitlines()[-1])
    assert ref["step"] == 12 and not ref["preempted"]

    # interrupted run: SIGTERM mid-flight
    proc = subprocess.Popen([sys.executable, "-c", script, ck_b, "12", "slow"],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    _time.sleep(25)   # let it warm up + take a few steps
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=240)
    assert proc.returncode == 0, err[-2000:]
    first = json.loads(out.strip().splitlines()[-1])
    assert first["preempted"] or first["step"] == 12, (first, err[-500:])
    if first["preempted"]:
        assert 0 < first["step"] < 12
        # resume and finish
        res_out = subprocess.run(
            [sys.executable, "-c", script, ck_b, "12", "fast"], env=env,
            capture_output=True, text=True, timeout=420)
        assert res_out.returncode == 0, res_out.stderr[-2000:]
        final = json.loads(res_out.stdout.strip().splitlines()[-1])
        assert final["step"] == 12
        psum = final["psum"]
    else:
        psum = first["psum"]
    # bit-reproducible across the preemption boundary
    assert abs(psum - ref["psum"]) < 1e-3, (psum, ref["psum"])
