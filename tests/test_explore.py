"""Design-space explorer: Pareto dominance, the sweep artifact schema, and
constrained autotune.

The sweep tests run the same deterministic 4-point smoke space CI sweeps
(``benchmarks/run.py --sweep --smoke``) and assert the ``BENCH_pareto.json``
schema plus dominance-correctness of the extracted front — timing values
themselves are machine-dependent and never asserted."""

import json
import math

import pytest

from repro import explore
from repro.core.fixed_point import FXP_4_8, FXP_8_16, FixedPointConfig
from repro.core.qlstm import QLSTMConfig

# ---------------------------------------------------------------------------
# Pareto dominance / front extraction (pure, no jax)
# ---------------------------------------------------------------------------

MAXMIN = {"gops": "max", "mse": "min"}


def test_dominates_basic_and_senses():
    a = {"gops": 2.0, "mse": 0.1}
    b = {"gops": 1.0, "mse": 0.2}
    assert explore.dominates(a, b, MAXMIN)
    assert not explore.dominates(b, a, MAXMIN)
    # better on one axis, worse on the other: neither dominates
    c = {"gops": 1.0, "mse": 0.05}
    assert not explore.dominates(a, c, MAXMIN)
    assert not explore.dominates(c, a, MAXMIN)


def test_dominates_ties():
    a = {"gops": 2.0, "mse": 0.1}
    same = dict(a)
    assert not explore.dominates(a, same, MAXMIN)
    assert not explore.dominates(same, a, MAXMIN)
    # equal on one objective, strictly better on the other: dominates
    better = {"gops": 2.0, "mse": 0.05}
    assert explore.dominates(better, a, MAXMIN)
    assert not explore.dominates(a, better, MAXMIN)


def test_pareto_front_hand_built_2d():
    pts = [
        {"gops": 3.0, "mse": 0.3},   # front
        {"gops": 2.0, "mse": 0.1},   # front
        {"gops": 1.0, "mse": 0.2},   # dominated by the one above
        {"gops": 3.0, "mse": 0.3},   # duplicate of a front point: kept
        {"gops": 0.5, "mse": 0.4},   # dominated by everything
    ]
    idx = explore.pareto_indices(pts, MAXMIN)
    assert idx == [0, 1, 3]
    assert explore.pareto_front(pts, MAXMIN) == [pts[0], pts[1], pts[3]]


def test_pareto_front_three_objectives():
    obj = {"gops": "max", "gops_w": "max", "mse": "min"}
    pts = [
        {"gops": 3.0, "gops_w": 1.0, "mse": 0.30},  # fastest
        {"gops": 1.0, "gops_w": 3.0, "mse": 0.30},  # most efficient
        {"gops": 1.0, "gops_w": 1.0, "mse": 0.01},  # most accurate
        {"gops": 1.0, "gops_w": 1.0, "mse": 0.30},  # dominated by all three
    ]
    assert explore.pareto_indices(pts, obj) == [0, 1, 2]
    # dropping the accuracy objective collapses the accurate point too
    assert explore.pareto_indices(pts, {"gops": "max", "gops_w": "max"}) \
        == [0, 1]


def test_pareto_front_excludes_non_finite():
    pts = [
        {"gops": float("nan"), "mse": 0.0},   # failed measurement
        {"gops": float("inf"), "mse": 0.1},   # bogus timer
        {"gops": 1.0, "mse": 0.2},
    ]
    assert explore.pareto_indices(pts, MAXMIN) == [2]


def test_dominates_rejects_bad_sense():
    with pytest.raises(ValueError, match="sense"):
        explore.dominates({"g": 1}, {"g": 2}, {"g": "maximize"})


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_search_space_size_grid_and_sample():
    s = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16),
                            alu_mode=("pipelined", "per_step"),
                            hidden_size=(8, 20))
    assert s.size == 8
    grid = list(s.grid())
    assert len(grid) == 8 and len({p.label for p in grid}) == 8
    sampled = s.sample(3, seed=0)
    assert len(sampled) == 3 and len(set(sampled)) == 3
    assert s.sample(3, seed=0) == sampled          # deterministic
    assert set(s.sample(99, seed=1)) == set(grid)  # n >= size: whole grid
    # singletons auto-wrap
    assert explore.SearchSpace(hidden_size=16).hidden_size == (16,)


def test_search_space_validation():
    with pytest.raises(ValueError, match="hs_method"):
        explore.SearchSpace(hs_method=("bogus",))
    with pytest.raises(ValueError, match="no choices"):
        explore.SearchSpace(batch=())
    with pytest.raises(ValueError, match="positive ints"):
        explore.SearchSpace(hidden_size=(0,))


def test_point_configs_and_roundtrip():
    p = next(iter(explore.SearchSpace(fxp=FXP_8_16, alu_mode="per_step",
                                      hidden_size=12, batch=7).grid()))
    base = QLSTMConfig(input_size=3, seq_len=9)
    model, accel = p.configs(base)
    assert model.hidden_size == 12 and model.input_size == 3 \
        and model.seq_len == 9
    assert accel.fxp == FXP_8_16 and accel.alu_mode == "per_step"
    from repro.explore.space import point_from_config
    assert point_from_config(p.asdict()) == p
    assert isinstance(point_from_config(p.asdict()).fxp, FixedPointConfig)


# ---------------------------------------------------------------------------
# The smoke sweep: schema + dominance correctness (the CI artifact)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_payload(tmp_path_factory):
    """One shared ``--sweep --smoke`` run, through the benchmark writer so
    the on-disk artifact is what gets schema-checked."""
    from benchmarks.bench_pareto import write_sweep
    out = tmp_path_factory.mktemp("sweep") / "BENCH_pareto.json"
    write_sweep(str(out), smoke=True, iters=2)
    with open(out) as f:
        return json.load(f)


def test_smoke_sweep_schema(smoke_payload):
    p = smoke_payload
    assert p["suite"] == "pareto"
    assert p["schema_version"] == explore.SCHEMA_VERSION
    assert p["mode"] == "grid"
    assert isinstance(p["seed"], int)
    assert set(p["space"]) == set(explore.AXES)
    assert all(v in ("max", "min") for v in p["objectives"].values())
    assert len(p["points"]) >= 4
    for r in p["points"]:
        assert set(r) >= {"label", "config", "status", "pareto"}
        assert set(r["config"]) == set(explore.AXES)
        if r["status"] == "ok":
            assert set(r["metrics"]) >= {
                "us_per_wave", "samples_per_s", "throughput_gops",
                "gops_per_watt", "total_w", "int_float_mse",
                "int_float_max_abs", "weight_bytes"}
            assert r["plan"]["backend"] in ("ref", "pallas", "xla")
            assert all(math.isfinite(v) for v in r["metrics"].values()
                       if isinstance(v, float))


def test_smoke_sweep_front_dominance_correct(smoke_payload):
    p = smoke_payload
    ok = [r for r in p["points"] if r["status"] == "ok"]
    assert len(ok) >= 4
    front = [r for r in ok if r["pareto"]]
    assert front and sorted(p["front"]) == sorted(r["label"] for r in front)
    obj = p["objectives"]
    for r in front:                      # nothing dominates a front point
        assert not any(explore.dominates(o["metrics"], r["metrics"], obj)
                       for o in ok)
    for r in ok:                         # every non-front point is dominated
        if not r["pareto"]:
            assert any(explore.dominates(f["metrics"], r["metrics"], obj)
                       for f in front), r["label"]


def test_sweep_records_unsupported_backend_instead_of_raising():
    # per-step ALU is exactly what the fused engines refuse: explicit
    # backend=pallas must surface as an 'unsupported' row, not an exception
    space = explore.SearchSpace(alu_mode="per_step", backend="pallas",
                                batch=4)
    payload = explore.sweep(space, iters=1)
    (row,) = payload["points"]
    assert row["status"] == "unsupported" and "pallas" in row["reason"]
    assert payload["front"] == [] and row["pareto"] is False


def test_sweep_respects_base_model_and_eval_x():
    import numpy as np
    base = QLSTMConfig(input_size=2, seq_len=4)
    space = explore.SearchSpace(backend="ref", batch=4, hidden_size=8)
    x = np.zeros((3, 4, 2), np.float32)
    payload = explore.sweep(space, base, iters=1, eval_x=x)
    (row,) = payload["points"]
    assert row["status"] == "ok"
    with pytest.raises(ValueError, match="windows"):
        explore.sweep(space, base, iters=1,
                      eval_x=np.zeros((3, 6, 1), np.float32))


# ---------------------------------------------------------------------------
# autotune: constrained argmax on the feasible front (ref backend)
# ---------------------------------------------------------------------------

def test_autotune_constraint_satisfaction_ref_backend():
    import repro
    space = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16), backend="ref",
                                batch=8)
    # (8,16) is ~256x more accurate; bound int_float_mse so only it is
    # feasible regardless of which format happens to measure faster.
    session = explore.autotune(
        space=space, iters=2,
        constraints={"int_float_mse": (None, 1e-4)})
    assert isinstance(session, repro.Accelerator)
    assert session.accel.fxp == FXP_8_16
    assert session.plan["backend"] == "ref"
    assert session.qparams is not None     # ready to infer/serve

    s = session.autotune_summary
    assert s["best"]["label"] in s["front"]
    assert s["best"]["metrics"]["int_float_mse"] <= 1e-4
    # the winner maximises the objective over the feasible front
    feasible = [r for r in s["sweep"]["points"]
                if r["status"] == "ok"
                and r["metrics"]["int_float_mse"] <= 1e-4]
    best_val = max(r["metrics"]["gops_per_watt"] for r in feasible)
    assert s["best"]["metrics"]["gops_per_watt"] == best_val

    # and the built session actually runs the winning configuration
    import jax
    y = session.infer(jax.random.normal(jax.random.key(0), (4, 6, 1)),
                      path="int")
    assert y.shape == (4, 1)


def test_autotune_infeasible_constraints_raise():
    space = explore.SearchSpace(backend="ref", batch=4)
    with pytest.raises(ValueError, match="no feasible point"):
        explore.autotune(space=space, iters=1,
                         constraints={"samples_per_s": (1e18, None)})


def test_autotune_reuses_payload_without_resweeping():
    import jax
    import repro
    from repro.explore.space import point_from_config

    space = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16), backend="ref",
                                batch=8)
    payload = explore.sweep(space, iters=2, seed=3)
    assert payload["seed"] == 3
    calls = []
    session = explore.autotune(payload=payload, objective="int_float_mse",
                               log=calls.append)
    # objective is cost-like -> minimised -> the (8,16) point wins
    assert session.accel.fxp == FXP_8_16
    assert session.autotune_summary["sense"] == "min"
    assert not any("/2]" in c for c in calls)   # no sweep progress lines
    # rebuilt with the PAYLOAD's seed: the deployed weights are the ones
    # the stored metrics were measured on
    cfgs = point_from_config(session.autotune_summary["best"]["config"])
    want = repro.build(*cfgs.configs(), seed=3).params
    assert all(bool((a == b).all()) for a, b in
               zip(jax.tree.leaves(session.params), jax.tree.leaves(want)))


def test_sweep_and_autotune_validate_metric_names_upfront():
    space = explore.SearchSpace(backend="ref", batch=4)
    with pytest.raises(ValueError, match="unknown objective.*gops_per_wat"):
        explore.sweep(space, objectives={"gops_per_wat": "max"})
    with pytest.raises(ValueError, match="sense"):
        explore.sweep(space, objectives={"gops_per_watt": "maximize"})
    with pytest.raises(ValueError, match="unknown objective"):
        explore.autotune(space=space, objective="latency")
    with pytest.raises(ValueError, match="unknown constraint"):
        explore.autotune(space=space, constraints={"watts": (None, 1.0)})


def test_sweep_base_accel_is_honoured():
    from repro.core.accelerator import AcceleratorConfig
    space = explore.SearchSpace(backend="ref", batch=4)
    payload = explore.sweep(space, None, AcceleratorConfig(ht_max=0.5),
                            iters=1)
    (row,) = payload["points"]
    assert row["status"] == "ok"
    session = explore.autotune(space=space, iters=1,
                               accel=AcceleratorConfig(ht_max=0.5))
    assert session.model.acts.ht_max == 0.5

# ---------------------------------------------------------------------------
# ExploreError: empty/eliminated fronts fail loudly, naming the eliminator
# ---------------------------------------------------------------------------

def test_pareto_front_of_nothing_raises_explore_error():
    with pytest.raises(explore.ExploreError, match="0 measurements"):
        explore.pareto_front([], MAXMIN)
    assert issubclass(explore.ExploreError, ValueError)   # old catches work


def test_pareto_front_all_non_finite_raises_explore_error():
    pts = [{"gops": float("nan"), "mse": 0.1},
           {"gops": float("inf"), "mse": 0.2}]
    with pytest.raises(explore.ExploreError, match="non-finite"):
        explore.pareto_indices(pts, MAXMIN)


def test_dominates_missing_metric_names_it():
    with pytest.raises(explore.ExploreError, match="mse"):
        explore.dominates({"gops": 3.0}, {"gops": 2.0, "mse": 0.1}, MAXMIN)


def test_constrained_front_raises_naming_the_constraint():
    slo = explore.parse_constraint("p99_ms<=5")
    pts = [{"samples_per_s": 10.0, "p99_ms": 9.0},
           {"samples_per_s": 99.0, "p99_ms": 6.0}]
    with pytest.raises(explore.ExploreError, match=r"p99_ms<=5"):
        explore.constrained_pareto_front(
            pts, {"samples_per_s": "max"}, constraint=slo)
    # the closest miss is named by magnitude (6 - 5 = 1)
    try:
        explore.constrained_pareto_front(
            pts, {"samples_per_s": "max"}, constraint=slo)
    except explore.ExploreError as e:
        assert "1" in str(e)


def test_constrained_front_filters_violators_keeps_feasible():
    slo = explore.parse_constraint("p99_ms<=5")
    pts = [{"samples_per_s": 10.0, "p99_ms": 4.0},
           {"samples_per_s": 99.0, "p99_ms": 6.0},   # fastest but violating
           {"samples_per_s": 5.0, "p99_ms": 1.0}]
    front = explore.constrained_pareto_front(
        pts, {"samples_per_s": "max", "p99_ms": "min"}, constraint=slo)
    assert pts[1] not in front
    assert pts[0] in front and pts[2] in front


# ---------------------------------------------------------------------------
# SLO parsing
# ---------------------------------------------------------------------------

def test_slo_parse_ok_violation_roundtrip():
    slo = explore.parse_constraint("p99_ms<=5")
    assert slo.ok({"p99_ms": 5.0}) and not slo.ok({"p99_ms": 5.01})
    assert slo.violation({"p99_ms": 7.5}) == 2.5
    assert slo.violation({"p99_ms": 2.0}) == 0.0
    assert slo.violation({}) == float("inf")
    assert explore.parse_constraint(slo.describe()) == slo
    multi = explore.parse_constraint("p99_ms<=5,samples_per_s>=100")
    assert multi.ok({"p99_ms": 4.0, "samples_per_s": 200.0})
    assert not multi.ok({"p99_ms": 4.0, "samples_per_s": 50.0})
    assert multi.violation({"p99_ms": 6.0, "samples_per_s": 50.0}) == 51.0


def test_slo_parse_rejects_garbage():
    with pytest.raises(ValueError, match="cannot parse"):
        explore.parse_constraint("p99_ms ~ 5")
    with pytest.raises(ValueError, match="unknown SLO metric"):
        explore.parse_constraint("p99<=5")
    with pytest.raises(ValueError, match="empty"):
        explore.parse_constraint(" , ")


# ---------------------------------------------------------------------------
# hypothesis property: the constrained front never admits an SLO violator
# while a feasible point exists
# ---------------------------------------------------------------------------

from tests.hypothesis_compat import given, settings, st  # noqa: E402

metrics_strategy = st.lists(
    st.fixed_dictionaries({
        "samples_per_s": st.floats(1.0, 1e6, allow_nan=False),
        "p99_ms": st.floats(0.01, 100.0, allow_nan=False),
    }), min_size=1, max_size=12)


@pytest.mark.property
@settings(max_examples=60, deadline=None)
@given(pts=metrics_strategy, bound=st.floats(0.01, 100.0, allow_nan=False))
def test_property_constrained_front_respects_slo(pts, bound):
    slo = explore.SLO("p99_ms", "<=", bound)
    feasible_exists = any(slo.ok(p) for p in pts)
    objectives = {"samples_per_s": "max", "p99_ms": "min"}
    if not feasible_exists:
        with pytest.raises(explore.ExploreError):
            explore.constrained_pareto_front(pts, objectives, constraint=slo)
        return
    front = explore.constrained_pareto_front(pts, objectives, constraint=slo)
    assert front
    for p in front:
        assert slo.ok(p), "front admitted an SLO violator"
    # and no feasible point dominates a front member
    feas = [p for p in pts if slo.ok(p)]
    for f in front:
        assert not any(explore.dominates(o, f, objectives) for o in feas)


# ---------------------------------------------------------------------------
# serving axes: declarative prune agrees with the imperative serving plan
# ---------------------------------------------------------------------------

def test_space_gains_serving_axes_and_labels():
    assert "replicas" in explore.AXES and "state_residency" in explore.AXES
    sp = explore.SearchSpace(backend="xla", batch=4, replicas=(1, 2),
                             state_residency=("auto", "host"))
    labels = {p.label for p in sp.grid()}
    assert len(labels) == 4
    assert any(lab.endswith("_r2_host") for lab in labels)
    base = next(iter(explore.SearchSpace(backend="xla", batch=4).grid()))
    assert "_r" not in base.label and not base.label.endswith("_host")
    from repro.explore.space import point_from_config
    for p in sp.grid():
        assert point_from_config(p.asdict()) == p
    with pytest.raises(ValueError, match="state_residency"):
        explore.SearchSpace(state_residency=("gpu",))
    with pytest.raises(ValueError, match="positive ints"):
        explore.SearchSpace(replicas=(0,))


def test_prune_and_serving_plan_agree_across_the_axes():
    """The declarative constraint tree and the imperative serving_plan are
    two forms of one contract: a point prunes iff its plan raises, with
    matching rule names."""
    import itertools
    from repro.explore.constraints import InfeasiblePoint
    from repro.explore.serving_objective import serving_plan
    from repro.explore.space import Point

    sp = explore.SearchSpace(backend=("auto", "ref", "xla", "pallas"),
                             batch=4, hidden_size=8,
                             cell=("lstm", "gru", "rglru"),
                             replicas=(1, 3),
                             state_residency=("auto", "host", "device"),
                             alu_mode=("pipelined", "per_step"))
    checked = 0
    for p in sp.grid():
        reason = sp.feasible(p)
        try:
            pl = serving_plan(p)
            planned = None
        except InfeasiblePoint as e:
            planned = str(e)
        if reason is None:
            assert planned is None, (p.label, planned)
            assert pl["replicas"] == p.replicas
            assert pl["state_residency"] in ("host", "device")
        else:
            assert planned is not None, (p.label, reason)
            # same rule fired, modulo the declarative/imperative prefix
            decl = reason.split(":", 1)[0]
            imp = planned.split(":", 1)[0]
            assert {("backend_supported", "backend"),
                    ("device_residency", "state_residency"),
                    ("replicas_fit_devices", "replicas")} >= {(decl, imp)} \
                or decl.startswith(imp) or imp in decl, (decl, imp)
        checked += 1
    assert checked == sp.size == 4 * 3 * 2 * 3 * 2


def test_constraint_node_composition_operators():
    from repro.explore.constraints import AllOf, AnyOf, Not, Rule

    yes = Rule("yes", lambda *a: None)
    no = Rule("no", lambda *a: "bad value")
    assert (yes & no).check(None, None, None) == "no: bad value"
    assert (yes | no).check(None, None, None) is None
    assert (~yes).check(None, None, None) == \
        "~yes: point satisfies the negated rule"
    assert (~no).check(None, None, None) is None
    both = AllOf((yes, AnyOf((no, yes))))
    assert both.check(None, None, None) is None
    assert "no" in AnyOf((no, no)).check(None, None, None)


def test_sweep_all_infeasible_records_front_reason_no_builds():
    # device residency on a cell with no fused kernel: every point pruned
    # before measurement; the sweep reports WHY the front is empty.
    space = explore.SearchSpace(backend="xla", batch=4, cell="gru",
                                state_residency="device")
    payload = explore.sweep(space, scenario=explore.ServingScenario(
        streams=2, windows_per_stream=1), strategy="full")
    (row,) = payload["points"]
    assert row["status"] == "infeasible"
    assert "device" in row["reason"]
    assert payload["front"] == []
    assert payload["front_reason"] is not None
    assert "0 of 1 points" in payload["front_reason"]


def test_halving_without_scenario_is_rejected():
    space = explore.SearchSpace(backend="ref", batch=4)
    with pytest.raises(ValueError, match="halving"):
        explore.sweep(space, strategy="halving")
    with pytest.raises(ValueError, match="SLO"):
        explore.sweep(space, constraint="p99_ms<=5")


# ---------------------------------------------------------------------------
# live serving-aware search: schema v2, SLO satisfaction, determinism
# (a tiny 2-point space so the battery stays tier-1 fast)
# ---------------------------------------------------------------------------

SERVING_SLO = "p99_ms<=60000"          # generous: CI runners are slow


@pytest.fixture(scope="module")
def halving_payload():
    """One shared serving halving sweep over a 2-point space whose ranking
    is robust (batch 1 vs 16 differ by an order of magnitude)."""
    space = explore.SearchSpace(backend="xla", batch=(1, 16), hidden_size=8,
                                num_layers=1)
    scenario = explore.ServingScenario(streams=3, windows_per_stream=3,
                                       deadline_ms=60000.0, name="t")
    return explore.sweep(space, scenario=scenario, strategy="halving",
                         objective="samples_per_s", constraint=SERVING_SLO,
                         eta=2, seed=0)


def test_serving_sweep_schema_v2(halving_payload):
    p = halving_payload
    assert p["schema_version"] == 2
    assert p["strategy"] == "halving"
    assert p["constraint"] == "p99_ms<=60000"
    assert p["scenario"]["streams"] == 3
    assert p["objective"] == "samples_per_s"
    tr = p["halving"]
    assert tr["sizes"] == [2, 1]
    assert tr["fractions"] == [0.5, 1.0]
    assert tr["total_measurements"] == 3 <= tr["budget_bound"]
    assert len(tr["rungs"]) == 2
    for r in p["points"]:
        assert r["status"] == "ok"
        m = r["metrics"]
        assert set(m) == set(explore.SERVING_METRIC_KEYS)
        op = r["operating_point"]
        assert set(op) >= {"scenario", "rung", "fraction", "final",
                           "p99_ms", "deadline_miss_rate", "feasible"}
        assert op["p99_ms"] == m["p99_ms"]
    finals = [r for r in p["points"] if r["operating_point"]["final"]]
    assert len(finals) == 1            # only the rung-1 survivor is final
    assert finals[0]["operating_point"]["fraction"] == 1.0
    # non-final rows were measured on the truncated scenario
    truncated = [r for r in p["points"] if not r["operating_point"]["final"]]
    assert truncated and all(
        r["operating_point"]["scenario"]["windows_per_stream"] == 2
        for r in truncated)
    # the front only ever contains final-rung points
    assert set(p["front"]) <= {r["label"] for r in finals}


def test_serving_autotune_satisfies_slo_on_remeasure(halving_payload):
    import repro
    session = explore.autotune(payload=halving_payload,
                               objective="samples_per_s",
                               constraint=SERVING_SLO)
    assert isinstance(session, repro.Accelerator)
    s = session.autotune_summary
    assert s["strategy"] == "halving"
    assert s["constraint"] == "p99_ms<=60000"
    assert s["operating_point"]["final"] is True
    assert s["operating_point"]["feasible"] is True
    assert s["halving"]["winner_label"] == s["best"]["label"]
    # re-measure the winner at the recorded operating point: the deployed
    # session must satisfy the SLO it was selected under
    scenario = explore.ServingScenario.from_dict(halving_payload["scenario"])
    remeasured = session.measure_scenario(scenario)
    slo = explore.parse_constraint(SERVING_SLO)
    assert slo.ok(remeasured), remeasured


def test_serving_autotune_impossible_slo_names_it(halving_payload):
    with pytest.raises(explore.ExploreError,
                       match=r"no feasible point.*p99_ms<=0.0001"):
        explore.autotune(payload=halving_payload,
                         constraint="p99_ms<=0.0001")


def test_serving_halving_same_seed_identical_traces(halving_payload):
    """The acceptance property: a second same-seed sweep reproduces the
    rung-promotion trace and picks the same config."""
    space = explore.SearchSpace(backend="xla", batch=(1, 16), hidden_size=8,
                                num_layers=1)
    scenario = explore.ServingScenario(streams=3, windows_per_stream=3,
                                       deadline_ms=60000.0, name="t")
    p2 = explore.sweep(space, scenario=scenario, strategy="halving",
                       objective="samples_per_s", constraint=SERVING_SLO,
                       eta=2, seed=0)
    strip = lambda tr: [(r["rung"], r["fraction"], r["measured"],  # noqa: E731
                         r["promoted"]) for r in tr["rungs"]]
    assert strip(p2["halving"]) == strip(halving_payload["halving"])
    assert p2["halving"]["winner_label"] == \
        halving_payload["halving"]["winner_label"]
    assert p2["front"] == halving_payload["front"]


def test_measure_scenario_session_api():
    import repro
    sess = repro.build(QLSTMConfig(hidden_size=8),
                       seed=0).quantize()
    sc = explore.ServingScenario(streams=2, windows_per_stream=2,
                                 deadline_ms=60000.0)
    m = sess.measure_scenario(sc)
    assert set(m) == set(explore.SERVING_METRIC_KEYS)
    assert m["samples_per_s"] > 0
    assert m["waves"] >= 1
