"""Design-space explorer: Pareto dominance, the sweep artifact schema, and
constrained autotune.

The sweep tests run the same deterministic 4-point smoke space CI sweeps
(``benchmarks/run.py --sweep --smoke``) and assert the ``BENCH_pareto.json``
schema plus dominance-correctness of the extracted front — timing values
themselves are machine-dependent and never asserted."""

import json
import math

import pytest

from repro import explore
from repro.core.fixed_point import FXP_4_8, FXP_8_16, FixedPointConfig
from repro.core.qlstm import QLSTMConfig

# ---------------------------------------------------------------------------
# Pareto dominance / front extraction (pure, no jax)
# ---------------------------------------------------------------------------

MAXMIN = {"gops": "max", "mse": "min"}


def test_dominates_basic_and_senses():
    a = {"gops": 2.0, "mse": 0.1}
    b = {"gops": 1.0, "mse": 0.2}
    assert explore.dominates(a, b, MAXMIN)
    assert not explore.dominates(b, a, MAXMIN)
    # better on one axis, worse on the other: neither dominates
    c = {"gops": 1.0, "mse": 0.05}
    assert not explore.dominates(a, c, MAXMIN)
    assert not explore.dominates(c, a, MAXMIN)


def test_dominates_ties():
    a = {"gops": 2.0, "mse": 0.1}
    same = dict(a)
    assert not explore.dominates(a, same, MAXMIN)
    assert not explore.dominates(same, a, MAXMIN)
    # equal on one objective, strictly better on the other: dominates
    better = {"gops": 2.0, "mse": 0.05}
    assert explore.dominates(better, a, MAXMIN)
    assert not explore.dominates(a, better, MAXMIN)


def test_pareto_front_hand_built_2d():
    pts = [
        {"gops": 3.0, "mse": 0.3},   # front
        {"gops": 2.0, "mse": 0.1},   # front
        {"gops": 1.0, "mse": 0.2},   # dominated by the one above
        {"gops": 3.0, "mse": 0.3},   # duplicate of a front point: kept
        {"gops": 0.5, "mse": 0.4},   # dominated by everything
    ]
    idx = explore.pareto_indices(pts, MAXMIN)
    assert idx == [0, 1, 3]
    assert explore.pareto_front(pts, MAXMIN) == [pts[0], pts[1], pts[3]]


def test_pareto_front_three_objectives():
    obj = {"gops": "max", "gops_w": "max", "mse": "min"}
    pts = [
        {"gops": 3.0, "gops_w": 1.0, "mse": 0.30},  # fastest
        {"gops": 1.0, "gops_w": 3.0, "mse": 0.30},  # most efficient
        {"gops": 1.0, "gops_w": 1.0, "mse": 0.01},  # most accurate
        {"gops": 1.0, "gops_w": 1.0, "mse": 0.30},  # dominated by all three
    ]
    assert explore.pareto_indices(pts, obj) == [0, 1, 2]
    # dropping the accuracy objective collapses the accurate point too
    assert explore.pareto_indices(pts, {"gops": "max", "gops_w": "max"}) \
        == [0, 1]


def test_pareto_front_excludes_non_finite():
    pts = [
        {"gops": float("nan"), "mse": 0.0},   # failed measurement
        {"gops": float("inf"), "mse": 0.1},   # bogus timer
        {"gops": 1.0, "mse": 0.2},
    ]
    assert explore.pareto_indices(pts, MAXMIN) == [2]


def test_dominates_rejects_bad_sense():
    with pytest.raises(ValueError, match="sense"):
        explore.dominates({"g": 1}, {"g": 2}, {"g": "maximize"})


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_search_space_size_grid_and_sample():
    s = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16),
                            alu_mode=("pipelined", "per_step"),
                            hidden_size=(8, 20))
    assert s.size == 8
    grid = list(s.grid())
    assert len(grid) == 8 and len({p.label for p in grid}) == 8
    sampled = s.sample(3, seed=0)
    assert len(sampled) == 3 and len(set(sampled)) == 3
    assert s.sample(3, seed=0) == sampled          # deterministic
    assert set(s.sample(99, seed=1)) == set(grid)  # n >= size: whole grid
    # singletons auto-wrap
    assert explore.SearchSpace(hidden_size=16).hidden_size == (16,)


def test_search_space_validation():
    with pytest.raises(ValueError, match="hs_method"):
        explore.SearchSpace(hs_method=("bogus",))
    with pytest.raises(ValueError, match="no choices"):
        explore.SearchSpace(batch=())
    with pytest.raises(ValueError, match="positive ints"):
        explore.SearchSpace(hidden_size=(0,))


def test_point_configs_and_roundtrip():
    p = next(iter(explore.SearchSpace(fxp=FXP_8_16, alu_mode="per_step",
                                      hidden_size=12, batch=7).grid()))
    base = QLSTMConfig(input_size=3, seq_len=9)
    model, accel = p.configs(base)
    assert model.hidden_size == 12 and model.input_size == 3 \
        and model.seq_len == 9
    assert accel.fxp == FXP_8_16 and accel.alu_mode == "per_step"
    from repro.explore.space import point_from_config
    assert point_from_config(p.asdict()) == p
    assert isinstance(point_from_config(p.asdict()).fxp, FixedPointConfig)


# ---------------------------------------------------------------------------
# The smoke sweep: schema + dominance correctness (the CI artifact)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_payload(tmp_path_factory):
    """One shared ``--sweep --smoke`` run, through the benchmark writer so
    the on-disk artifact is what gets schema-checked."""
    from benchmarks.bench_pareto import write_sweep
    out = tmp_path_factory.mktemp("sweep") / "BENCH_pareto.json"
    write_sweep(str(out), smoke=True, iters=2)
    with open(out) as f:
        return json.load(f)


def test_smoke_sweep_schema(smoke_payload):
    p = smoke_payload
    assert p["suite"] == "pareto"
    assert p["schema_version"] == explore.SCHEMA_VERSION
    assert p["mode"] == "grid"
    assert isinstance(p["seed"], int)
    assert set(p["space"]) == set(explore.AXES)
    assert all(v in ("max", "min") for v in p["objectives"].values())
    assert len(p["points"]) >= 4
    for r in p["points"]:
        assert set(r) >= {"label", "config", "status", "pareto"}
        assert set(r["config"]) == set(explore.AXES)
        if r["status"] == "ok":
            assert set(r["metrics"]) >= {
                "us_per_wave", "samples_per_s", "throughput_gops",
                "gops_per_watt", "total_w", "int_float_mse",
                "int_float_max_abs", "weight_bytes"}
            assert r["plan"]["backend"] in ("ref", "pallas", "xla")
            assert all(math.isfinite(v) for v in r["metrics"].values()
                       if isinstance(v, float))


def test_smoke_sweep_front_dominance_correct(smoke_payload):
    p = smoke_payload
    ok = [r for r in p["points"] if r["status"] == "ok"]
    assert len(ok) >= 4
    front = [r for r in ok if r["pareto"]]
    assert front and sorted(p["front"]) == sorted(r["label"] for r in front)
    obj = p["objectives"]
    for r in front:                      # nothing dominates a front point
        assert not any(explore.dominates(o["metrics"], r["metrics"], obj)
                       for o in ok)
    for r in ok:                         # every non-front point is dominated
        if not r["pareto"]:
            assert any(explore.dominates(f["metrics"], r["metrics"], obj)
                       for f in front), r["label"]


def test_sweep_records_unsupported_backend_instead_of_raising():
    # per-step ALU is exactly what the fused engines refuse: explicit
    # backend=pallas must surface as an 'unsupported' row, not an exception
    space = explore.SearchSpace(alu_mode="per_step", backend="pallas",
                                batch=4)
    payload = explore.sweep(space, iters=1)
    (row,) = payload["points"]
    assert row["status"] == "unsupported" and "pallas" in row["reason"]
    assert payload["front"] == [] and row["pareto"] is False


def test_sweep_respects_base_model_and_eval_x():
    import numpy as np
    base = QLSTMConfig(input_size=2, seq_len=4)
    space = explore.SearchSpace(backend="ref", batch=4, hidden_size=8)
    x = np.zeros((3, 4, 2), np.float32)
    payload = explore.sweep(space, base, iters=1, eval_x=x)
    (row,) = payload["points"]
    assert row["status"] == "ok"
    with pytest.raises(ValueError, match="windows"):
        explore.sweep(space, base, iters=1,
                      eval_x=np.zeros((3, 6, 1), np.float32))


# ---------------------------------------------------------------------------
# autotune: constrained argmax on the feasible front (ref backend)
# ---------------------------------------------------------------------------

def test_autotune_constraint_satisfaction_ref_backend():
    import repro
    space = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16), backend="ref",
                                batch=8)
    # (8,16) is ~256x more accurate; bound int_float_mse so only it is
    # feasible regardless of which format happens to measure faster.
    session = explore.autotune(
        space=space, iters=2,
        constraints={"int_float_mse": (None, 1e-4)})
    assert isinstance(session, repro.Accelerator)
    assert session.accel.fxp == FXP_8_16
    assert session.plan["backend"] == "ref"
    assert session.qparams is not None     # ready to infer/serve

    s = session.autotune_summary
    assert s["best"]["label"] in s["front"]
    assert s["best"]["metrics"]["int_float_mse"] <= 1e-4
    # the winner maximises the objective over the feasible front
    feasible = [r for r in s["sweep"]["points"]
                if r["status"] == "ok"
                and r["metrics"]["int_float_mse"] <= 1e-4]
    best_val = max(r["metrics"]["gops_per_watt"] for r in feasible)
    assert s["best"]["metrics"]["gops_per_watt"] == best_val

    # and the built session actually runs the winning configuration
    import jax
    y = session.infer(jax.random.normal(jax.random.key(0), (4, 6, 1)),
                      path="int")
    assert y.shape == (4, 1)


def test_autotune_infeasible_constraints_raise():
    space = explore.SearchSpace(backend="ref", batch=4)
    with pytest.raises(ValueError, match="no feasible point"):
        explore.autotune(space=space, iters=1,
                         constraints={"samples_per_s": (1e18, None)})


def test_autotune_reuses_payload_without_resweeping():
    import jax
    import repro
    from repro.explore.space import point_from_config

    space = explore.SearchSpace(fxp=(FXP_4_8, FXP_8_16), backend="ref",
                                batch=8)
    payload = explore.sweep(space, iters=2, seed=3)
    assert payload["seed"] == 3
    calls = []
    session = explore.autotune(payload=payload, objective="int_float_mse",
                               log=calls.append)
    # objective is cost-like -> minimised -> the (8,16) point wins
    assert session.accel.fxp == FXP_8_16
    assert session.autotune_summary["sense"] == "min"
    assert not any("/2]" in c for c in calls)   # no sweep progress lines
    # rebuilt with the PAYLOAD's seed: the deployed weights are the ones
    # the stored metrics were measured on
    cfgs = point_from_config(session.autotune_summary["best"]["config"])
    want = repro.build(*cfgs.configs(), seed=3).params
    assert all(bool((a == b).all()) for a, b in
               zip(jax.tree.leaves(session.params), jax.tree.leaves(want)))


def test_sweep_and_autotune_validate_metric_names_upfront():
    space = explore.SearchSpace(backend="ref", batch=4)
    with pytest.raises(ValueError, match="unknown objective.*gops_per_wat"):
        explore.sweep(space, objectives={"gops_per_wat": "max"})
    with pytest.raises(ValueError, match="sense"):
        explore.sweep(space, objectives={"gops_per_watt": "maximize"})
    with pytest.raises(ValueError, match="unknown objective"):
        explore.autotune(space=space, objective="latency")
    with pytest.raises(ValueError, match="unknown constraint"):
        explore.autotune(space=space, constraints={"watts": (None, 1.0)})


def test_sweep_base_accel_is_honoured():
    from repro.core.accelerator import AcceleratorConfig
    space = explore.SearchSpace(backend="ref", batch=4)
    payload = explore.sweep(space, None, AcceleratorConfig(ht_max=0.5),
                            iters=1)
    (row,) = payload["points"]
    assert row["status"] == "ok"
    session = explore.autotune(space=space, iters=1,
                               accel=AcceleratorConfig(ht_max=0.5))
    assert session.model.acts.ht_max == 0.5
