"""The serving reliability layer (PR 6): seeded chaos, guarded execution,
overload control.

The contract under test: a ``StreamServer`` under injected faults —
compute exceptions, latency spikes, state loss — keeps serving with zero
crashes; every stream untouched by state faults stays BIT-EXACT with the
concatenated-sequence oracle (retries and backend degradation change
latency, never results); every stream that was touched is FLAGGED
(``StreamResult.error`` / ``state_reset``), never silently wrong; and the
``faults`` block of ``metrics_summary()`` accounts for all of it."""

import threading
import time

import numpy as np
import pytest

import repro
from repro.core.qlstm import QLSTMConfig
from repro.serving import (ExecutionGuard, FaultConfig, FaultInjector,
                           InjectedFault, OverloadPolicy, ResiliencePolicy,
                           ServerOverloaded, ServingConfig, StreamServer,
                           WaveScheduler, WaveTimeout)

MODEL = QLSTMConfig(input_size=1, hidden_size=8, num_layers=2, seq_len=4)

FAST = ResiliencePolicy(max_retries=3, backoff_base_s=0.0)


@pytest.fixture(scope="module")
def sess():
    return repro.build(MODEL, seed=0).quantize()


def _windows(n, seed=0, t=4, m=1):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (n, t, m)).astype(np.float32)


def _oracle(sess, windows):
    """Per-window predictions of one stream run stateful on the bit-exact
    ref engine — the concatenated-sequence ground truth."""
    fn = sess.compiled_stateful("ref")
    state, ys = sess.init_state(1), []
    for w in windows:
        y, state = fn(w[None], state)
        ys.append(np.asarray(y)[0])
    return ys


def _run_chaos(sess, backend, seed=11, n_streams=6, k=3, policy=FAST,
               **rates):
    """One seeded chaos run: submit k windows on each of n_streams,
    drain, return ({sid: windows}, {sid: {seq: row}}, summary, injector)."""
    xs = {f"s{i}": _windows(k, seed=50 + i) for i in range(n_streams)}
    inj = FaultInjector(seed=seed, **rates)
    cfg = ServingConfig(batch=4, backend=backend, deadline_s=0.005,
                        resilience=policy)
    rows = {}
    with StreamServer(sess, cfg, fault_injector=inj) as srv:
        for w in range(k):
            for sid in xs:
                srv.submit(sid, xs[sid][w])
        for r in srv.drain(timeout=120):
            rows.setdefault(r.stream_id, {})[r.seq] = r
        summary = srv.metrics_summary()
    return xs, rows, summary, inj


def _check_partition(sess, xs, rows, inj):
    """The chaos post-conditions: survivors bit-exact, casualties flagged
    (and bit-exact up to their first flagged window)."""
    touched = inj.lost_streams | inj.corrupted_streams
    for sid, wins in xs.items():
        oracle = _oracle(sess, wins)
        got = rows[sid]
        assert sorted(got) == list(range(len(wins)))   # no window lost
        flagged = [q for q in sorted(got)
                   if (not got[q].ok) or got[q].state_reset]
        if not flagged and sid not in touched:
            for q in sorted(got):                      # survivor: bit-exact
                np.testing.assert_array_equal(got[q].y, oracle[q])
        else:
            first = flagged[0] if flagged else len(wins)
            for q in range(first):                     # clean prefix only
                np.testing.assert_array_equal(got[q].y, oracle[q])
            for q in sorted(got):                      # errors carry no y
                if not got[q].ok:
                    assert got[q].y is None and got[q].error


# ---------------------------------------------------------------------------
# The chaos matrix: fault rates x backends (the PR's acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
@pytest.mark.parametrize("rate", [0.0, 0.05, 0.2])
def test_chaos_wave_faults_absorbed_bit_exactly(sess, backend, rate):
    """Injected compute faults at 0/5/20% per attempt on every engine:
    retries absorb them, every stream completes, and every stream stays
    bit-exact with the oracle (faults change latency, never results)."""
    xs, rows, summary, inj = _run_chaos(sess, backend,
                                        wave_fault_rate=rate)
    _check_partition(sess, xs, rows, inj)
    f = summary["faults"]
    assert f["injected"]["wave_faults"] == inj.stats()["wave_faults"]
    if rate == 0.0:
        assert f["injected"]["wave_faults"] == 0 and f["retries"] == 0
    elif f["injected"]["wave_faults"] > 0:
        # Any injected fault forces at least one retry somewhere (a
        # 12-attempt full-ladder wipe-out at these rates is ~0).
        assert f["retries"] >= 1 and f["stream_errors"] == 0


@pytest.mark.slow
@pytest.mark.chaos
def test_acceptance_64_streams_20pct_faults_on_pallas(sess):
    """The PR's acceptance scenario: 64 streams through the fused pallas
    engine at a 20% per-attempt wave-fault rate — zero crashes, every
    window answered, every stream bit-exact, counters consistent with the
    injected schedule."""
    xs, rows, summary, inj = _run_chaos(sess, "pallas", seed=17,
                                        n_streams=64, k=2,
                                        wave_fault_rate=0.2)
    assert sum(len(by) for by in rows.values()) == 128
    _check_partition(sess, xs, rows, inj)
    f = summary["faults"]
    assert f["injected"] == inj.stats()
    assert f["stream_errors"] == 0 and f["sheds"] == 0
    if inj.stats()["wave_faults"]:
        assert f["retries"] >= 1


def test_chaos_injection_schedule_is_deterministic():
    """Same (seed, rates) -> the exact same raise/pass schedule; a
    different seed -> a different one (so chaos tests can assert exact
    counters)."""
    def schedule(seed):
        inj = FaultInjector(seed=seed, wave_fault_rate=0.3)
        fn = inj.wrap_fn(lambda: None)
        out = []
        for _ in range(64):
            try:
                fn()
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out, inj.stats()

    a, sa = schedule(7)
    b, sb = schedule(7)
    c, _ = schedule(8)
    assert a == b and sa == sb
    assert a != c
    assert sa["wave_faults"] == sum(a) and sa["attempts"] == 64


@pytest.mark.chaos
def test_chaos_state_loss_flags_reset_not_silence(sess):
    """Lost carries (a crashed replica): the stream's next window is
    computed from the reset state and MUST come back ``state_reset=True``;
    untouched streams stay bit-exact; the resets are counted."""
    xs, rows, summary, inj = _run_chaos(sess, "ref", seed=3, k=4,
                                        state_loss_rate=0.4)
    assert inj.stats()["state_losses"] > 0      # seed 3 does inject
    _check_partition(sess, xs, rows, inj)
    n_reset = sum(r.state_reset for by in rows.values()
                  for r in by.values())
    assert n_reset > 0
    # no false flags: a reset row only ever appears on a stream the
    # injector actually touched (a loss on a stream's LAST put leaves no
    # later window to observe it, so the converse need not hold)
    for sid, by in rows.items():
        if any(r.state_reset for r in by.values()):
            assert sid in inj.lost_streams
    assert summary["faults"]["state_resets"] == n_reset


@pytest.mark.chaos
def test_chaos_state_corruption_is_recorded(sess):
    """Corrupted carries are the one fault the server cannot flag (the
    codes are plausible); the injector records the victims so tests can
    exclude them — and untouched streams still verify."""
    xs, rows, summary, inj = _run_chaos(sess, "ref", seed=5, k=3,
                                        state_corrupt_rate=0.5)
    assert inj.stats()["state_corruptions"] > 0
    assert inj.corrupted_streams
    for sid, wins in xs.items():
        if sid in inj.corrupted_streams:
            continue
        oracle = _oracle(sess, wins)
        for q, r in rows[sid].items():
            assert r.ok
            np.testing.assert_array_equal(r.y, oracle[q])


def test_wave_failure_isolated_to_error_results(sess):
    """A wave that fails on EVERY engine (100% per-attempt fault rate, no
    retries) kills no thread: each window comes back as a structured
    ``compute_failed`` row, the server stays alive, and close() is
    clean."""
    xs, rows, summary, inj = _run_chaos(
        sess, "ref", n_streams=4, k=2, wave_fault_rate=1.0,
        policy=ResiliencePolicy(max_retries=0, backoff_base_s=0.0))
    n = sum(len(by) for by in rows.values())
    assert n == 8                                # every window answered
    for by in rows.values():
        for r in by.values():
            assert not r.ok and "compute_failed" in r.error
            assert "InjectedFault" in r.error
    f = summary["faults"]
    assert f["wave_failures"] > 0
    assert f["stream_errors"] == 8


def test_degradation_and_promotion_round_trip_server(sess):
    """The preferred engine fails -> the guard serves the wave on the next
    ladder engine and (after degrade_after failures) officially degrades;
    once the engine heals, a recovery probe promotes back.  Results stay
    bit-exact through the whole round trip."""
    wins = _windows(6, seed=77)
    oracle = _oracle(sess, wins)
    cfg = ServingConfig(
        batch=2, backend="ref", deadline_s=0.005,
        resilience=ResiliencePolicy(max_retries=0, backoff_base_s=0.0,
                                    degrade_after=1, promote_after=1))
    srv = StreamServer(sess, cfg)
    preferred, real_fn = srv._fns[0][0]
    broken = {"on": True}

    def flaky(*args, **kwargs):
        if broken["on"]:
            raise RuntimeError("simulated engine outage")
        return real_fn(*args, **kwargs)

    srv._fns[0][0] = (preferred, flaky)
    try:
        rows = []
        for w in range(3):                        # outage: waves degrade
            srv.submit("s", wins[w])
            rows += srv.drain(timeout=60)
        assert srv.metrics_summary()["faults"]["degraded"]
        assert srv.health()["status"] == "degraded"
        broken["on"] = False                      # engine heals
        for w in range(3, 6):                     # probe promotes back
            srv.submit("s", wins[w])
            rows += srv.drain(timeout=60)
        f = srv.metrics_summary()["faults"]
        assert f["degradations"] >= 1 and f["promotions"] >= 1
        assert f["backend"] == preferred and not f["degraded"]
        assert srv.health()["status"] == "ok"
        by = {r.seq: r for r in rows}
        for q in range(6):                        # the bit-exactness claim
            assert by[q].ok
            np.testing.assert_array_equal(by[q].y, oracle[q])
        # the outage waves were carried by a non-preferred engine
        assert {by[q].backend for q in range(3)} != {preferred}
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# ExecutionGuard unit tests (plain callables, no server)
# ---------------------------------------------------------------------------

def test_guard_retries_with_backoff_then_succeeds():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return x * 2

    g = ExecutionGuard(("a",), ResiliencePolicy(max_retries=2,
                                                backoff_base_s=0.0))
    out = g.run([("a", flaky)], 21)
    assert out.ok and out.value == 42 and out.backend == "a"
    assert out.retries == 2 and len(out.attempt_errors) == 2
    assert g.stats()["retries"] == 2 and g.stats()["wave_failures"] == 0


def test_guard_total_failure_reports_last_error():
    g = ExecutionGuard(("a", "b"), ResiliencePolicy(max_retries=0,
                                                    backoff_base_s=0.0))
    out = g.run([("a", lambda: 1 / 0), ("b", lambda: [][1])])
    assert not out.ok and out.value is None
    assert "IndexError" in out.error
    assert len(out.attempt_errors) == 2
    assert g.stats()["wave_failures"] == 1


def test_guard_timeout_abandons_attempt_and_degrades():
    """A hung attempt is abandoned at wave_timeout_s (never waited on) and
    the wave lands on the next ladder engine."""
    release = threading.Event()

    def hung(x):
        release.wait(5.0)
        return -1

    g = ExecutionGuard(("slow", "fast"), ResiliencePolicy(
        max_retries=0, backoff_base_s=0.0, wave_timeout_s=0.05))
    t0 = time.perf_counter()
    out = g.run([("slow", hung), ("fast", lambda x: x + 1)], 1)
    assert out.ok and out.value == 2 and out.backend == "fast"
    assert out.timeouts == 1
    assert time.perf_counter() - t0 < 2.0        # did not wait the 5 s
    assert g.stats()["timeouts"] == 1
    assert g.stats()["abandoned_attempts"] == 1
    release.set()
    g.close()


def test_guard_degrade_then_probe_then_promote():
    """The full ladder state machine on plain lambdas: degrade after
    ``degrade_after`` preferred failures, probe after ``promote_after``
    clean degraded waves, promote when the probe lands."""
    broken = {"on": True}

    def pallas(x):
        if broken["on"]:
            raise RuntimeError("down")
        return ("pallas", x)

    fns = [("pallas", pallas), ("xla", lambda x: ("xla", x))]
    g = ExecutionGuard(("pallas", "xla"), ResiliencePolicy(
        max_retries=0, backoff_base_s=0.0, degrade_after=2,
        promote_after=2))
    assert g.run(fns, 0).backend == "xla"        # carried, not yet degraded
    assert not g.degraded
    out = g.run(fns, 1)
    assert out.degraded and g.degraded           # second failure: degrade
    assert g.backend == "xla"
    assert g.run(fns, 2).backend == "xla"        # clean degraded wave 1
    assert g.run(fns, 3).backend == "xla"        # clean degraded wave 2
    broken["on"] = False
    out = g.run(fns, 4)                          # probe fires and lands
    assert out.promoted and out.backend == "pallas"
    assert not g.degraded and g.backend == "pallas"
    s = g.stats()
    assert s["degradations"] == 1 and s["promotions"] == 1
    assert s["probes"] == 1


def test_guard_failed_probe_resets_clean_streak():
    """A probe that fails must wait another promote_after clean waves
    before re-probing — not hammer the broken engine every wave."""
    fns = [("a", lambda: 1 / 0), ("b", lambda: "b")]
    g = ExecutionGuard(("a", "b"), ResiliencePolicy(
        max_retries=0, backoff_base_s=0.0, degrade_after=1,
        promote_after=2))
    g.run(fns)                                   # degrade to b
    assert g.degraded
    g.run(fns)                                   # clean 1
    g.run(fns)                                   # clean 2
    g.run(fns)                                   # probe -> a fails -> b
    assert g.stats()["probes"] == 1
    g.run(fns)                                   # clean 1 again: NO probe
    assert g.stats()["probes"] == 1
    g.run(fns)                                   # clean 2
    g.run(fns)                                   # probe #2
    assert g.stats()["probes"] == 2


def test_resilience_policy_validation_and_backoff():
    with pytest.raises(ValueError, match="max_retries"):
        ResiliencePolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_factor"):
        ResiliencePolicy(backoff_factor=0.5)
    with pytest.raises(ValueError, match="wave_timeout_s"):
        ResiliencePolicy(wave_timeout_s=0.0)
    with pytest.raises(ValueError, match="degrade_after"):
        ResiliencePolicy(degrade_after=0)
    p = ResiliencePolicy(backoff_base_s=0.01, backoff_factor=2.0,
                         backoff_max_s=0.05)
    assert p.backoff_s(1) == pytest.approx(0.01)
    assert p.backoff_s(2) == pytest.approx(0.02)
    assert p.backoff_s(10) == pytest.approx(0.05)     # capped


def test_fault_config_validation():
    with pytest.raises(ValueError, match="wave_fault_rate"):
        FaultConfig(wave_fault_rate=1.5)
    with pytest.raises(ValueError, match="latency_spike_s"):
        FaultConfig(latency_spike_s=-1.0)
    with pytest.raises(ValueError, match="not both"):
        FaultInjector(FaultConfig(), wave_fault_rate=0.1)


# ---------------------------------------------------------------------------
# Overload: admission control and deadline-aware shedding
# ---------------------------------------------------------------------------

def test_overload_policy_validation():
    with pytest.raises(ValueError, match="admission"):
        OverloadPolicy(admission="panic")
    with pytest.raises(ValueError, match="reject_miss_rate"):
        OverloadPolicy(reject_miss_rate=2.0)
    with pytest.raises(ValueError, match="shed_after_s"):
        OverloadPolicy(shed_after_s=0.0)


def test_admission_control_rejects_when_saturated():
    """With a wedged compute thread and a reject-mode policy, submit
    raises ServerOverloaded in bounded time instead of blocking forever."""
    release = threading.Event()
    sched = WaveScheduler(2, lambda wave: release.wait(10.0),
                          one_per_stream=False, deadline_s=None,
                          queue_depth=1, max_pending=2,
                          overload=OverloadPolicy(admission="reject",
                                                  reject_miss_rate=0.0))
    try:
        with pytest.raises(ServerOverloaded, match="admission rejected"):
            for i in range(64):                  # must trip well before 64
                sched.submit("s", np.zeros((4, 1), np.float32), lambda: 0)
        assert sched.stats()["rejections"] >= 1
    finally:
        release.set()
        sched.close(abandon=True)


def test_deadline_shedding_drops_hopeless_windows(sess):
    """Windows older than shed_after_s are dropped uncomputed: the client
    gets an ``error="shed"`` row, the stream's carry is dropped, and its
    NEXT window restarts flagged ``state_reset=True``."""
    wins = _windows(2, seed=9)
    cfg = ServingConfig(batch=8, deadline_s=None, backend="ref",
                        resilience=FAST,
                        overload=OverloadPolicy(admission="block",
                                                shed_after_s=0.05))
    with StreamServer(sess, cfg) as srv:
        srv.submit("s", wins[0])
        # batch 8, no deadline: the window can only leave pending by aging
        # past shed_after_s.
        deadline = time.perf_counter() + 10.0
        rows = []
        while not rows and time.perf_counter() < deadline:
            rows = srv.poll(timeout=0.2)
        assert len(rows) == 1
        (r,) = rows
        assert not r.ok and r.error == "shed" and r.y is None
        assert srv.metrics_summary()["faults"]["sheds"] == 1
        srv.submit("s", wins[1])
        srv.flush(timeout=30)
        (r2,) = srv.poll()
        assert r2.ok and r2.state_reset           # hole in the recurrence
        # windows[1] from the reset carry == a fresh stream's first window
        np.testing.assert_array_equal(
            r2.y, _oracle(sess, wins[1:2])[0])


def test_scheduler_error_clears_after_recovery():
    """A transient compute-thread exception does not poison every later
    wave: in-flight waves keep executing, and the first clean one clears
    the stored error (counted as a recovery) so submit/flush work again."""
    both_in = threading.Event()
    calls = []

    def execute(wave):
        both_in.wait(10.0)            # hold wave 1 until wave 2 is queued
        calls.append(wave)
        if len(calls) == 1:
            raise RuntimeError("transient device error")

    sched = WaveScheduler(1, execute, one_per_stream=False,
                          deadline_s=None, queue_depth=2)
    try:
        sched.submit("s", np.zeros((4, 1), np.float32), lambda: 0)
        sched.submit("s", np.zeros((4, 1), np.float32), lambda: 1)
        both_in.set()
        deadline = time.perf_counter() + 10.0
        while sched.stats()["recoveries"] == 0 \
                and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert sched.stats()["recoveries"] == 1   # error was set, then
        assert sched.error is None                # cleared by wave 2
        # the scheduler accepts work again — no stale re-raise
        sched.submit("s", np.zeros((4, 1), np.float32), lambda: 2)
        sched.flush(timeout=10)
    finally:
        sched.close(abandon=True)


def test_close_reports_leaked_threads():
    """close() with a wave wedged inside the datapath joins with a timeout
    and REPORTS the leaked thread instead of hanging forever."""
    release = threading.Event()
    sched = WaveScheduler(1, lambda wave: release.wait(30.0),
                          one_per_stream=False, deadline_s=None,
                          queue_depth=1)
    try:
        sched.submit("s", np.zeros((4, 1), np.float32), lambda: 0)
        time.sleep(0.1)                           # let compute pick it up
        leaked = sched.close(abandon=True, timeout=0.3)
        assert leaked == ["wave-compute"]
        assert sched.leaked_threads == ["wave-compute"]
    finally:
        release.set()


# ---------------------------------------------------------------------------
# submit() validation — malformed input never reaches the compute thread
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,match", [
    (np.full((4, 1), np.nan, np.float32), "NaN"),
    (np.zeros((4,), np.float32), r"\(T, M\)"),
    (np.zeros((4, 3), np.float32), "input_size"),
    (np.zeros((0, 1), np.float32), "input_size"),
    ([["not", "numbers"], ["at", "all"]], "not convertible"),
])
def test_submit_rejects_malformed_windows(sess, window, match):
    with StreamServer(sess, batch=2, deadline_s=0.005) as srv:
        with pytest.raises(ValueError, match=match):
            srv.submit("s", window)
        assert srv.metrics_summary()["waves"] == 0    # nothing computed
        assert srv.drain() == []


# ---------------------------------------------------------------------------
# Concurrency stress: submit/end_stream churn under chaos
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_concurrent_submit_end_stream_stress(sess):
    """4 client threads x 4 streams each, ending and reviving their
    streams mid-run, under a 5% injected fault rate: no deadlock, no
    crash, every submitted window answered exactly once, per-thread
    per-generation rows in submission order."""
    inj = FaultInjector(seed=21, wave_fault_rate=0.05)
    cfg = ServingConfig(batch=8, deadline_s=0.002, backend="ref",
                        resilience=FAST)
    srv = StreamServer(sess, cfg, fault_injector=inj)
    n_threads, n_streams, k = 4, 4, 6
    submitted = [0] * n_threads
    errors = []

    def client(ti):
        try:
            rng = np.random.default_rng(100 + ti)
            for sid_i in range(n_streams):
                sid = f"t{ti}-{sid_i}"
                for w in range(k):
                    win = rng.uniform(0, 1, (MODEL.seq_len, 1)) \
                             .astype(np.float32)
                    srv.submit(sid, win)
                    submitted[ti] += 1
                    if w == 2:                   # churn: end mid-stream
                        srv.end_stream(sid)
        except BaseException as e:               # surfaced to the assert
            errors.append(e)

    threads = [threading.Thread(target=client, args=(ti,))
               for ti in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive()
    assert not errors, errors
    rows = srv.drain(timeout=120)
    assert srv.close() == []
    assert len(rows) == sum(submitted) == n_threads * n_streams * k
    # per (stream, generation) the seq numbers the server handed out are
    # consecutive from 0 — no duplicate or lost (stream_id, seq) keys
    per_stream = {}
    for r in rows:
        per_stream.setdefault(r.stream_id, []).append(r.seq)
    for sid, seqs in per_stream.items():
        assert sorted(seqs) == sorted(list(range(3)) * 2), sid


# ---------------------------------------------------------------------------
# Surfaces: ladder API, metrics counters
# ---------------------------------------------------------------------------

def test_api_degradation_ladder(sess):
    ladder = sess.degradation_ladder()
    assert set(ladder) == {"ref", "xla", "pallas"}
    assert ladder[0] == sess.plan["stateful_backend"]
    assert sess.degradation_ladder(backend="xla")[0] == "xla"
    from repro import backends
    assert ladder == backends.degradation_ladder(sess.model, sess.accel)


def test_metrics_sink_named_counters():
    from repro.serving import MetricsSink
    m = MetricsSink()
    assert m.counters() == {}
    m.count("sheds")
    m.count("state_resets", 3)
    m.count("sheds")
    assert m.counters() == {"sheds": 2, "state_resets": 3}


def test_eviction_reset_is_flagged_on_returning_stream(sess):
    """Satellite 1 end-to-end: a stream LRU-evicted while a window is
    still in flight keeps its numbering, and the in-flight window —
    computed from the reset carry — comes back ``state_reset=True`` and
    bumps the counter (silent zeros before this PR)."""
    xs = {sid: _windows(2, seed=60 + i) for i, sid in enumerate("ab")}
    with StreamServer(sess, batch=2, deadline_s=None,
                      max_streams=1) as srv:
        # waves assemble oldest-first, one per stream: {a0,b0} then {a1,b1}
        for w in range(2):
            for sid in "ab":
                srv.submit(sid, xs[sid][w])
        rows = {(r.stream_id, r.seq): r for r in srv.drain(timeout=30)}
        # wave 1's scatter (capacity 1) evicted "a"; its in-flight second
        # window ran from the reset carry and says so
        assert rows[("a", 1)].state_reset
        assert rows[("a", 1)].ok                 # still a real prediction
        assert not rows[("a", 0)].state_reset    # first window: fresh is
        assert not rows[("b", 0)].state_reset    # normal, not a reset
        assert srv.metrics_summary()["faults"]["state_resets"] >= 1
        np.testing.assert_array_equal(           # == a fresh stream's first
            rows[("a", 1)].y, _oracle(sess, xs["a"][1:2])[0])


def test_wave_timeout_exception_type():
    assert issubclass(WaveTimeout, RuntimeError)
    assert issubclass(InjectedFault, RuntimeError)
    assert issubclass(ServerOverloaded, RuntimeError)


# ---------------------------------------------------------------------------
# Device-resident state under chaos (the slot table's partition contract)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_state_loss_on_device_store(sess):
    """The host-store loss drill replayed against the DEVICE slot table
    (backend=pallas resolves state_residency=device): a committed row
    whose slot is dropped flags the stream's next window ``state_reset``,
    survivors stay bit-exact with the oracle, and the per-wave (h, c)
    transfer counters stay at zero throughout the whole chaotic run."""
    xs, rows, summary, inj = _run_chaos(sess, "pallas", seed=3, k=4,
                                        state_loss_rate=0.15,
                                        wave_fault_rate=0.1)
    assert summary["state_residency"] == "device"
    assert summary["state"]["residency"] == "device"
    assert inj.stats()["state_losses"] > 0      # seed 3 does inject
    _check_partition(sess, xs, rows, inj)
    for sid, by in rows.items():
        if any(r.state_reset for r in by.values()):
            assert sid in inj.lost_streams
    t = summary["state_transfer"]
    assert t["to_device_bytes"] == 0 and t["from_device_bytes"] == 0
    assert t["slot_id_bytes"] > 0


@pytest.mark.chaos
def test_chaos_state_corruption_on_device_store(sess):
    """Corrupted table rows (the device form of put-corruption) are
    recorded by the injector; untouched streams still verify bit-exactly
    against the oracle through the slot-gathered path."""
    xs, rows, summary, inj = _run_chaos(sess, "pallas", seed=5, k=3,
                                        state_corrupt_rate=0.5)
    assert summary["state_residency"] == "device"
    assert inj.stats()["state_corruptions"] > 0
    for sid, wins in xs.items():
        if sid in inj.corrupted_streams:
            continue
        oracle = _oracle(sess, wins)
        for q, r in rows[sid].items():
            assert r.ok
            np.testing.assert_array_equal(r.y, oracle[q])


@pytest.mark.slow
def test_concurrent_device_store_stress(sess):
    """Satellite acceptance: N client threads churning end_stream against
    the device slot table under injected wave faults AND state loss —
    no deadlock, every window answered exactly once, per-generation seq
    numbering intact; streams the injector never touched are bit-exact
    with the oracle in BOTH generations; every reset flag traces back to
    a real cause (no silent corruption).  Legitimate causes: an injected
    slot loss, a wave the whole ladder failed (its carries are popped),
    or — first generation only — end_stream outrunning the compute
    thread, which tombstones the dying generation's in-flight carries at
    gather time (the documented host-path semantics, replayed by the
    slot table's pre-compute tombstone check)."""
    inj = FaultInjector(seed=29, wave_fault_rate=0.1, state_loss_rate=0.12)
    cfg = ServingConfig(batch=8, deadline_s=0.002, backend="pallas",
                        state_residency="device", resilience=FAST)
    srv = StreamServer(sess, cfg, fault_injector=inj)
    assert srv.state_residency == "device"
    n_threads, n_streams, k = 4, 3, 6
    windows = {}                                 # sid -> the k windows
    errors = []

    def client(ti):
        try:
            rng = np.random.default_rng(200 + ti)
            for sid_i in range(n_streams):
                sid = f"t{ti}-{sid_i}"
                wins = rng.uniform(0, 1, (k, MODEL.seq_len, 1)) \
                          .astype(np.float32)
                windows[sid] = wins
                for w in range(k):
                    srv.submit(sid, wins[w])
                    if w == 2:                   # churn: end mid-stream
                        srv.end_stream(sid)
        except BaseException as e:               # surfaced to the assert
            errors.append(e)

    threads = [threading.Thread(target=client, args=(ti,))
               for ti in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive()
    assert not errors, errors
    rows = srv.drain(timeout=120)
    summary = srv.metrics_summary()
    assert srv.close() == []
    assert len(rows) == n_threads * n_streams * k
    assert summary["state_transfer"]["to_device_bytes"] == 0
    assert summary["state_transfer"]["from_device_bytes"] == 0
    per_stream = {}
    for r in rows:
        per_stream.setdefault(r.stream_id, []).append(r)
    verified = 0
    for sid, rs in per_stream.items():
        # Two generations of 3 (end_stream after window 2), rows arriving
        # in submission order within the stream.
        assert [r.seq for r in rs] == [0, 1, 2, 0, 1, 2], sid
        for idx, r in enumerate(rs):
            if not r.ok:
                assert r.y is None and r.error
            if r.state_reset and idx >= 3:
                # Second generation: the end-churn tombstone cannot reach
                # it, so a reset must trace to an injected loss or to a
                # failed wave that popped the stream's carry.
                assert sid in inj.lost_streams \
                    or any(not p.ok for p in rs[:idx]), sid
        if sid in inj.corrupted_streams:
            continue        # corruption is silent by design: skip values
        # Generations are state-independent (end_stream resets the carry),
        # so each is judged on its own: a generation with no error and no
        # reset flag promised faithful chaining — hold it to bit-exact.
        for gen, lo in ((rs[:3], 0), (rs[3:], 3)):
            if any((not r.ok) or r.state_reset for r in gen):
                continue    # flagged: the casualty was advertised
            oracle = _oracle(sess, windows[sid][lo:lo + 3])
            for q, r in enumerate(gen):
                np.testing.assert_array_equal(r.y, oracle[q])
            verified += 1
    assert verified >= 6     # the exactness sweep must not be vacuous
