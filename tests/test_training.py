"""Training substrate: optimizer, checkpointing (atomic / async / keep-k /
restart-bit-exactness), straggler watchdog, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS, reduce_config
from repro.data.lm_data import SyntheticLM
from repro.models import transformer as T
from repro.training import checkpoint as ck
from repro.training.compress import compress, init_error_state
from repro.training.optimizer import (OptConfig, apply_updates,
                                      init_opt_state, schedule)
from repro.training.step import TrainPlan, init_train_state, make_train_step
from repro.training.train_loop import LoopConfig, StragglerWatchdog, Trainer


def test_adamw_minimises_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip():
    from repro.training.optimizer import clip_by_global_norm
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


def _tiny_state():
    cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"])
    params, _ = T.init_model(cfg, jax.random.key(0))
    plan = TrainPlan(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                     microbatches=2)
    return cfg, plan, init_train_state(params, plan)


def _batch(cfg, step, b=4, s=16):
    src = SyntheticLM(cfg.vocab_size, seed=7)
    d = src.batch(step, b, s)
    return {"tokens": jnp.asarray(d["tokens"]), "labels": jnp.asarray(d["labels"])}


def test_train_step_decreases_loss():
    cfg, plan, state = _tiny_state()
    step = jax.jit(make_train_step(cfg, plan), donate_argnums=0)
    losses = []
    for i in range(15):
        state, m = step(state, _batch(cfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state["step"]) == 15


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    cfg, plan, state = _tiny_state()
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ck.save(d, state, s, keep=2)
    assert ck.latest_step(d) == 4
    assert sorted(os.listdir(d)) == ["step_0000000003", "step_0000000004"]
    restored = ck.restore(d, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bit_exact(tmp_path):
    """10 straight steps == 5 steps + checkpoint + restore + 5 steps, with
    the step-keyed pipeline replaying identical batches."""
    cfg, plan, state0 = _tiny_state()
    step = jax.jit(make_train_step(cfg, plan))

    state = state0
    for i in range(10):
        state, m = step(state, _batch(cfg, i))
    full = state

    state = state0
    for i in range(5):
        state, _ = step(state, _batch(cfg, i))
    d = str(tmp_path / "ck")
    ck.save(d, state, 5)
    resumed = ck.restore(d, state)
    for i in range(5, 10):
        resumed, _ = step(resumed, _batch(cfg, i))
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer(tmp_path):
    cfg, plan, state = _tiny_state()
    d = str(tmp_path / "ck")
    ac = ck.AsyncCheckpointer(d, keep=3)
    ac.save_async(state, 7)
    ac.wait()
    assert ck.latest_step(d) == 7


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    cfg, plan, state = _tiny_state()
    d = str(tmp_path / "ck")
    ck.save(d, state, 1)
    # tmp dirs never remain
    assert not any(p.startswith("tmp.") for p in os.listdir(d))


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0, alpha=0.5)
    for _ in range(5):
        w.observe(0, 0.1)
    assert not w.observe(6, 0.15)
    assert w.observe(7, 0.5)          # 5x EMA -> straggler
    assert len(w.events) == 1
    # straggler must not poison the EMA
    assert w.ema < 0.2


def test_trainer_runs_and_checkpoints(tmp_path):
    cfg, plan, state = _tiny_state()
    step = jax.jit(make_train_step(cfg, plan))
    tr = Trainer(step, state, lambda i: _batch(cfg, i),
                 LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / "ck"),
                            ckpt_every=3, log_every=100),
                 log=lambda s: None)
    out = tr.run()
    assert out["step"] == 6 and not out["preempted"]
    assert ck.latest_step(str(tmp_path / "ck")) == 6
    # resume path: a new trainer picks up from 6 and does nothing (total 6)
    tr2 = Trainer(step, state, lambda i: _batch(cfg, i),
                  LoopConfig(total_steps=6, ckpt_dir=str(tmp_path / "ck")),
                  log=lambda s: None)
    start = tr2.maybe_resume()
    assert start == 6


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_gradient_compression(mode):
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .normal(0, 1e-3, (64,)).astype(np.float32))}
    err = init_error_state(grads)
    out, err = compress(grads, mode, err)
    g = jax.tree.leaves(out)[0]
    if mode == "bf16":
        assert g.dtype == jnp.bfloat16
    rel = float(jnp.max(jnp.abs(g.astype(jnp.float32) - grads["w"]))) / 1e-3
    assert rel < 0.1


def test_int8_error_feedback_converges():
    """Error feedback: the accumulated quantisation error stays bounded and
    the running sum of compressed grads tracks the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(16, np.float32)
    comp_sum = np.zeros(16, np.float32)
    grads = {"w": jnp.zeros(16)}
    err = init_error_state(grads)
    for i in range(50):
        g = rng.normal(0, 1e-2, 16).astype(np.float32)
        true_sum += g
        out, err = compress({"w": jnp.asarray(g)}, "int8", err)
        comp_sum += np.asarray(jax.tree.leaves(out)[0])
    resid = np.abs(np.asarray(err["w"]))
    assert np.abs(comp_sum - true_sum).max() <= resid.max() + 1e-5
