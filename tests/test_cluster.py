"""The multi-replica serving cluster (`repro.serving.cluster` / `routing`).

The load-bearing guarantee is the ROUTING INVARIANT: every named stream's
windows all execute on ONE replica (consistent hash), so its (h, c) carry
stays replica-local — and windowed-through-the-cluster is therefore
bit-identical on the int path to the concatenated one-shot run on a single
session.  Plus: HashRing determinism and minimal-disruption properties,
MetricsSink.merge units, drain/rebalance with ``state_reset`` provenance,
failover off a failed replica, and the device-pinning of
``Accelerator.replicate``."""

import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.qlstm import QLSTMConfig
from repro.serving import (ClusterConfig, ClusterServer, HashRing,
                           MetricsSink, OverloadPolicy, ServerOverloaded)
from repro.serving.metrics import WaveRecord

MODEL = QLSTMConfig(input_size=1, hidden_size=8, num_layers=2, seq_len=4)


@pytest.fixture(scope="module")
def sess():
    return repro.build(MODEL, seed=0).quantize()


def _windows(n, seed=0, t=4, m=1):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (n, t, m)).astype(np.float32)


# ---------------------------------------------------------------------------
# HashRing — determinism and minimal disruption
# ---------------------------------------------------------------------------

def test_ring_deterministic_across_instances():
    """Two fresh rings with the same nodes and seed agree on every key —
    the property that lets an external balancer compute the same routing
    (blake2b, never Python's per-process-randomised hash())."""
    keys = [f"stream-{i}" for i in range(500)]
    a = HashRing(["r0", "r1", "r2"], seed=7)
    b = HashRing(["r2", "r0", "r1"], seed=7)   # insertion order irrelevant
    assert a.assignments(keys) == b.assignments(keys)
    # ...and a different seed is a different (but still valid) mapping.
    c = HashRing(["r0", "r1", "r2"], seed=8)
    assert c.assignments(keys) != a.assignments(keys)


def test_ring_balance():
    """With vnodes smoothing, no replica owns a wildly disproportionate
    key share (loose bound — consistent hashing is approximate)."""
    keys = [f"s{i}" for i in range(3000)]
    ring = HashRing(["r0", "r1", "r2", "r3"], vnodes=64, seed=0)
    counts = {n: 0 for n in ring.nodes}
    for n in ring.assignments(keys).values():
        counts[n] += 1
    for n, c in counts.items():
        assert 0.4 * 3000 / 4 < c < 2.2 * 3000 / 4, counts


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_keys", [64, 500])
def test_ring_leave_moves_exactly_the_leavers_keys(seed, n_keys):
    """Removing a node re-routes EXACTLY that node's keys (the consistent-
    hashing contract, with no slack: surviving nodes' points don't move)."""
    keys = [f"k{i}" for i in range(n_keys)]
    ring = HashRing(["r0", "r1", "r2", "r3"], seed=seed)
    before = ring.assignments(keys)
    ring.remove("r2")
    after = ring.assignments(keys)
    for k in keys:
        if before[k] == "r2":
            assert after[k] != "r2"
        else:
            assert after[k] == before[k]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ring_join_moves_at_most_its_fair_share(seed):
    """Adding a node steals only the keys it now owns — bounded by the
    fair share ceil(K/N) plus slack for hashing variance; every stolen key
    moves TO the new node (never between old nodes)."""
    keys = [f"k{i}" for i in range(600)]
    ring = HashRing(["r0", "r1", "r2"], seed=seed)
    before = ring.assignments(keys)
    ring.add("r3")
    after = ring.assignments(keys)
    moved = [k for k in keys if after[k] != before[k]]
    assert all(after[k] == "r3" for k in moved)
    fair = math.ceil(len(keys) / 4)
    assert len(moved) <= 2 * fair, (len(moved), fair)


def test_ring_edge_cases():
    with pytest.raises(RuntimeError):
        HashRing().route("k")                   # empty ring
    with pytest.raises(ValueError):
        HashRing(vnodes=0)
    ring = HashRing(["a"])
    with pytest.raises(ValueError):
        ring.add("a")                           # duplicate
    with pytest.raises(KeyError):
        ring.remove("b")                        # absent
    assert ring.route("anything") == "a"        # single node owns all
    assert "a" in ring and len(ring) == 1


# ---------------------------------------------------------------------------
# MetricsSink.merge — the cluster aggregation primitive
# ---------------------------------------------------------------------------

def _rec(t, lat=0.010, occ=4, batch=4):
    return WaveRecord(t_done=t, compute_s=lat / 2, latency_s=lat,
                      occupancy=occ, batch=batch, deadline_flush=False)


def test_merge_empty_and_partial():
    """merge([]) is the empty sink; sinks that never saw a wave contribute
    nothing (no None-vs-float crashes on the wall interval)."""
    assert MetricsSink.merge([]).summary()["waves"] == 0
    empty, live = MetricsSink(), MetricsSink()
    live.note_submit(100.0)
    live.record_wave(_rec(100.5))
    s = MetricsSink.merge([empty, live]).summary()
    assert s["waves"] == 1 and s["samples"] == 4
    assert s["wall_s"] == pytest.approx(0.5)


def test_merge_sums_counters_and_spans_walls():
    """Lifetime counts sum; the wall spans earliest-submit to latest-done
    across replicas, so merged samples/s is the aggregate rate; named
    event counters sum too."""
    a, b = MetricsSink(), MetricsSink()
    a.note_submit(10.0)
    b.note_submit(10.2)
    for t in (10.5, 11.0):
        a.record_wave(_rec(t, occ=3))
    b.record_wave(_rec(12.0, occ=5))
    a.count("sheds", 2)
    b.count("sheds")
    b.count("state_resets", 4)
    m = MetricsSink.merge([a, b])
    s = m.summary()
    assert s["waves"] == 3 and s["samples"] == 11
    assert s["wall_s"] == pytest.approx(2.0)        # 10.0 -> 12.0
    assert s["samples_per_s"] == pytest.approx(11 / 2.0)
    assert m.counters() == {"sheds": 3, "state_resets": 4}


def test_merge_percentiles_union_recent_window():
    """The merged rolling window is the union of the inputs' retained
    records ordered by completion — its percentiles equal those computed
    over the pooled latencies directly."""
    a, b = MetricsSink(), MetricsSink()
    lats = []
    for i in range(20):
        (a if i % 2 else b).record_wave(_rec(100.0 + i, lat=0.001 * (i + 1)))
        lats.append(0.001 * (i + 1))
    s = MetricsSink.merge([a, b]).summary()
    want = np.percentile(np.asarray(lats), [50, 95, 99]) * 1e3
    assert s["latency_ms"]["p50"] == pytest.approx(want[0])
    assert s["latency_ms"]["p99"] == pytest.approx(want[2])


def test_merge_truncates_to_window():
    """A small merge window keeps only the most RECENT records across the
    union (deque semantics), like a single server's sink would."""
    a = MetricsSink()
    for i in range(10):
        a.record_wave(_rec(100.0 + i))
    m = MetricsSink.merge([a], window=4)
    assert [r.t_done for r in m.waves] == [106.0, 107.0, 108.0, 109.0]
    assert m.summary()["waves"] == 10                # lifetime count intact


# ---------------------------------------------------------------------------
# Accelerator.replicate — per-device pinned replicas
# ---------------------------------------------------------------------------

def test_replicate_pins_bit_identical_codes(sess):
    """Replicas carry the SAME integer codes (pinned, not re-quantised),
    committed to a device, and produce bit-identical int-path output."""
    reps = sess.replicate(2)
    x = _windows(3, seed=5)
    want = np.asarray(sess.infer(jnp.asarray(x), path="int"))
    for rep in reps:
        assert rep.device in jax.devices()
        leaves = jax.tree_util.tree_leaves(rep.qparams)
        assert all(l.devices() == {rep.device} for l in leaves)
        np.testing.assert_array_equal(
            np.asarray(rep.infer(jnp.asarray(x), path="int")), want)


def test_replicate_requires_quantized():
    with pytest.raises(RuntimeError, match="quantised"):
        repro.build(MODEL, seed=0).replicate(2)


def test_serving_devices_contract():
    from repro.launch.mesh import serving_devices
    devs = serving_devices(3)                       # oversubscribe by default
    assert len(devs) == 3
    with pytest.raises(ValueError):
        serving_devices(0)
    if len(jax.devices()) < 3:
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            serving_devices(3, oversubscribe=False)
    with pytest.raises(ValueError):
        serving_devices(2, devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# ClusterServer — the routing invariant, end to end
# ---------------------------------------------------------------------------

def _cluster(sess, n=3, **kw):
    kw.setdefault("batch", 4)
    kw.setdefault("deadline_s", 0.002)
    return ClusterServer(sess.replicate(n), **kw)


@pytest.mark.slow
def test_cluster_routing_invariant_and_bit_exact_carry(sess):
    """THE acceptance property: every stream's windows run on exactly one
    replica (``routed_replica`` constant per stream, equal to the ring's
    assignment), and each stream's windowed-on-the-cluster predictions are
    bit-exact against the single-session concatenated oracle — the carry
    stayed replica-local the whole way."""
    k, t = 3, MODEL.seq_len
    streams = {f"c{i}": _windows(k, seed=30 + i) for i in range(9)}
    with _cluster(sess, 3) as cluster:
        expect = {sid: cluster.replica_for(sid) for sid in streams}
        for w in range(k):
            for sid, xs in streams.items():
                cluster.submit(sid, xs[w])
        results = cluster.drain()
    by = {}
    for r in results:
        assert r.ok
        assert r.routed_replica == expect[r.stream_id]
        by.setdefault(r.stream_id, {})[r.seq] = r.y
    assert len({expect[s] for s in streams}) > 1    # actually spread out
    for sid, xs in streams.items():
        assert sorted(by[sid]) == list(range(k))
        for w in range(k):
            oracle = np.asarray(sess.infer(
                jnp.asarray(xs[:w + 1].reshape(1, (w + 1) * t, 1)),
                path="int"))
            np.testing.assert_array_equal(by[sid][w], oracle[0])


def test_cluster_rejects_non_replicas(sess):
    other = repro.build(MODEL, seed=42).quantize()
    with pytest.raises(ValueError, match="weights"):
        ClusterServer([sess, other], batch=2)
    with pytest.raises(ValueError, match="replica"):
        ClusterServer([], batch=2)
    with pytest.raises(ValueError, match="names"):
        ClusterServer(sess.replicate(2), names=["a"], batch=2)


def test_cluster_metrics_aggregate(sess):
    """metrics_summary: merged aggregate block + per-replica breakdown +
    summed fault/state counters + the ring block — the schema report.py's
    serving table renders."""
    with _cluster(sess, 2) as cluster:
        for i, w in enumerate(_windows(12, seed=6)):
            cluster.submit(f"m{i % 4}", w)
        cluster.drain()
        s = cluster.metrics_summary()
    assert s["samples"] == 12 and s["waves"] >= 3
    assert set(s["replicas"]) == {"r0", "r1"}
    assert s["samples_per_s"] > 0 and s["samples_per_s_sum"] > 0
    assert {"p50", "p95", "p99"} <= set(s["latency_ms"])
    assert s["faults"]["sheds"] == 0 and s["faults"]["backend"]
    assert s["state"]["live_streams"] == 4          # summed across replicas
    assert s["ring"]["vnodes"] == 64
    assert s["ring"]["streams_routed"] == 4
    assert s["health"]["status"] == "ok"
    assert s["gops_per_watt"] > 0


def test_cluster_end_stream(sess):
    """end_stream forgets the stream cluster-wide: numbering restarts and
    the carry resets (fresh-stream output), on whatever replica owns it."""
    x = _windows(2, seed=8)
    fresh = np.asarray(sess.infer(jnp.asarray(x[1:2]), path="int"))
    with _cluster(sess, 2, batch=2) as cluster:
        assert cluster.submit("e", x[0]) == 0
        cluster.flush()
        cluster.end_stream("e")
        assert cluster.submit("e", x[1]) == 0
        results = cluster.drain()
    last = [r for r in results if r.seq == 0][-1]
    np.testing.assert_array_equal(last.y, fresh[0])


def test_cluster_overload_propagates_replica_name(sess):
    """A saturated replica's admission rejection surfaces to the client as
    ServerOverloaded naming the replica — never silently re-routed, which
    would break state locality."""
    policy = OverloadPolicy(admission="reject")
    with _cluster(sess, 2, batch=2, deadline_s=None, max_pending=2,
                  queue_depth=1, overload=policy) as cluster:
        sid = "hot"
        with pytest.raises(ServerOverloaded, match="replica 'r[01]'"):
            for w in _windows(64, seed=9):
                cluster.submit(sid, w)
        cluster.drain()


def test_cluster_remove_replica_moves_only_its_streams(sess):
    """Drain/rebalance with HOST-resident state (pinned — device residency
    upgrades the drain to a warm handoff, covered separately below): the
    ring shrink moves ONLY the removed replica's streams (~K/N); each
    restarts at its new home with seq 0 and ``state_reset=True``
    provenance, and its post-move prediction equals a fresh stream's (the
    carry really did reset).  Unmoved streams keep replica, numbering,
    and carry."""
    k = 2
    streams = {f"d{i}": _windows(k + 1, seed=40 + i) for i in range(8)}
    with _cluster(sess, 3, state_residency="host") as cluster:
        for w in range(k):
            for sid, xs in streams.items():
                cluster.submit(sid, xs[w])
        cluster.drain()
        before = {sid: cluster.replica_for(sid) for sid in streams}
        victim = before["d0"]
        moved = cluster.remove_replica(victim)
        assert sorted(moved) == sorted(
            s for s, r in before.items() if r == victim)
        assert victim not in cluster.replicas
        for sid, xs in streams.items():
            cluster.submit(sid, xs[k])
        results = cluster.drain()
        by = {r.stream_id: r for r in results}
        t = MODEL.seq_len
        for sid, xs in streams.items():
            r = by[sid]
            if sid in moved:
                assert r.seq == 0 and r.state_reset
                assert r.routed_replica != victim
                fresh = np.asarray(sess.infer(
                    jnp.asarray(xs[k].reshape(1, t, 1)), path="int"))
                np.testing.assert_array_equal(r.y, fresh[0])
            else:
                assert r.seq == k and not r.state_reset
                assert r.routed_replica == before[sid]
                oracle = np.asarray(sess.infer(
                    jnp.asarray(xs.reshape(1, (k + 1) * t, 1)), path="int"))
                np.testing.assert_array_equal(r.y, oracle[0])
        with pytest.raises(KeyError):
            cluster.remove_replica(victim)          # already gone


def test_cluster_remove_replica_warm_handoff_device_residency(sess):
    """Satellite acceptance: with DEVICE-resident state (the default —
    ``auto`` resolves to the slot table on this pallas plan) a planned
    drain upgrades to a WARM handoff.  The ring shrink still moves
    exactly the victim's streams, but each moved carry is read back from
    the dying replica's slot table and seeded into the stream's new ring
    home — the destination's read-back rows must reproduce the ref
    oracle's threaded state — so the stream's next window continues the
    recurrence bit-exactly against the concatenated oracle: per-replica
    seq restarts at 0 with NO ``state_reset`` flag.  Unmoved streams
    keep replica, numbering, and carry, exactly as on the cold path."""
    k, t = 2, MODEL.seq_len
    streams = {f"w{i}": _windows(k + 1, seed=60 + i) for i in range(8)}
    ref = sess.compiled_stateful("ref")

    def carry_after(xs, n):
        state = sess.init_state(1)
        for w in xs[:n]:
            _, state = ref(w[None], state)
        return state

    with _cluster(sess, 3) as cluster:
        assert all(s.state_residency == "device"
                   for s in cluster._servers.values())
        for w in range(k):
            for sid, xs in streams.items():
                cluster.submit(sid, xs[w])
        cluster.drain()
        before = {sid: cluster.replica_for(sid) for sid in streams}
        victim = before["w0"]
        moved = cluster.remove_replica(victim)
        assert sorted(moved) == sorted(
            s for s, r in before.items() if r == victim)
        assert victim not in cluster.replicas
        for sid in moved:
            # The handoff seeded the carry at the stream's new ring home,
            # and what reads back row-for-row IS the oracle's state.
            dest = cluster.replica_for(sid)
            assert dest != victim
            got = cluster._servers[dest].read_stream_state(sid)
            assert got is not None
            oracle_state = carry_after(streams[sid], k)
            for li, (h, c) in enumerate(got):
                oh, oc = oracle_state[li]
                np.testing.assert_array_equal(h, np.asarray(oh)[0])
                np.testing.assert_array_equal(c, np.asarray(oc)[0])
        for sid, xs in streams.items():
            cluster.submit(sid, xs[k])
        results = cluster.drain()
        by = {r.stream_id: r for r in results}
        for sid, xs in streams.items():
            r = by[sid]
            assert r.ok and not r.state_reset, sid
            if sid in moved:
                assert r.seq == 0 and r.routed_replica != victim
            else:
                assert r.seq == k and r.routed_replica == before[sid]
            oracle = np.asarray(sess.infer(
                jnp.asarray(xs.reshape(1, (k + 1) * t, 1)), path="int"))
            np.testing.assert_array_equal(r.y, oracle[0])


def test_cluster_remove_replica_abandon_skips_handoff(sess):
    """``abandon=True`` on a device-residency drain: the replica died, so
    there is nothing to read back — moved streams restart COLD at their
    new home with the flagged reset, the cold path's contract."""
    k = 1
    streams = {f"a{i}": _windows(k + 1, seed=80 + i) for i in range(8)}
    with _cluster(sess, 3) as cluster:
        for sid, xs in streams.items():
            cluster.submit(sid, xs[0])
        cluster.drain()
        before = {sid: cluster.replica_for(sid) for sid in streams}
        victim = before["a0"]
        moved = cluster.remove_replica(victim, abandon=True)
        for sid, xs in streams.items():
            cluster.submit(sid, xs[k])
        by = {r.stream_id: r for r in cluster.drain()}
        t = MODEL.seq_len
        for sid, xs in streams.items():
            r = by[sid]
            if sid in moved:
                assert r.seq == 0 and r.state_reset
                fresh = np.asarray(sess.infer(
                    jnp.asarray(xs[k].reshape(1, t, 1)), path="int"))
                np.testing.assert_array_equal(r.y, fresh[0])
            else:
                assert r.seq == k and not r.state_reset


def test_cluster_cannot_remove_last_replica(sess):
    with _cluster(sess, 1) as cluster:
        with pytest.raises(RuntimeError, match="last"):
            cluster.remove_replica("r0")
        assert cluster.replicas == ["r0"]           # ring intact after undo


def test_cluster_add_replica_rebalances_lazily(sess):
    """Growing the ring steals only the new node's fair share; stolen
    streams move on their NEXT submit with flagged resets, the rest are
    untouched."""
    streams = {f"g{i}": _windows(2, seed=60 + i) for i in range(8)}
    with _cluster(sess, 2) as cluster:
        for sid, xs in streams.items():
            cluster.submit(sid, xs[0])
        cluster.drain()
        before = {sid: cluster.replica_for(sid) for sid in streams}
        name = cluster.add_replica(sess.replicate(1)[0])
        assert name == "r2" and name in cluster.replicas
        after = {sid: cluster.replica_for(sid) for sid in streams}
        stolen = [s for s in streams if after[s] != before[s]]
        assert all(after[s] == name for s in stolen)
        for sid, xs in streams.items():
            cluster.submit(sid, xs[1])
        results = cluster.drain()
        for r in results:
            if r.stream_id in stolen:
                assert r.seq == 0 and r.state_reset
                assert r.routed_replica == name
            else:
                assert r.seq == 1 and not r.state_reset
        with pytest.raises(ValueError, match="weights"):
            cluster.add_replica(repro.build(MODEL, seed=42).quantize())


def test_cluster_failover_reroutes_on_failed_replica(sess, monkeypatch):
    """When a replica's health says ``failed`` at submit time, failover
    takes it off the ring and re-routes (flagged reset) instead of raising
    the replica's error; the dead replica shows up in health()."""
    streams = {f"f{i}": _windows(2, seed=70 + i) for i in range(6)}
    with _cluster(sess, 2) as cluster:
        for sid, xs in streams.items():
            cluster.submit(sid, xs[0])
        cluster.drain()
        owners = {sid: cluster.replica_for(sid) for sid in streams}
        victim = owners[next(iter(streams))]
        srv = cluster._servers[victim]
        monkeypatch.setattr(
            srv, "submit",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("dead")))
        monkeypatch.setattr(
            srv, "health", lambda: {"status": "failed"})
        hit = [s for s, r in owners.items() if r == victim]
        seq = cluster.submit(hit[0], streams[hit[0]][1])
        assert seq == 0                             # restarted at new home
        assert victim not in cluster.replicas
        results = cluster.drain()
        moved = [r for r in results if r.stream_id == hit[0]]
        assert moved and moved[0].state_reset
        assert moved[0].routed_replica != victim
        h = cluster.health()
        assert h["status"] == "degraded"
        assert victim in h["unhealthy"]
    # restore path: back on the ring, streams may hash home again
    # (exercised separately to keep the monkeypatched server out of play)


def test_cluster_restore_replica(sess):
    """mark_unhealthy -> restore_replica round-trip: streams move away
    with flagged resets and may move back the same way; no stale carry
    survives on the sidelined replica."""
    with _cluster(sess, 2) as cluster:
        xs = _windows(3, seed=80)
        sid = "rt"
        home = cluster.replica_for(sid)
        other = next(n for n in cluster.replicas if n != home)
        cluster.submit(sid, xs[0])
        cluster.drain()
        cluster.mark_unhealthy(home, reason="drill")
        assert cluster.replica_for(sid) == other
        r1 = None
        cluster.submit(sid, xs[1])
        r1 = cluster.drain()[0]
        assert r1.routed_replica == other and r1.seq == 0 and r1.state_reset
        with pytest.raises(RuntimeError, match="last"):
            cluster.mark_unhealthy(other)
        cluster.restore_replica(home)
        assert cluster.replica_for(sid) == home
        cluster.submit(sid, xs[2])
        r2 = cluster.drain()[0]
        # Back home: fresh numbering AND flagged reset — the sidelined
        # replica's old carry was ended at mark_unhealthy time, so the
        # prediction equals a fresh stream's, not a stale continuation.
        assert r2.routed_replica == home and r2.seq == 0 and r2.state_reset
        fresh = np.asarray(sess.infer(
            jnp.asarray(xs[2].reshape(1, MODEL.seq_len, 1)), path="int"))
        np.testing.assert_array_equal(r2.y, fresh[0])


def test_cluster_poll_timeout_and_close(sess):
    """poll(timeout) waits for the first batch; close drains cleanly and
    further submits are refused."""
    with _cluster(sess, 2) as cluster:
        t0 = time.perf_counter()
        assert cluster.poll(timeout=0.05) == []
        assert time.perf_counter() - t0 >= 0.04
        cluster.submit("p", _windows(1, seed=90)[0])
        rows = cluster.poll(timeout=5.0)
        assert rows and rows[0].ok
    assert cluster.close() == []                    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        cluster.submit("p", _windows(1, seed=90)[0])


def test_cluster_bad_window_raises_to_caller_only(sess):
    """A malformed window is the CLIENT's error (ValueError at submit) —
    it must not trip failover or unhealth the replica."""
    with _cluster(sess, 2) as cluster:
        with pytest.raises(ValueError, match="window"):
            cluster.submit("b", np.zeros((4, 3), np.float32))
        assert cluster.health()["status"] == "ok"
        assert len(cluster.replicas) == 2


def test_cluster_config_validation():
    with pytest.raises(ValueError, match="vnodes"):
        ClusterConfig(vnodes=0)


def test_build_cluster_front_door(sess):
    """repro.build_cluster: one call from a quantised session to a serving
    cluster (the api.py wrapper over replicate + ClusterServer)."""
    cluster = repro.build_cluster(sess, 2, batch=2, deadline_s=0.002,
                                  vnodes=16)
    try:
        assert len(cluster.replicas) == 2
        assert cluster.config.vnodes == 16
        assert cluster.config.serving.batch == 2
        cluster.submit("q", _windows(1, seed=95)[0])
        rows = cluster.drain()
        assert rows[0].ok and rows[0].routed_replica in ("r0", "r1")
    finally:
        cluster.close()
