"""End-to-end behaviour: the paper's full pipeline (train -> QAT -> deploy
on the integer accelerator) reaches the paper-band accuracy, and the serve
launcher generates tokens."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import PAPER_DEFAULT
from repro.core.qlstm import QLSTMConfig
from repro.data.timeseries import pems_like_dataset
from repro.models import lstm_model
from repro.training.optimizer import OptConfig, apply_updates, init_opt_state


def test_e2e_qat_to_int8_deployment():
    """Abbreviated §6.1: QAT training converges and the deployed int8
    (Pallas-kernel) model matches QAT accuracy to <2x MSE."""
    cfg = QLSTMConfig()
    data = pems_like_dataset(seq_len=cfg.seq_len, n_days=10)
    xtr, ytr = data["train"]
    xte, yte = data["test"]
    params = lstm_model.init_lstm_model(cfg, jax.random.key(0))[0]
    oc = OptConfig(lr=5e-3, weight_decay=0.0, warmup_steps=5, total_steps=120)
    opt = init_opt_state(params, oc)

    @jax.jit
    def step(params, opt, x, y):
        (l, _), g = jax.value_and_grad(
            lambda p: lstm_model.loss_fn(p, {"x": x, "y": y}, cfg, "qat"),
            has_aux=True)(params)
        params, opt, _ = apply_updates(params, g, opt, oc)
        return params, opt, l

    rng = np.random.default_rng(0)
    first = last = None
    for i in range(120):
        idx = rng.integers(0, len(xtr), 64)
        params, opt, l = step(params, opt, jnp.asarray(xtr[idx]),
                              jnp.asarray(ytr[idx]))
        if i == 0:
            first = float(l)
        last = float(l)
    assert last < first * 0.25, (first, last)

    x = jnp.asarray(xte[:256])
    y = jnp.asarray(yte[:256])
    mse_qat = float(jnp.mean((lstm_model.forward(params, x, cfg, "qat") - y) ** 2))
    mse_hw = float(jnp.mean(
        (lstm_model.serve_int(params, x, cfg, PAPER_DEFAULT) - y) ** 2))
    assert mse_qat < 0.05          # paper band (0.040 on real PeMS)
    assert mse_hw < max(2 * mse_qat, 0.05)


def test_serve_launcher_generates():
    from repro.launch.serve import main
    gen = main(["--arch", "qwen1.5-0.5b", "--batch", "2", "--gen", "4",
                "--prompt-len", "3", "--max-seq", "16"])
    assert gen.shape == (2, 4)


def test_train_launcher_lm_smoke():
    from repro.launch.train import main
    out = main(["--arch", "qwen1.5-0.5b", "--steps", "3", "--batch", "4",
                "--seq", "16"])
    assert out["step"] == 3
