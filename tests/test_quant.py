"""Tensor-level int8 quantisation (C1 at LM scale) properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.quant import (NO_QUANT, W8, W8A8, QuantConfig, compute_scale,
                              fq_matmul, qmatmul, quantize_kv,
                              quantize_tensor, quantize_weight)


@given(st.integers(0, 1000), st.floats(0.01, 1000.0))
@settings(max_examples=100, deadline=None)
def test_quantize_error_bound(seed, scale_mag):
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, scale_mag, (32,))).astype(np.float32)
    qt = quantize_tensor(jnp.asarray(x))
    err = np.abs(np.asarray(qt.dequantize()) - x)
    assert err.max() <= float(qt.scale) / 2 + 1e-6


@given(st.integers(0, 200))
@settings(max_examples=50, deadline=None)
def test_p2_scales_are_powers_of_two(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, rng.uniform(0.01, 100), (16, 8)).astype(np.float32)
    s = float(compute_scale(jnp.asarray(x), p2=True))
    assert s > 0 and abs(np.log2(s) - round(np.log2(s))) < 1e-6
    # p2 rounding never clips: values stay within int8 after quantisation
    qt = quantize_tensor(jnp.asarray(x), p2=True)
    assert np.abs(np.asarray(qt.values)).max() <= 127


def test_per_channel_weight_quant():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 1, (64, 32)).astype(np.float32)
    w[:, 5] *= 100  # one hot channel shouldn't wreck the others
    qt = quantize_weight(jnp.asarray(w), W8A8, out_axis=-1)
    assert qt.scale.shape == (1, 32)
    err = np.abs(np.asarray(qt.dequantize()) - w)
    assert err[:, 0].max() < 0.02  # normal channel keeps fine resolution


def test_qmatmul_close_to_float():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (16, 64)).astype(np.float32)
    w = rng.normal(0, 0.1, (64, 32)).astype(np.float32)
    wq = quantize_weight(jnp.asarray(w), W8A8)
    y8 = np.asarray(qmatmul(jnp.asarray(x), wq, W8A8))
    yf = x @ w
    rel = np.abs(y8 - yf).max() / (np.abs(yf).max() + 1e-9)
    assert rel < 0.05


def test_fq_matmul_gradients_flow():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, (16, 4)).astype(np.float32))
    g = jax.grad(lambda w: jnp.sum(fq_matmul(x, w, W8A8) ** 2))(w)
    assert float(jnp.sum(jnp.abs(g))) > 0
    # and the forward is close to float
    err = jnp.max(jnp.abs(fq_matmul(x, w, W8A8) - x @ w))
    assert float(err) < 0.2


def test_kv_quantisation_roundtrip():
    rng = np.random.default_rng(3)
    kv = rng.normal(0, 1, (2, 10, 4, 16)).astype(np.float32)  # B,S,KV,hd
    qt = quantize_kv(jnp.asarray(kv))
    assert qt.values.dtype == jnp.int8
    err = np.abs(np.asarray(qt.dequantize()) - kv)
    assert err.max() < 0.05
