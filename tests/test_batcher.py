"""Wave-batched serving: requests of mixed lengths drain correctly and
deterministically match unbatched decoding."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_CONFIGS, reduce_config
from repro.launch.batcher import WaveBatcher
from repro.models import transformer as T


def test_wave_batcher_drains_mixed_requests():
    cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"]).replace(remat="none")
    params, _ = T.init_model(cfg, jax.random.key(0))
    b = WaveBatcher(params, cfg, batch_size=4, max_seq=32)
    rng = np.random.default_rng(0)
    rids = [b.submit(rng.integers(0, cfg.vocab_size, n), max_new=m)
            for n, m in [(3, 4), (5, 2), (2, 6), (4, 3), (3, 3)]]  # 2 waves
    out = b.run()
    assert set(out) == set(rids)
    assert [len(out[r]) for r in rids] == [4, 2, 6, 3, 3]


def test_wave_batcher_matches_single_request():
    """A batched slot produces the same tokens as a batch-of-one run."""
    cfg = reduce_config(ARCH_CONFIGS["qwen1.5-0.5b"]).replace(remat="none")
    params, _ = T.init_model(cfg, jax.random.key(0))
    prompt = np.asarray([5, 9, 11], np.int32)

    single = WaveBatcher(params, cfg, batch_size=1, max_seq=32)
    r0 = single.submit(prompt, max_new=5)
    out_single = single.run()[r0]

    batched = WaveBatcher(params, cfg, batch_size=3, max_seq=32)
    rids = [batched.submit(prompt, max_new=5),
            batched.submit(np.asarray([1, 2], np.int32), max_new=5),
            batched.submit(np.asarray([7], np.int32), max_new=5)]
    out_b = batched.run()
    assert out_b[rids[0]] == out_single
