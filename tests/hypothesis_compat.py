"""Optional-hypothesis shim for the test suite.

When ``hypothesis`` is installed (see requirements-dev.txt) this re-exports
the real ``given``/``settings``/``st``.  On a bare interpreter the property
tests are skipped individually while every plain pytest test in the same
module still runs — module-level ``pytest.importorskip`` would discard the
kernel-parity tests along with the property tests.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Placeholder accepting any strategy-construction chain."""

        def __call__(self, *args, **kwargs):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
