"""Property tests for the (a,b) fixed-point datapath (C1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import fixed_point as fxp
from repro.core.fixed_point import (FXP_4_8, FXP_8_16, FixedPointConfig,
                                    dequantize, fake_quant, quantize,
                                    requantize)

cfgs = st.sampled_from([FXP_4_8, FixedPointConfig(6, 8),
                        FixedPointConfig(8, 10), FXP_8_16,
                        FixedPointConfig(0, 8), FixedPointConfig(7, 8)])


@given(cfgs, st.floats(-300, 300, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_quantize_roundtrip_error_bound(cfg, x):
    """|dequant(quant(x)) - x| <= LSB/2 inside the representable range,
    and clips to the range outside it."""
    q = quantize(x, cfg)
    assert cfg.int_min <= int(q) <= cfg.int_max
    xd = float(dequantize(q, cfg))
    if cfg.min_value <= x <= cfg.max_value:
        assert abs(xd - x) <= cfg.scale / 2 + 1e-7
    else:
        assert xd in (pytest.approx(cfg.min_value), pytest.approx(cfg.max_value))


@given(cfgs)
@settings(max_examples=50, deadline=None)
def test_quantize_is_monotonic(cfg):
    xs = np.linspace(cfg.min_value * 1.5, cfg.max_value * 1.5, 301)
    qs = np.asarray(quantize(jnp.asarray(xs), cfg))
    assert (np.diff(qs) >= 0).all()


@given(st.integers(-(2 ** 14), 2 ** 14), st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_round_shift_is_round_half_up(v, s):
    got = int(fxp.round_shift_right(jnp.asarray(v), s))
    want = int(np.floor(v / 2 ** s + 0.5))
    assert got == want


def test_requantize_matches_paper_example():
    # (8,16) product -> (4,8): shift 4 with round-half-up, saturate.
    v = jnp.asarray([0, 7, 8, -8, -9, 40000, -40000])
    out = requantize(v, FXP_8_16, FXP_4_8)
    assert out.tolist() == [0, 0, 1, 0, -1, 127, -128]


@given(cfgs, st.integers(1, 24))
@settings(max_examples=60, deadline=None)
def test_late_rounding_at_least_as_accurate(cfg, n):
    """Pipelined (late-rounding) MAC is never less accurate than the
    per-step-rounding baseline — the paper's S5 design point."""
    rng = np.random.default_rng(n)
    x = rng.uniform(-1, 1, n).astype(np.float32)
    w = rng.uniform(-1, 1, n).astype(np.float32)
    xi = quantize(jnp.asarray(x), cfg)
    wi = quantize(jnp.asarray(w), cfg)
    exact = float(dequantize(xi, cfg) @ dequantize(wi, cfg))
    late = float(dequantize(fxp.fxp_mac_late_rounding(xi, wi, cfg), cfg))
    per = float(dequantize(fxp.fxp_mac_per_step_rounding(xi, wi, cfg), cfg))
    exact_clip = np.clip(exact, cfg.min_value, cfg.max_value)
    assert abs(late - exact_clip) <= abs(per - exact_clip) + cfg.scale + 1e-6


def test_fake_quant_ste_gradient():
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, FXP_4_8)))(
        jnp.asarray([0.3, -0.2, 100.0, -100.0]))
    # identity gradient inside range, zero outside (saturation)
    assert g.tolist() == [1.0, 1.0, 0.0, 0.0]


def test_matvec_late_rounding_matches_manual():
    cfg = FXP_4_8
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, (5, 7)).astype(np.int32)
    w = rng.integers(-128, 128, (7, 3)).astype(np.int32)
    b = rng.integers(-1000, 1000, (3,)).astype(np.int32)
    got = np.asarray(fxp.fxp_matvec_late_rounding(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), cfg))
    acc = x @ w + b
    want = np.clip(np.floor(acc / 16 + 0.5), -128, 127)
    np.testing.assert_array_equal(got, want)
