"""Seed coverage for the float RG-LRU stack — ``models/rglru.py`` and
``kernels/rglru_scan.py``.

These are the Griffin-faithful float-path modules (exp/softplus/sqrt
datapath) that the quantised ``cells/rglru.py`` deliberately REdefines
for hardware; they ship in the seed but had no dedicated tests.  This
file pins them: shape/finiteness on the block forward, decode==train
equivalence through the conv window and recurrent state, and fixed-seed
regression values so a silent numeric change (a dropped normaliser, a
sign flip in the decay) fails loudly rather than drifting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_CONFIGS, reduce_config
from repro.kernels import ref
from repro.kernels.rglru_scan import rglru_seq_pallas
from repro.models import rglru as RG
from repro.models.modules import unbox


@pytest.fixture(scope="module")
def cfg():
    return reduce_config(ARCH_CONFIGS["recurrentgemma-2b"])


@pytest.fixture(scope="module")
def params(cfg):
    p, _ = unbox(RG.init_rglru_block(jax.random.key(3), cfg))
    return p


def test_init_rglru_block_tree(cfg, params):
    """The block's param tree: every Griffin surface, correct shapes."""
    d, w = cfg.d_model, cfg.recurrent.lru_width
    cw = cfg.recurrent.conv_width
    want = {"w_x": (d, w), "w_gate": (d, w), "w_out": (w, d),
            "conv_w": (cw, w), "conv_b": (w,), "w_a": (w, w), "b_a": (w,),
            "w_i": (w, w), "b_i": (w,), "lam": (w,)}
    assert set(params) == set(want)
    for name, shape in want.items():
        assert params[name].shape == shape, name
    # Biases start at zero, Lambda at one (Griffin's stable decay band).
    assert not np.any(np.asarray(params["b_a"]))
    assert not np.any(np.asarray(params["b_i"]))
    np.testing.assert_array_equal(np.asarray(params["lam"]),
                                  np.ones(w, np.float32))


def test_rglru_scan_shape_finite_and_pinned(cfg, params):
    """Fixed-seed regression: the scan's output is pinned, not just
    finite — decay normalisation bugs move these digits."""
    rng = np.random.default_rng(42)
    w = cfg.recurrent.lru_width
    x = jnp.asarray(rng.normal(0, 1, (2, 7, w)).astype(np.float32))
    h = RG.rglru_scan(params, x, cfg)
    assert h.shape == (2, 7, w)
    assert bool(jnp.all(jnp.isfinite(h)))
    np.testing.assert_allclose(float(jnp.sum(h)), -21.403288, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(h)[0, -1, :4],
        [-1.177827, -0.383444, 0.061266, 0.279287], atol=1e-4)


def test_rec_block_apply_train_shape_finite_and_pinned(cfg, params):
    rng = np.random.default_rng(42)
    w = cfg.recurrent.lru_width
    rng.normal(0, 1, (2, 7, w))           # keep the draw order of the pin
    x = jnp.asarray(rng.normal(0, 1, (2, 5, cfg.d_model)).astype(np.float32))
    y = RG.rec_block_apply(params, x, cfg)
    assert y.shape == (2, 5, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(
        np.asarray(y)[1, -1, :4],
        [0.187749, -1.501872, -0.00121, 0.372229], atol=1e-4)


def test_rec_block_decode_equals_train(cfg, params):
    """O(1) decode through the conv window + recurrent state reproduces
    the full train/prefill forward step-for-step."""
    rng = np.random.default_rng(7)
    w, cw = cfg.recurrent.lru_width, cfg.recurrent.conv_width
    x = jnp.asarray(rng.normal(0, 1, (2, 6, cfg.d_model)).astype(np.float32))
    y_train = RG.rec_block_apply(params, x, cfg)
    state = {"h": jnp.zeros((2, w)), "conv": jnp.zeros((2, cw - 1, w))}
    outs = []
    for t in range(6):
        y_t, state = RG.rec_block_apply(params, x[:, t:t + 1], cfg,
                                        mode="decode", state=state)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    assert state["h"].shape == (2, w)
    assert state["conv"].shape == (2, cw - 1, w)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=1e-5, atol=1e-5)


def test_rglru_seq_pallas_matches_ref_and_pinned():
    """The fused sequential kernel against its oracle (exact) plus a
    fixed-seed pin, including a batch that needs padding (3 rows,
    batch_block=2)."""
    rng = np.random.default_rng(42)
    rng.normal(0, 1, (2, 7, 64))          # keep the draw order of the pin
    rng.normal(0, 1, (2, 5, 64))
    log_a = jnp.asarray(rng.uniform(-1.0, -0.01, (6, 3, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (6, 3, 8)).astype(np.float32))
    h = rglru_seq_pallas(log_a, b, batch_block=2)
    assert h.shape == (6, 3, 8)
    np.testing.assert_array_equal(np.asarray(h),
                                  np.asarray(ref.rglru_seq_ref(log_a, b)))
    np.testing.assert_allclose(
        np.asarray(h)[-1, 0, :4],
        [-1.130105, -1.414136, 0.452369, -0.542766], atol=1e-4)


def test_rglru_seq_pallas_zero_decay_is_cumulative_sum():
    """log_a == 0 (a == 1) degenerates to a running sum — an analytic
    anchor independent of the oracle."""
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.normal(0, 1, (5, 2, 8)).astype(np.float32))
    h = rglru_seq_pallas(jnp.zeros_like(b), b, batch_block=2)
    np.testing.assert_allclose(np.asarray(h),
                               np.cumsum(np.asarray(b), axis=0),
                               rtol=1e-5, atol=1e-5)
