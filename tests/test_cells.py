"""The cell-agnostic recurrent contract (`repro.cells`).

Every registered cell — lstm, gru, rglru — must pass the SAME battery
shape that locked in the LSTM: bit-exact ref<->xla int-path parity across
fixed-point widths x HardSigmoid* methods x 1-3 layers, and
windowed-vs-concatenated bit-exactness through ``StreamServer`` (host
residency AND the device-resident slot table, which non-LSTM cells reach
through the XLA-level slot adapter).  Plus the registry/plan surfaces the
serving and explore layers dispatch on."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import backends, cells, explore
from repro.backends import BackendUnsupported
from repro.core import fixed_point as fxp
from repro.core.accelerator import (AcceleratorConfig, HS_METHODS, plan,
                                    resolve_model)
from repro.core.fixed_point import FXP_4_8, FXP_8_16
from repro.core.qlstm import QLSTMConfig, init_int_state

CELLS = ("lstm", "gru", "rglru")
NON_FUSED_CELLS = ("gru", "rglru")


def _model(cell, layers=2, hidden=8, **kw):
    return QLSTMConfig(input_size=3, hidden_size=hidden, num_layers=layers,
                       seq_len=4, out_features=2, cell=cell, **kw)


def _x(batch=2, t=4, m=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, (batch, t, m)).astype(np.float32)


# ---------------------------------------------------------------------------
# Registry surfaces
# ---------------------------------------------------------------------------

def test_registry_lists_the_zoo():
    assert cells.available() == ("gru", "lstm", "rglru")
    for name in CELLS:
        spec = cells.get(name)
        assert spec.name == name
        assert spec.state_arity == len(spec.state_names)


def test_registry_unknown_cell_names_known_ones():
    with pytest.raises(KeyError, match="rglru"):
        cells.get("rwkv6")


def test_state_shape_and_init_state_follow_the_spec():
    for name in CELLS:
        m = _model(name, layers=3, hidden=5)
        arity = cells.get(name).state_arity
        assert cells.state_shape(m) == (3, arity, 5)
        st = cells.init_state(m, batch=4)
        assert len(st) == 3
        for layer in st:
            assert len(layer) == arity
            for a in layer:
                assert a.shape == (4, 5) and a.dtype == jnp.int32
                assert not np.any(np.asarray(a))


def test_lstm_init_state_matches_legacy_init_int_state():
    """The generic reset carry is bit-for-bit the historical LSTM one."""
    m = _model("lstm")
    legacy = init_int_state(m, 2)
    generic = cells.init_state(m, 2)
    assert len(legacy) == len(generic)
    for (lh, lc), (gh, gc) in zip(legacy, generic):
        np.testing.assert_array_equal(np.asarray(lh), np.asarray(gh))
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(gc))


def test_unknown_cell_fails_at_build():
    with pytest.raises(KeyError, match="registered"):
        repro.build(_model("lstm").__class__(cell="nope"))


# ---------------------------------------------------------------------------
# The parity battery: ref <-> xla bit-exact, every cell, fxp x hs x layers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layers", [1, 2, 3])
@pytest.mark.parametrize("fp", [FXP_4_8, FXP_8_16],
                         ids=["a4b8", "a8b16"])
@pytest.mark.parametrize("cell", CELLS)
def test_ref_xla_parity(cell, fp, layers):
    """The general (xla) int datapath must match the independently written
    pure-jnp oracle bit-for-bit, for every HardSigmoid* method."""
    base = _model(cell, layers=layers)
    spec = cells.get(cell)
    params = spec.init_params(
        dataclasses.replace(base, fxp=fp), jax.random.key(layers))
    x_int = fxp.quantize(jnp.asarray(_x(seed=layers)), fp)
    for hs_method in HS_METHODS:
        accel = AcceleratorConfig(fxp=fp, hs_method=hs_method)
        m = resolve_model(base, accel, warn=False)
        qp = spec.quantize_params(params, m)
        y_ref = backends.get("ref").run(qp, x_int, m, accel)
        y_xla = backends.get("xla").run(qp, x_int, m, accel)
        np.testing.assert_array_equal(
            np.asarray(y_ref), np.asarray(y_xla),
            err_msg=f"{cell} ref!=xla at {fp} {hs_method} L{layers}")


@pytest.mark.parametrize("cell", CELLS)
def test_stateful_windowed_equals_concatenated_backends(cell):
    """k windows through run_stateful == one run over the k*T sequence,
    bit-exact, on both int engines."""
    m = _model(cell)
    sess = repro.build(m, seed=1).quantize()
    k, t = 3, m.seq_len
    x_int = fxp.quantize(jnp.asarray(_x(t=k * t, seed=7)), sess.model.fxp)
    for name in ("ref", "xla"):
        bk = backends.get(name)
        y_full = bk.run(sess.qparams, x_int, sess.model, sess.accel)
        state = cells.init_state(sess.model, x_int.shape[0])
        for w in range(k):
            y, state = bk.run_stateful(
                sess.qparams, x_int[:, w * t:(w + 1) * t],
                sess.model, sess.accel, state)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_full),
                                      err_msg=f"{cell}@{name}")


@pytest.mark.parametrize("cell", CELLS)
def test_per_step_alu_runs_on_xla(cell):
    """The per-step (baseline [15]) ALU has no oracle, but the general
    datapath must run it for every cell; pipelined vs per-step codes
    genuinely differ (the rounding contract is doing something)."""
    m = _model(cell)
    per = repro.build(m, AcceleratorConfig(alu_mode="per_step"),
                      seed=2).quantize()
    pipe = repro.build(m, AcceleratorConfig(), params=per.params).quantize()
    assert per.plan["backend"] == "xla"
    x = jnp.asarray(_x(seed=3))
    y_per = np.asarray(per.infer(x, path="int"))
    y_pipe = np.asarray(pipe.infer(x, path="int"))
    assert np.all(np.isfinite(y_per))
    assert y_per.shape == y_pipe.shape


@pytest.mark.parametrize("cell", CELLS)
def test_float_and_qat_paths_run(cell):
    sess = repro.build(_model(cell), seed=4)
    x = jnp.asarray(_x(seed=4))
    for path in ("float", "qat"):
        y = np.asarray(sess.infer(x, path=path))
        assert y.shape == (2, 2) and np.all(np.isfinite(y))


# ---------------------------------------------------------------------------
# Plan / backend selection
# ---------------------------------------------------------------------------

def test_plan_carries_cell_and_state_shape():
    for cell in CELLS:
        m = _model(cell)
        p = plan(m, AcceleratorConfig())
        assert p["cell"] == cell
        assert p["state_shape"] == cells.state_shape(m)


def test_auto_backend_per_cell():
    """LSTM keeps the fused kernel; cells without one resolve to xla (and
    therefore to host state residency)."""
    p = plan(_model("lstm"), AcceleratorConfig())
    assert p["backend"] == "pallas" and p["state_residency"] == "device"
    for cell in NON_FUSED_CELLS:
        p = plan(_model(cell), AcceleratorConfig())
        assert p["backend"] == "xla"
        assert p["stateful_backend"] == "xla"
        assert p["state_residency"] == "host"


def test_pallas_refuses_cells_without_fused_kernel():
    for cell in NON_FUSED_CELLS:
        with pytest.raises(BackendUnsupported, match="no fused kernel"):
            backends.select(_model(cell), AcceleratorConfig(),
                            override="pallas")
        with pytest.raises(ValueError, match="no fused kernel"):
            repro.build(_model(cell), AcceleratorConfig(backend="pallas"))


def test_stateful_ladder_per_cell():
    """Non-fused cells degrade xla -> ref; the fused LSTM keeps its
    three-rung ladder."""
    assert repro.build(_model("lstm")).degradation_ladder() == \
        ("pallas", "xla", "ref")
    for cell in NON_FUSED_CELLS:
        assert repro.build(_model(cell)).degradation_ladder() == \
            ("xla", "ref")


def test_report_runs_per_cell():
    for cell in CELLS:
        r = repro.build(_model(cell), seed=5).quantize().report()
        assert r["ops_per_inference"] > 0
        assert r["weight_bytes"] > 0
        assert r["plan"]["cell"] == cell


# ---------------------------------------------------------------------------
# Serving: windowed-vs-concatenated through StreamServer, both residencies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("residency", ["host", "device"])
@pytest.mark.parametrize("cell", CELLS)
def test_stream_server_carry_equals_concatenated(cell, residency):
    """The serving contract, per cell: feeding a stream window-by-window
    through StreamServer is bit-identical to one shot over the
    concatenated sequence — on the host LRU store AND on the
    device-resident slot table (which GRU/rGLRU reach through the
    XLA-level slot adapter, their documented device path)."""
    from repro.serving import StreamServer
    m = _model(cell)
    sess = repro.build(m, seed=6).quantize()
    k, t = 3, m.seq_len
    streams = {f"s{i}": _x(t=k * t, seed=20 + i)[0] for i in range(3)}
    with StreamServer(sess, batch=2, deadline_s=0.005, max_streams=8,
                      state_residency=residency) as srv:
        assert srv.state_residency == residency
        for w in range(k):
            for sid, xs in streams.items():
                srv.submit(sid, xs[w * t:(w + 1) * t])
        results = srv.drain()
    by = {}
    for r in results:
        assert r.error is None
        by.setdefault(r.stream_id, {})[r.seq] = r.y
    for sid, xs in streams.items():
        full = np.asarray(sess.infer(jnp.asarray(xs[None]), path="int"))
        np.testing.assert_array_equal(by[sid][k - 1], full[0],
                                      err_msg=f"{cell}@{residency}:{sid}")


@pytest.mark.parametrize("cell", NON_FUSED_CELLS)
def test_stream_state_read_seed_roundtrip(cell):
    """Warm stream handoff (read_stream_state -> seed_stream_state) is
    carry-shape-agnostic: a moved stream continues bit-exactly."""
    from repro.serving import StreamServer
    m = _model(cell)
    sess = repro.build(m, seed=8).quantize()
    t = m.seq_len
    xs = _x(t=2 * t, seed=31)[0]
    with StreamServer(sess, batch=1, deadline_s=0.005) as src:
        src.submit("mv", xs[:t])
        src.drain()
        st = src.read_stream_state("mv")
    assert st is not None
    assert len(st) == m.num_layers
    assert all(len(layer) == cells.get(cell).state_arity for layer in st)
    with StreamServer(sess, batch=1, deadline_s=0.005) as dst:
        dst.seed_stream_state("mv", st)
        dst.submit("mv", xs[t:])
        (r,) = dst.drain()
    full = np.asarray(sess.infer(jnp.asarray(xs[None]), path="int"))
    np.testing.assert_array_equal(r.y, full[0])


# ---------------------------------------------------------------------------
# Explore: the cell axis
# ---------------------------------------------------------------------------

def test_explore_cell_axis():
    # cell sits between the Table-2 axes and the PR-10 serving axes
    assert explore.AXES[-3:] == ("cell", "replicas", "state_residency")
    space = explore.SearchSpace(cell=("lstm", "gru"))
    assert space.size == 2
    labels = [p.label for p in space.grid()]
    assert labels[0].endswith("_auto")          # lstm label unchanged
    assert labels[1].endswith("_gru")
    with pytest.raises(ValueError, match="cell choice"):
        explore.SearchSpace(cell=("mamba",))


def test_point_from_config_defaults_old_records_to_lstm():
    from repro.explore.space import point_from_config
    p = next(iter(explore.SearchSpace().grid()))
    d = p.asdict()
    del d["cell"]                               # a pre-cell-axis record
    assert point_from_config(d).cell == "lstm"
    assert point_from_config(p.asdict()) == p


def test_point_configs_set_model_cell():
    space = explore.SearchSpace(cell=("rglru",))
    model, accel = next(iter(space.grid())).configs()
    assert model.cell == "rglru"
    assert plan(model, accel)["backend"] == "xla"
