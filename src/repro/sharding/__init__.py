from repro.sharding.partition import (  # noqa: F401
    DEFAULT_RULES, constrain, logical_to_spec, param_shardings,
    resolve_rules, rules_context,
)
