"""Logical-axis sharding rules — FSDP(data) × TP(model) × DP(pod).

Parameters and activations are annotated with LOGICAL axis names; the rules
below map them onto mesh axes (MaxText-style).  Uneven divisions (e.g. 12
heads over 16-way TP) are legal — GSPMD pads — and the waste is visible in
the roofline's useful-FLOPs ratio.

``constrain`` is a contextvar-scoped ``with_sharding_constraint`` so model
code can annotate activations without threading a mesh through every call
(it is a no-op outside a rules context — e.g. single-device smoke tests).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# TP width of the production mesh (launch/mesh.py); used for static layout
# decisions that must be made where the mesh isn't in scope (cache specs).
PRODUCTION_TP = 16

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),      # DP across pods, FSDP-data within
    "seq": None,
    "embed": ("data",),            # FSDP: shard the non-TP weight dim
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": None,               # TP-MoE default; EP overrides to model
    "expert_mlp": ("model",),
    "lru": ("model",),             # RG-LRU width
    "heads_d": ("model",),         # rwkv fused heads*head_dim projection dim
    "mlp2": ("model",),            # rwkv channel-mix receptance dim
    "kv_seq": ("model",),          # decode KV-cache seq dim (sequence-
                                   # parallel attention when kv_heads can't
                                   # use the model axis)
    "layers": None,
    "act_embed": None,             # activation d_model dim
    "act_heads": ("model",),       # activation heads dim
}


def resolve_rules(mesh: Mesh, overrides: Sequence[Tuple[str, Optional[str]]] = ()
                  ) -> Dict[str, Optional[Tuple[str, ...]]]:
    """Filter rules to the axes present in `mesh` and apply per-arch
    overrides."""
    rules = dict(DEFAULT_RULES)
    for k, v in overrides:
        rules[k] = (v,) if isinstance(v, str) else v
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
            continue
        axes = tuple(a for a in v if a in mesh.axis_names)
        out[k] = axes if axes else None
    return out


def logical_to_spec(axes: Tuple[Optional[str], ...],
                    rules: Dict[str, Optional[Tuple[str, ...]]],
                    shape: Optional[Tuple[int, ...]] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Logical axes tuple -> PartitionSpec.

    Guards: (1) a mesh axis is used at most once per spec (GSPMD rule);
    (2) when `shape` is given, mesh axes that do not DIVIDE the dim are
    dropped (JAX requires divisible explicit shardings — e.g. 8 KV heads
    over 16-way TP, or batch=1 decode, fall back to replication; the
    longest dividing PREFIX of the rule's axes is kept)."""
    used = set()
    parts = []
    for i, a in enumerate(axes):
        m = rules.get(a) if a else None
        if m:
            m = tuple(x for x in m if x not in used)
        if m and shape is not None and mesh is not None:
            kept = []
            prod = 1
            for x in m:
                prod *= mesh.shape[x]
                if shape[i] % prod == 0:
                    kept.append(x)
                else:
                    break
            m = tuple(kept)
        if m:
            used.update(m)
            parts.append(m if len(m) > 1 else m[0])
        else:
            parts.append(None)
    return P(*parts)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def param_shardings(axes_tree, mesh: Mesh, overrides=(), shapes_tree=None):
    """axes tree (+ optional twin shapes tree for divisibility guards) ->
    NamedSharding tree."""
    rules = resolve_rules(mesh, overrides)
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, logical_to_spec(axes, rules)),
            axes_tree, is_leaf=_is_axes_leaf)
    return jax.tree.map(
        lambda axes, s: NamedSharding(
            mesh, logical_to_spec(axes, rules, tuple(s.shape), mesh)),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


# --- activation constraints (contextvar-scoped) -----------------------------

_RULES: contextvars.ContextVar = contextvars.ContextVar("partition_rules",
                                                        default=None)


@contextlib.contextmanager
def rules_context(mesh: Mesh, overrides=()):
    token = _RULES.set((mesh, resolve_rules(mesh, overrides)))
    try:
        yield
    finally:
        _RULES.reset(token)


# --- per-replica placement (serving cluster) --------------------------------


def replica_shardings(mesh: Mesh) -> list:
    """One fully-replicated ``NamedSharding`` per coordinate of a
    ``("replica",)`` serving mesh (``launch.mesh.make_serving_mesh``).

    Each returned sharding is ``P()`` over a single-device sub-mesh — i.e.
    "this whole pytree lives on replica *i*'s device".  This is the
    cluster tier's placement primitive: per-replica parameters are small
    (the paper's model is KBs), so every replica holds a full copy pinned
    to its own device rather than sharding one copy across the mesh."""
    if "replica" not in mesh.axis_names:
        raise ValueError(
            f"expected a ('replica',) serving mesh, got axes "
            f"{mesh.axis_names}")
    out = []
    for d in mesh.devices.flat:
        sub = Mesh(np.asarray([d]), ("replica",))
        out.append(NamedSharding(sub, P()))
    return out


def pin_to_device(tree, device):
    """Commit every leaf of ``tree`` to ``device`` (a ``jax.Device`` or a
    ``NamedSharding`` from :func:`replica_shardings`).

    Committed inputs make jit follow them: a datapath whose parameters are
    pinned to replica *i*'s device executes on that device, which is what
    keeps a stream's (h, c) carry replica-local in the serving cluster
    (uncommitted host arrays — the wave inputs — are free to follow)."""
    return jax.device_put(tree, device)


def constrain(x, *axes: Optional[str]):
    """Annotate an activation with logical axes (no-op without rules).

    Must be active while the step function is TRACED (lower()/first call)."""
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(tuple(axes), rules, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
