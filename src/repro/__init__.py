"""repro: parameterised quantised-execution framework (JAX + Pallas).

Reproduction & TPU scale-out of 'Energy Efficient LSTM Accelerators for
Embedded FPGAs through Parameterised Architecture Design'.  See DESIGN.md.
"""
__version__ = "0.1.0"
