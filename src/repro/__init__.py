"""repro: parameterised quantised-execution framework (JAX + Pallas).

Reproduction & TPU scale-out of 'Energy Efficient LSTM Accelerators for
Embedded FPGAs through Parameterised Architecture Design'.  See DESIGN.md.

The front door is the session API (docs/API.md)::

    import repro
    acc = repro.build(model_cfg, accel_cfg)   # Table-2 parameters in
    acc.train_qat(data).quantize()            # QAT -> integer codes
    y = acc.infer(x, path="int")              # bit-exact datapath out

``repro.explore`` searches the configuration space instead of building one
point (docs/API.md §Design-space exploration)::

    session = repro.explore.autotune(objective="gops_per_watt",
                                     constraints={"total_w": (None, 61.0)})
"""
from repro.api import Accelerator, build, build_cluster  # noqa: F401

__version__ = "0.3.3"


def __getattr__(name):
    # Lazy: `repro.explore` / `repro.serving` without paying their import
    # cost on every `import repro` (explore pulls in the benchmark-
    # measurement machinery; serving the threaded scheduler).
    if name == "explore":
        import repro.explore as explore
        return explore
    if name == "serving":
        import repro.serving as serving
        return serving
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
