"""repro: parameterised quantised-execution framework (JAX + Pallas).

Reproduction & TPU scale-out of 'Energy Efficient LSTM Accelerators for
Embedded FPGAs through Parameterised Architecture Design'.  See DESIGN.md.

The front door is the session API (docs/API.md)::

    import repro
    acc = repro.build(model_cfg, accel_cfg)   # Table-2 parameters in
    acc.train_qat(data).quantize()            # QAT -> integer codes
    y = acc.infer(x, path="int")              # bit-exact datapath out
"""
from repro.api import Accelerator, build  # noqa: F401

__version__ = "0.2.0"
