"""Device-resident per-stream carry — the slot allocator and state table.

The host-side :class:`~repro.serving.state.StateStore` ships every
stream's carry codes to the device and back on EVERY wave.  This module
is ROADMAP item 1's answer: the carries live in one persistent
``(max_slots + 2, L, S, H)`` int32 table ON the accelerator (``(L, S,
H)`` is the cell's ``plan()['state_shape']`` — ``S == 2`` (h, c) rows
for the LSTM, ``S == 1`` for GRU/rGLRU)
(``Accelerator.init_state_table``), and the host keeps only a
:class:`SlotAllocator` — an LRU map ``stream_id -> table row`` with
exactly the hit/miss/eviction accounting of the ``StateStore`` it
replaces.  Per wave the scheduler ships two (B,) int32 slot-id vectors;
the kernel (``kernels/qlstm_cell.qlstm_seq_slot_pallas``) gathers each
row's carry at t == 0 and scatters the final state at t == T-1, so no
carry array crosses the host/device boundary on the hot path — the
paper's state-next-to-compute residency argument, and ELSA's throughput
lever, applied to serving.

Table row conventions (shared with the kernel and the XLA-level adapter
``backends.common.run_slots_via_state``):

  * rows ``0 .. max_slots-1`` — live stream carries, owned by the
    allocator;
  * row ``max_slots`` (:attr:`DeviceStateStore.zero_slot`) — the RESET
    row: always all-zero, gathered by fresh/evicted/ended streams, never
    written;
  * row ``max_slots + 1`` (:attr:`DeviceStateStore.trash_slot`) — the
    TRASH row: the scatter target for padding rows, tombstoned windows,
    and same-wave eviction victims; never read.

Eviction/reset semantics are IDENTICAL to the host store: an evicted or
brand-new stream gathers the ZERO row and its first window back is
flagged ``state_reset=True``.  The stale codes left in a freed slot are
unreachable — a returning stream misses in the allocator before it could
ever gather them, and the slot's next owner overwrites them at its first
scatter.

The only time a carry crosses back to the host is PLANNED stream
movement: :meth:`DeviceStateStore.read_state` /
:meth:`DeviceStateStore.seed_state`, used by
``ClusterServer.remove_replica`` to hand a draining replica's streams to
their new ring homes warm (docs/SERVING.md §Scaling out).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.state import StreamState


class SlotAllocator:
    """LRU map ``stream_id -> slot id`` over ``capacity`` device-table rows.

    The host half of the device-resident state store: it decides WHICH
    table row each stream's carry occupies, with the exact semantics of
    ``StateStore`` — :meth:`lookup` is ``get`` (recency refresh,
    hit/miss counters), :meth:`assign` is ``put`` (insert or refresh,
    LRU eviction when full), :meth:`release` is ``pop``.  Slot ids are
    unique among live streams; released slots are reused (LIFO) before
    the high-water mark grows, so a bursty tenancy pattern touches the
    fewest distinct table rows.

    NOT thread-safe on its own — :class:`DeviceStateStore` serialises
    access under its lock, exactly like ``StateStore`` does internally."""

    def __init__(self, capacity: int = 1024):
        """``capacity``: number of live stream slots (>= 1)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._slots: "OrderedDict[Hashable, int]" = OrderedDict()
        self._free: List[int] = []      # released slots, reused LIFO
        self._next = 0                  # high-water mark of slots ever used
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, stream_id: Hashable) -> Optional[int]:
        """The stream's slot (refreshing its recency), or ``None`` when
        the stream is new or was evicted — the caller gathers the ZERO
        row.  Mirrors ``StateStore.get``, counters included."""
        slot = self._slots.get(stream_id)
        if slot is None:
            self.misses += 1
            return None
        self._slots.move_to_end(stream_id)
        self.hits += 1
        return slot

    def assign(self, stream_id: Hashable) -> Tuple[int, List[Hashable]]:
        """The slot the stream's next scatter should target, allocating
        one if needed; returns ``(slot, evicted_ids)``.  Mirrors
        ``StateStore.put``: an existing stream keeps its slot (recency
        refreshed); a new stream takes a freed slot, a never-used slot,
        or — when all ``capacity`` slots are live — the LRU victim's
        (the victim is evicted and returned so the caller can release its
        bookkeeping and redirect any same-wave scatter to TRASH)."""
        if stream_id in self._slots:
            self._slots.move_to_end(stream_id)
            return self._slots[stream_id], []
        evicted: List[Hashable] = []
        if self._free:
            slot = self._free.pop()
        elif self._next < self.capacity:
            slot = self._next
            self._next += 1
        else:
            victim, slot = self._slots.popitem(last=False)
            self.evictions += 1
            evicted.append(victim)
        self._slots[stream_id] = slot
        return slot, evicted

    def release(self, stream_id: Hashable) -> Optional[int]:
        """Free a stream's slot (end-of-stream / state loss); returns the
        slot or ``None``.  Mirrors ``StateStore.pop``."""
        slot = self._slots.pop(stream_id, None)
        if slot is not None:
            self._free.append(slot)
        return slot

    def slot_of(self, stream_id: Hashable) -> Optional[int]:
        """Peek at a stream's slot WITHOUT touching recency or counters
        (for fault injection and state read-back)."""
        return self._slots.get(stream_id)

    @property
    def high_water(self) -> int:
        """Distinct slots ever handed out — the reuse property tests pin
        that this never exceeds the peak number of live streams."""
        return self._next

    def live(self) -> Dict[Hashable, int]:
        """Snapshot of the live ``stream_id -> slot`` map, LRU-first."""
        return dict(self._slots)

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._slots


class DeviceStateStore:
    """The device-resident replacement for ``StateStore``: a
    :class:`SlotAllocator` plus the accelerator-resident state table.

    API-compatible with ``StateStore`` where the serving layer needs it
    (``pop`` / ``stats`` / ``__len__`` / ``__contains__`` / ``capacity``),
    plus the slot surface the device hot path runs on (:meth:`lookup` /
    :meth:`assign` / :meth:`commit`) and the planned-movement surface
    (:meth:`read_state` / :meth:`seed_state`).  All methods take the
    internal lock; multi-op wave transactions are additionally serialised
    by the server's own lock, like the host store's gather/scatter."""

    def __init__(self, session, capacity: int = 1024):
        """``session``: the (quantised) ``Accelerator`` whose device owns
        the table; ``capacity``: live stream slots (the ``max_streams``
        serving knob)."""
        self.capacity = capacity
        self._alloc = SlotAllocator(capacity)
        self._model = session.model
        #: The persistent (capacity + 2, L, S, H) int32 carry table.  The
        #: serving hot path replaces this reference wholesale after each
        #: wave (:meth:`commit`) — the array itself never visits the host.
        self.table = session.init_state_table(capacity)
        self._lock = threading.Lock()

    @property
    def zero_slot(self) -> int:
        """Table row fresh/reset streams gather from (always zero)."""
        return self.capacity

    @property
    def trash_slot(self) -> int:
        """Table row retired/padding rows scatter to (never read)."""
        return self.capacity + 1

    # -- wave surface (serialised by the server's lock) ----------------------

    def lookup(self, stream_id: Hashable) -> Optional[int]:
        """GET-phase slot lookup — ``StateStore.get`` semantics."""
        with self._lock:
            return self._alloc.lookup(stream_id)

    def assign(self, stream_id: Hashable) -> Tuple[int, List[Hashable]]:
        """PUT-phase slot assignment — ``StateStore.put`` semantics."""
        with self._lock:
            return self._alloc.assign(stream_id)

    def commit(self, new_table, rows: List[Tuple[int, Hashable]]) -> None:
        """Adopt the kernel's updated table after a successful wave.
        ``rows`` lists the wave's real scatters as ``(batch_row,
        stream_id)`` — unused here, but the fault-injection wrapper draws
        its per-put schedule from them (``faults.FaultyDeviceStateStore``),
        keeping the injected schedule identical to the host store's."""
        with self._lock:
            self.table = new_table

    def pop(self, stream_id: Hashable) -> Optional[int]:
        """Release a stream's slot (end-of-stream, failed wave, shed,
        injected loss).  The freed row's stale codes are unreachable: the
        stream now misses in the allocator, and the slot's next owner
        overwrites them at its first scatter.  Returns the freed slot."""
        with self._lock:
            return self._alloc.release(stream_id)

    def ids(self) -> List[Hashable]:
        """Snapshot of the stream ids currently holding slots, LRU-first —
        the server's ``reset_streams()`` walks it to end every stream."""
        with self._lock:
            return list(self._alloc.live())

    # -- planned movement (cluster drain/rebalance) --------------------------

    def read_state(self, stream_id: Hashable) -> Optional[StreamState]:
        """Read a stream's carry BACK to the host — the one sanctioned
        host/device state transfer, used only on planned stream movement
        (``ClusterServer.remove_replica``).  Returns per layer a tuple of
        the cell's ``state_arity`` int32 rows (``[(h, c), ...]`` for the
        LSTM), or ``None`` for an unknown stream."""
        with self._lock:
            slot = self._alloc.slot_of(stream_id)
            table = self.table
        if slot is None:
            return None
        row = np.asarray(table[slot])              # (L, S, H) — one stream
        return [tuple(row[li, s].copy() for s in range(row.shape[1]))
                for li in range(row.shape[0])]

    def seed_state(self, stream_id: Hashable,
                   state: StreamState) -> List[Hashable]:
        """Plant a host-side carry into the table (the destination half of
        a warm handoff): assigns a slot and writes the row.  Returns any
        ids the assignment evicted."""
        with self._lock:
            slot, evicted = self._alloc.assign(stream_id)
            row = jnp.asarray(
                np.stack([np.stack([np.asarray(a) for a in layer])
                          for layer in state]).astype(np.int32))
            self.table = self.table.at[slot].set(row)
        return evicted

    # -- fault-injection surface ---------------------------------------------

    def corrupt_slot(self, stream_id: Hashable) -> bool:
        """XOR the low bit of every code in the stream's table row — the
        device form of the host store's put-corruption (same perturbation
        as ``FaultInjector._mutate_put``).  Returns False for an unknown
        stream (nothing to corrupt)."""
        with self._lock:
            slot = self._alloc.slot_of(stream_id)
            if slot is None:
                return False
            self.table = self.table.at[slot].set(
                jnp.bitwise_xor(self.table[slot], 1))
            return True

    # -- StateStore-compatible reporting ------------------------------------

    def stats(self) -> Dict[str, int]:
        """The ``StateStore`` counter block (live_streams, capacity,
        hits, misses, evictions) plus ``residency``/``slot_high_water``
        — the serving metrics report is schema-compatible either way."""
        with self._lock:
            return {"live_streams": len(self._alloc),
                    "capacity": self.capacity,
                    "hits": self._alloc.hits,
                    "misses": self._alloc.misses,
                    "evictions": self._alloc.evictions,
                    "residency": "device",
                    "slot_high_water": self._alloc.high_water}

    def __len__(self) -> int:
        with self._lock:
            return len(self._alloc)

    def __contains__(self, stream_id: Hashable) -> bool:
        with self._lock:
            return stream_id in self._alloc

    def __getattr__(self, name):
        raise AttributeError(
            f"DeviceStateStore has no attribute {name!r}; host-store-only "
            f"surfaces (get/put of carry arrays) do not exist on the "
            f"device path — pin ServingConfig(state_residency='host') for "
            f"host-store semantics")
