"""Deterministic fault injection for the serving tier — the chaos harness.

The paper's deployment is always-on embedded inference; a serving stack
that only works when nothing ever fails is not that deployment.  This
module makes failure a *testable input*: a seedable :class:`FaultInjector`
wraps the two surfaces where production faults land —

  * the **backend execute path** (:meth:`FaultInjector.wrap_fn`): injected
    compute exceptions (a Pallas lowering hiccup, a device error) and
    latency spikes (a descheduled host thread, a contended device);
  * the **state store** (:meth:`FaultInjector.wrap_state_store`): state
    *loss* (a carry silently dropped, as a crashed replica would) and
    state *corruption* (bit flips in the stored carry codes).

Everything is driven by one ``numpy`` PCG64 generator, so a given
``(seed, rates)`` pair injects the exact same schedule every run — chaos
tests assert exact counter values, not "some faults probably happened".
The injector records what it did (:meth:`stats`, :attr:`corrupted_streams`,
:attr:`lost_streams`), so a test can partition streams into *survivors*
(untouched by state faults — these must stay bit-exact with the
concatenated-sequence oracle) and *casualties* (these must be *flagged*,
via ``StreamResult.state_reset`` or an error, never silently wrong).

The injector is inert by default: every rate is 0.0, and a
``StreamServer`` built without one pays no wrapping cost at all.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Hashable, List, Optional, Set

import numpy as np

from repro.serving.state import StateStore, StreamState


class InjectedFault(RuntimeError):
    """The exception :class:`FaultInjector` raises on the execute path.

    A distinct type so the resilience layer (and tests) can tell an
    injected fault from a real defect — real defects must still surface."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-surface fault rates, all probabilities per *event* in [0, 1].

    ``wave_fault_rate``: chance one execute *attempt* raises
    :class:`InjectedFault` (retries draw independently, so a retried wave
    usually lands).  ``latency_spike_rate`` / ``latency_spike_s``: chance
    an attempt sleeps ``latency_spike_s`` before computing (drives the
    guard's timeout path).  ``state_loss_rate``: chance a ``put`` into the
    state store is silently dropped — the stream's next window starts from
    the reset carry exactly like an LRU eviction.  ``state_corrupt_rate``:
    chance a ``put`` stores bitwise-perturbed carry codes (the stream's
    id is recorded so tests can exclude it from bit-exactness)."""

    wave_fault_rate: float = 0.0
    latency_spike_rate: float = 0.0
    latency_spike_s: float = 0.05
    state_loss_rate: float = 0.0
    state_corrupt_rate: float = 0.0

    def __post_init__(self):
        """Validate every rate is a probability."""
        for f in ("wave_fault_rate", "latency_spike_rate",
                  "state_loss_rate", "state_corrupt_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.latency_spike_s < 0:
            raise ValueError(
                f"latency_spike_s must be >= 0, got {self.latency_spike_s}")


class FaultInjector:
    """Seeded chaos source for one ``StreamServer`` run.

    One injector owns one PCG64 stream; draws are serialised under a lock
    (the execute path and the state store live on different threads), so
    the injected schedule is a pure function of ``(seed, config)`` and the
    order of events.  Construct with either a :class:`FaultConfig` or the
    equivalent keyword rates::

        inj = FaultInjector(seed=7, wave_fault_rate=0.2)
        server = StreamServer(sess, batch=8, fault_injector=inj)
    """

    def __init__(self, config: Optional[FaultConfig] = None, *,
                 seed: int = 0, **rates):
        """``config`` or keyword rates (``wave_fault_rate=...``, see
        :class:`FaultConfig`); ``seed`` fixes the injection schedule."""
        if config is not None and rates:
            raise ValueError("pass a FaultConfig or keyword rates, not both")
        self.config = config or FaultConfig(**rates)
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {
            "attempts": 0, "wave_faults": 0, "latency_spikes": 0,
            "state_losses": 0, "state_corruptions": 0}
        #: Stream ids whose stored carry was bitwise-perturbed — their
        #: outputs are expected to diverge from the oracle.
        self.corrupted_streams: Set[Hashable] = set()
        #: Stream ids that lost a carry — their next window restarts from
        #: the reset state (and must be flagged ``state_reset``).
        self.lost_streams: Set[Hashable] = set()

    def _draw(self, rate: float) -> bool:
        return rate > 0.0 and float(self._rng.random()) < rate

    # -- execute-path surface ------------------------------------------------

    def wrap_fn(self, fn: Callable, label: str = "") -> Callable:
        """Wrap a compiled datapath callable: each call first draws a
        latency spike (sleep), then a compute fault (:class:`InjectedFault`)
        — in that fixed order, so the schedule is deterministic — then
        delegates.  ``label`` names the wrapped engine in the raise."""
        cfg = self.config

        def chaotic(*args, **kwargs):
            with self._lock:
                self._counts["attempts"] += 1
                spike = self._draw(cfg.latency_spike_rate)
                fault = self._draw(cfg.wave_fault_rate)
                if spike:
                    self._counts["latency_spikes"] += 1
                if fault:
                    self._counts["wave_faults"] += 1
            if spike:
                time.sleep(cfg.latency_spike_s)
            if fault:
                raise InjectedFault(
                    f"injected compute fault"
                    f"{f' on {label}' if label else ''} "
                    f"(seed={self.seed}, attempt "
                    f"{self._counts['attempts']})")
            return fn(*args, **kwargs)

        return chaotic

    # -- state-store surface -------------------------------------------------

    def wrap_state_store(self, store: StateStore) -> "FaultyStateStore":
        """A delegating view of ``store`` whose ``put`` may drop or corrupt
        carries according to the configured rates."""
        return FaultyStateStore(store, self)

    def wrap_device_state_store(self, store) -> "FaultyDeviceStateStore":
        """The device-residency counterpart of :meth:`wrap_state_store`:
        a delegating view of a ``DeviceStateStore`` whose per-wave
        ``commit`` draws the same lose-then-corrupt schedule per stored
        row (in batch-row order) that the host store draws per ``put`` —
        so a given seed injects one identical schedule whichever side of
        the host/device boundary the carry lives on."""
        return FaultyDeviceStateStore(store, self)

    def draw_put_fault(self, stream_id: Hashable) -> str:
        """One put-side draw for ``stream_id``: ``"lose"`` (the carry is
        dropped — counted and recorded in :attr:`lost_streams`),
        ``"corrupt"`` (the stored codes must be bit-perturbed — counted
        and recorded in :attr:`corrupted_streams`), or ``"none"``.  Both
        store wrappers consume the RNG through this single method, in the
        same lose-then-corrupt order, which is what keeps the host and
        device schedules identical for a given seed."""
        with self._lock:
            lose = self._draw(self.config.state_loss_rate)
            corrupt = (not lose) and self._draw(self.config.state_corrupt_rate)
            if lose:
                self._counts["state_losses"] += 1
                self.lost_streams.add(stream_id)
                return "lose"
            if corrupt:
                self._counts["state_corruptions"] += 1
                self.corrupted_streams.add(stream_id)
                return "corrupt"
            return "none"

    def _mutate_put(self, stream_id: Hashable,
                    state: StreamState) -> Optional[StreamState]:
        """The host-store put-side injection: ``None`` means drop the put
        entirely (state loss); otherwise the possibly-corrupted state to
        store."""
        fault = self.draw_put_fault(stream_id)
        if fault == "lose":
            return None
        if fault != "corrupt":
            return state
        # XOR a low bit of every code: bitwise-plausible corruption that
        # is guaranteed to change the carry.
        return [tuple(np.bitwise_xor(np.asarray(a), 1) for a in layer)
                for layer in state]

    # -- reporting -----------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        """Injection counters (attempts seen, faults/spikes/losses/
        corruptions injected) — the ``faults.injected`` block of
        ``metrics_summary()``."""
        with self._lock:
            return dict(self._counts)


class FaultyStateStore:
    """A :class:`~repro.serving.state.StateStore` view with injected
    ``put`` faults; every other method delegates verbatim.

    Kept API-compatible with ``StateStore`` (``get``/``put``/``pop``/
    ``stats``/``__len__``/``__contains__``/``capacity``) so
    ``StreamServer`` and its tests cannot tell the difference — which is
    the point."""

    def __init__(self, store: StateStore, injector: FaultInjector):
        """Wrap ``store`` with the injector's put-side schedule."""
        self._store = store
        self._injector = injector

    @property
    def capacity(self) -> int:
        """The wrapped store's capacity."""
        return self._store.capacity

    def get(self, stream_id: Hashable) -> Optional[StreamState]:
        """Delegates to the wrapped store (reads are never faulted — a
        lost carry is modelled at put time, like a crashed replica)."""
        return self._store.get(stream_id)

    def put(self, stream_id: Hashable,
            state: StreamState) -> List[Hashable]:
        """Store the carry — unless the schedule drops it (the stream's
        existing carry is also popped, so the loss is observable) or
        corrupts it first."""
        mutated = self._injector._mutate_put(stream_id, state)
        if mutated is None:
            self._store.pop(stream_id)
            return []
        return self._store.put(stream_id, mutated)

    def pop(self, stream_id: Hashable) -> Optional[StreamState]:
        """Delegates to the wrapped store."""
        return self._store.pop(stream_id)

    def ids(self) -> List[Hashable]:
        """Delegates to the wrapped store (``reset_streams`` support)."""
        return self._store.ids()

    def stats(self) -> Dict[str, int]:
        """The wrapped store's counters."""
        return self._store.stats()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._store


class FaultyDeviceStateStore:
    """A ``DeviceStateStore`` view with injected commit-time faults; every
    other method delegates verbatim (kept API-compatible so the serving
    layer cannot tell the difference — which is the point).

    On the device path the kernel has already scattered every row's carry
    into the table by the time the wave commits, so faults land AT COMMIT,
    once per really-stored row in batch-row order — the exact points the
    host store draws at (one ``put`` per row, same order).  A ``lose``
    releases the row's slot (the scattered carry becomes unreachable — the
    stream's next window restarts from the ZERO row, flagged
    ``state_reset``, exactly like the host store popping the carry); a
    ``corrupt`` XORs the low bit of every code in the row's table slot
    (the same perturbation ``FaultyStateStore`` stores)."""

    def __init__(self, store, injector: FaultInjector):
        """Wrap ``store`` (a ``DeviceStateStore``) with ``injector``'s
        put-side schedule."""
        self._store = store
        self._injector = injector

    def commit(self, new_table, rows) -> None:
        """Adopt the wave's updated table, then apply one put-fault draw
        per stored row (``rows``: the wave's ``(batch_row, stream_id)``
        scatters, in batch-row order)."""
        self._store.commit(new_table, rows)
        for _, sid in rows:
            fault = self._injector.draw_put_fault(sid)
            if fault == "lose":
                self._store.pop(sid)
            elif fault == "corrupt":
                self._store.corrupt_slot(sid)

    def __getattr__(self, name):
        # lookup/assign/pop/read_state/seed_state/corrupt_slot/stats/
        # table/capacity/zero_slot/trash_slot delegate verbatim.
        return getattr(self._store, name)

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, stream_id: Hashable) -> bool:
        return stream_id in self._store
