"""``ClusterServer`` — mesh-backed multi-replica serving with
consistent-hash stream routing.

One ``StreamServer`` is one device's worth of throughput (the paper's §6
point: 32 873 samples/s on one FPGA).  The ROADMAP's millions-of-users
scenario scales OUT: N replica servers, each pinned to its own device
(``Accelerator.replicate`` / ``launch.mesh.serving_devices``), each owning
its own scheduler threads, state store, and overload policy — and a
routing layer in front that keeps the one invariant scale-out must not
break: **a stream's (h, c) carry never migrates across replicas on the
hot path**.  ELSA's state-residency argument at cluster scale — recurrent
state stays next to the compute that consumes it.

The invariant comes from :class:`~repro.serving.routing.HashRing`
(consistent hashing with virtual nodes): every named stream hashes to
exactly one replica, so all its windows execute there and its carry stays
in that replica's ``StateStore``.  ``StreamResult.routed_replica`` carries
the replica name out, so the invariant is testable per row.

Deployment shape::

    replicas = acc.replicate(4)               # one session per device
    cluster = ClusterServer(replicas, batch=64, deadline_s=0.005)
    cluster.submit("sensor-17", window)       # routed by consistent hash
    for r in cluster.poll(timeout=0.1):       # r.routed_replica pins it
        route(r.stream_id, r.y)
    cluster.metrics_summary()                 # aggregate + per-replica
    cluster.remove_replica("r3")              # drain: ~K/N streams move
    cluster.close()

The cluster layer COMPOSES the per-replica machinery rather than
reimplementing it: admission control and load shedding run per replica
(``OverloadPolicy``), guarded execution and backend degradation run per
replica (``ExecutionGuard``), and the front door adds only what needs the
global view — routing, failover off a replica whose ``health()`` reports
``failed``, aggregate metrics (``MetricsSink.merge``), and the
drain/rebalance path whose ring-shrink moves only the leaving replica's
~K/N streams (their carries reset with ``state_reset=True`` provenance;
every other stream is untouched).

Re-route semantics (rebalance, failover, or a ring change): a moved
stream RESTARTS on its new replica — sequence numbering from 0 and the
zero reset carry, with its first window flagged ``state_reset=True``
because the history was real.  This mirrors the ``StreamServer`` LRU
eviction semantics exactly: a flagged reset, never a silently wrong
continuation from a stale carry left on the old device.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.metrics import MetricsSink
from repro.serving.routing import HashRing
from repro.serving.scheduler import ServerOverloaded
from repro.serving.server import (ServingConfig, StreamResult, StreamServer,
                                  _params_equal)

# faults keys summed across replicas by metrics_summary (deadline_miss_rate
# is taken as the worst replica's instead; backend/degraded summarised).
_FAULT_SUM_KEYS = ("retries", "timeouts", "wave_failures", "degradations",
                   "promotions", "probes", "sheds", "rejections",
                   "recoveries", "state_resets", "stream_errors")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Knobs of the cluster tier (per-replica behaviour stays in the
    embedded :class:`ServingConfig` — one config, applied to every
    replica's ``StreamServer``).

    ``serving``: the per-replica streaming config (batch, deadline,
    backpressure, resilience, overload — docs/SERVING.md).  ``vnodes`` /
    ``seed``: the consistent-hash ring's smoothing and hash namespace
    (``routing.HashRing``).  ``failover``: when a replica's ``health()``
    reports ``failed`` at submit time, take it out of the ring and
    re-route the stream to the next replica (flagged ``state_reset``)
    instead of re-raising the replica's error to the client; False
    propagates the error and leaves ring surgery to the operator
    (``mark_unhealthy`` / ``remove_replica``)."""

    serving: ServingConfig = ServingConfig()
    vnodes: int = 64
    seed: int = 0
    failover: bool = True

    def __post_init__(self):
        """Reject nonsense at construction (the ring checks vnodes too,
        but failing here names the config field)."""
        if self.vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {self.vnodes}")


class ClusterServer:
    """Consistent-hash front door over N per-device ``StreamServer``
    replicas (see the module docstring for the deployment shape and the
    re-route semantics).

    Each replica runs its OWN scheduler threads — wave assembly and
    device compute proceed in parallel across replicas, which is where
    the aggregate-throughput scaling comes from (the single
    ``StreamServer`` multi-session mode only round-robins one compute
    thread)."""

    def __init__(self, replicas: Sequence, config: Optional[ClusterConfig]
                 = None, *, names: Optional[Sequence[str]] = None,
                 **overrides):
        """``replicas``: ``Accelerator`` sessions of ONE configuration
        sharing one set of weights — typically ``Accelerator.replicate``'s
        output, each pinned to its own device.  ``names`` labels them on
        the ring (default ``r0..rN-1``).  ``config`` or keyword overrides
        set :class:`ClusterConfig`; override keys that are not cluster
        fields fall through to the per-replica :class:`ServingConfig`
        (``batch=``, ``deadline_s=``, ...)."""
        replicas = list(replicas)
        if not replicas:
            raise ValueError("need at least one replica session")
        for s in replicas[1:]:
            if s.model != replicas[0].model:
                raise ValueError(
                    "cluster replicas must share one configuration; got "
                    f"models {s.model} != {replicas[0].model}")
            if not _params_equal(s.params, replicas[0].params):
                raise ValueError(
                    "cluster replicas must share one set of weights; the "
                    "given sessions' params differ")
        cfg = config or ClusterConfig()
        if overrides:
            cluster_keys = {f.name for f in dataclasses.fields(ClusterConfig)}
            own = {k: v for k, v in overrides.items() if k in cluster_keys}
            rest = {k: v for k, v in overrides.items()
                    if k not in cluster_keys}
            if rest:
                own["serving"] = dataclasses.replace(cfg.serving, **rest)
            cfg = dataclasses.replace(cfg, **own)
        self.config = cfg
        if names is None:
            names = [f"r{i}" for i in range(len(replicas))]
        if len(names) != len(replicas) or len(set(names)) != len(names):
            raise ValueError(
                f"names must be unique, one per replica; got {names!r} for "
                f"{len(replicas)} replicas")
        self._servers: Dict[str, StreamServer] = {}
        for name, sess in zip(names, replicas):
            self._servers[name] = StreamServer(sess, cfg.serving)
        self._ring = HashRing(names, vnodes=cfg.vnodes, seed=cfg.seed)
        self._lock = threading.Lock()
        # Routing state, all under _lock:
        #   _route[sid]        -> replica currently serving the stream
        #   _hist[sid]         -> windows ever submitted for the stream
        #   _reset_pending[sid] -> replica whose FIRST result for the
        #                          stream must be flagged state_reset (the
        #                          stream was moved there with history)
        self._route: Dict[Hashable, str] = {}
        self._hist: Dict[Hashable, int] = {}
        self._reset_pending: Dict[Hashable, str] = {}
        self._unhealthy: Dict[str, str] = {}   # name -> reason
        self._stash: List[StreamResult] = []   # results of removed replicas
        self._closed = False

    # -- routing ------------------------------------------------------------

    def replica_for(self, stream_id: Hashable) -> str:
        """The replica the NEXT window of ``stream_id`` will route to —
        what an external load balancer would compute from the same ring."""
        with self._lock:
            return self._ring.route(stream_id)

    @property
    def replicas(self) -> List[str]:
        """Replica names currently serving (on the ring)."""
        with self._lock:
            return sorted(self._ring.nodes)

    def _routed_submit(self, stream_id: Hashable, window) -> int:
        """Route + submit with the move/failover bookkeeping.  The lock is
        NEVER held across the inner (possibly blocking) ``submit`` —
        otherwise a backpressured replica would wedge ``poll`` and
        deadlock the whole cluster."""
        for _ in range(len(self._servers) + 1):
            with self._lock:
                target = self._ring.route(stream_id)
                prev = self._route.get(stream_id)
                hist = self._hist.get(stream_id, 0)
                moved = prev is not None and prev != target
                server = self._servers[target]
                old = self._servers.get(prev) if moved else None
            if moved and old is not None:
                # The old replica's carry is stale the moment the stream
                # moves; end_stream is in-flight-safe (tombstone watermark)
                # so a window still queued there cannot resurrect it.
                old.end_stream(stream_id)
            try:
                seq = server.submit(stream_id, window)
            except ServerOverloaded as e:
                # Per-replica admission control IS the cluster's front
                # door: the stream's replica is saturated, and routing it
                # elsewhere would break the state-locality invariant.
                raise ServerOverloaded(f"replica {target!r}: {e}") from None
            except ValueError:
                raise          # malformed window: the client's bug, not
                               # the replica's health
            except Exception as e:
                with self._lock:
                    gone = target not in self._ring
                    ring_len = len(self._ring)
                if gone and ring_len >= 1:
                    continue   # replica left the ring mid-submit
                               # (remove/mark race): re-route, don't raise
                if (self.config.failover and ring_len > 1
                        and server.health()["status"] == "failed"):
                    self.mark_unhealthy(target, reason=f"{type(e).__name__}:"
                                        f" {e}")
                    continue   # re-route on the shrunk ring
                raise
            with self._lock:
                if moved or (prev is None and hist > 0):
                    # Moved with real history: the first window at the new
                    # home computes from the zero reset carry — flag it.
                    self._reset_pending[stream_id] = target
                self._route[stream_id] = target
                self._hist[stream_id] = hist + 1
            return seq
        raise RuntimeError(
            "no healthy replica accepted the stream after exhausting the "
            f"ring (unhealthy: {sorted(self._unhealthy)})")

    # -- client surface -----------------------------------------------------

    def submit(self, stream_id: Hashable, window) -> int:
        """Enqueue one (T, M) float window for ``stream_id`` on its ring
        replica; returns the stream's sequence number AT THAT REPLICA
        (numbering restarts from 0 when a rebalance moves the stream —
        the flagged-reset semantics in the module docstring).  Raises
        ``ServerOverloaded`` when the stream's replica rejects under its
        ``OverloadPolicy``; with ``failover`` a replica whose ``health()``
        is ``failed`` is removed from the ring and the stream re-routed
        instead of surfacing the replica's error."""
        if self._closed:
            raise RuntimeError("cluster is closed")
        return self._routed_submit(stream_id, window)

    def poll(self, timeout: float = 0.0) -> List[StreamResult]:
        """Completed rows from every replica, each stamped with the
        replica name in ``routed_replica`` (plus anything stashed by
        ``remove_replica``).  With ``timeout`` > 0, waits up to that long
        for the first batch."""
        end = time.perf_counter() + timeout
        while True:
            out: List[StreamResult] = []
            with self._lock:
                out.extend(self._stash)
                self._stash.clear()
                servers = list(self._servers.items())
            for name, srv in servers:
                out.extend(self._translate(name, r) for r in srv.poll())
            if out:
                return out
            remaining = end - time.perf_counter()
            if remaining <= 0:
                return out
            time.sleep(min(remaining, 0.02))

    def flush(self, timeout: Optional[float] = None) -> None:
        """Barrier across every replica: all windows submitted before the
        call are computed when it returns."""
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.flush(timeout=timeout)

    def drain(self, timeout: Optional[float] = None) -> List[StreamResult]:
        """``flush`` then collect everything outstanding."""
        self.flush(timeout=timeout)
        return self.poll()

    def end_stream(self, stream_id: Hashable) -> None:
        """Forget a stream cluster-wide: its carry on its replica and the
        cluster's routing bookkeeping — the next window under the same id
        is a brand-new stream."""
        with self._lock:
            name = self._route.pop(stream_id, None)
            self._hist.pop(stream_id, None)
            self._reset_pending.pop(stream_id, None)
            server = self._servers.get(name) if name is not None else None
        if server is not None:
            server.end_stream(stream_id)

    def close(self, abandon: bool = False,
              timeout: float = 30.0) -> List[str]:
        """Stop every replica (drain first unless ``abandon``).  Returns
        leaked thread names across all replicas (empty = clean)."""
        self._closed = True
        leaked: List[str] = []
        with self._lock:
            servers = list(self._servers.items())
        for name, srv in servers:
            leaked.extend(f"{name}:{t}"
                          for t in srv.close(abandon=abandon,
                                             timeout=timeout))
        return leaked

    def __enter__(self) -> "ClusterServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(abandon=exc_type is not None)

    # -- membership / rebalance ---------------------------------------------

    def add_replica(self, session, name: Optional[str] = None) -> str:
        """Grow the cluster: ``session`` (a replica of the same weights,
        ideally device-pinned) joins the ring under ``name``.  ~K/(N+1)
        existing streams re-route to it lazily — each moves on its next
        submit, restarting with ``state_reset=True`` provenance; the rest
        never notice."""
        with self._lock:
            ref = next(iter(self._servers.values()), None)
            if name is None:
                i = len(self._servers) + len(self._unhealthy)
                while f"r{i}" in self._servers or f"r{i}" in self._unhealthy:
                    i += 1
                name = f"r{i}"
            if name in self._servers or name in self._unhealthy:
                raise ValueError(f"replica name {name!r} already in use")
        if ref is not None:
            sess0 = ref._sessions[0]
            if session.model != sess0.model \
                    or not _params_equal(session.params, sess0.params):
                raise ValueError(
                    "new replica must share the cluster's configuration "
                    "and weights")
        server = StreamServer(session, self.config.serving)
        with self._lock:
            self._servers[name] = server
            self._ring.add(name)
        return name

    def remove_replica(self, name: str, abandon: bool = False,
                       timeout: Optional[float] = 30.0) -> List[Hashable]:
        """Drain ``name`` out of the cluster: the ring shrinks FIRST (new
        windows re-route), its in-flight windows are flushed and their
        results stashed for the next ``poll``, and the replica's server is
        closed.  Returns the ids of the streams that lose their home —
        only ~K/N of the cluster's streams (the consistent-hash guarantee;
        everything else keeps replica, carry, and numbering).

        What a moved stream keeps depends on where its carry lived.  With
        HOST-resident state it restarts cold: ``state_reset=True``
        provenance on its first window at the new replica.  With
        DEVICE-resident state (``state_residency='device'``/``auto`` on a
        pallas plan) a planned drain performs a WARM HANDOFF: after the
        flush, each moved stream's carry is read back from the draining
        replica's device table (the one sanctioned host/device state
        transfer) and seeded into its new ring home, so its recurrence
        continues bit-exactly — no reset, no flag (its per-replica seq
        still restarts at 0).  ``abandon=True`` skips drain AND handoff
        (replica died; pending windows and device-resident carries are
        lost, and the moved streams restart cold with flagged resets).
        Call with the moved streams quiescent — windows submitted for
        them mid-drain race the handoff, exactly like they race the cold
        path's re-route."""
        with self._lock:
            if name not in self._servers:
                raise KeyError(f"no replica named {name!r}")
            if name in self._ring:
                self._ring.remove(name)
            if len(self._ring) == 0 and not self._closed:
                # Undo: a cluster with work coming must keep one replica.
                self._ring.add(name)
                raise RuntimeError(
                    "cannot remove the last healthy replica; close() the "
                    "cluster instead")
            server = self._servers[name]
        if not abandon:
            server.flush(timeout=timeout)
        stashed = [self._translate(name, r) for r in server.poll()]
        with self._lock:
            moved = [sid for sid, rname in self._route.items()
                     if rname == name]
        handoff: Dict[Hashable, object] = {}
        if not abandon and server.state_residency == "device":
            for sid in moved:
                st = server.read_stream_state(sid)
                if st is not None:
                    handoff[sid] = st
        server.close(abandon=True)
        seeds: List[Tuple[str, Hashable]] = []
        with self._lock:
            self._stash.extend(stashed)
            del self._servers[name]
            self._unhealthy.pop(name, None)
            for sid in moved:
                if sid in handoff:
                    # Re-home the route NOW: the next submit sees
                    # prev == target, so no reset flag — the seeded carry
                    # makes the continuation real, not silent.
                    dest = self._ring.route(sid)
                    self._route[sid] = dest
                    seeds.append((dest, sid))
                else:
                    del self._route[sid]   # next submit re-routes + flags
            dest_servers = {d: self._servers[d] for d, _ in seeds}
        # Seed outside the cluster lock: seed_stream_state takes the
        # destination server's own locks (same ordering rule as
        # mark_unhealthy's end_stream calls).
        for dest, sid in seeds:
            dest_servers[dest].seed_stream_state(sid, handoff[sid])
        return moved

    def mark_unhealthy(self, name: str, reason: str = "operator") -> None:
        """Take ``name`` out of the ring without closing it: its streams
        re-route (flagged resets) while the replica's server stays up so
        in-flight results still drain through ``poll``.  Failover calls
        this when ``health()`` reports ``failed``."""
        with self._lock:
            if name in self._ring:
                if len(self._ring) == 1:
                    raise RuntimeError(
                        "cannot mark the last ring replica unhealthy")
                self._ring.remove(name)
            self._unhealthy[name] = reason
            server = self._servers.get(name)
            moved = [s for s, r in self._route.items() if r == name]
            for sid in moved:
                del self._route[sid]
        # End the moved streams ON the sidelined server (outside the
        # cluster lock — end_stream takes the server's own locks): its
        # stale carries and seq numbering must not survive, or a later
        # restore_replica would resume a moved-away stream from them with
        # a non-zero seq that defeats the state_reset provenance flag.
        if server is not None:
            for sid in moved:
                server.end_stream(sid)

    def restore_replica(self, name: str) -> None:
        """Return a replica marked unhealthy to the ring (it recovered);
        streams that hash to it move back on their next submit, restarting
        with flagged resets like any other move."""
        with self._lock:
            if name not in self._servers:
                raise KeyError(f"no replica named {name!r}")
            self._unhealthy.pop(name, None)
            if name not in self._ring:
                self._ring.add(name)

    # -- results ------------------------------------------------------------

    def _translate(self, name: str, r: StreamResult) -> StreamResult:
        """Stamp a replica's row with its name and apply the cluster's
        move provenance: the first (seq 0) result of a stream that moved
        here WITH history is flagged ``state_reset`` even though the
        replica itself saw a brand-new stream."""
        reset = r.state_reset
        with self._lock:
            if r.seq == 0 and self._reset_pending.get(r.stream_id) == name:
                reset = True
                del self._reset_pending[r.stream_id]
        return dataclasses.replace(r, routed_replica=name,
                                   state_reset=reset)

    # -- metrics ------------------------------------------------------------

    def warmup(self, window) -> None:
        """Compile every replica's datapath outside the measured interval:
        one synthetic window through EACH replica (routing would only
        cover the replicas the warmup ids happen to hash to), then reset
        the metrics."""
        with self._lock:
            servers = list(self._servers.items())
        for name, srv in servers:
            wid = f"__warmup_{name}"
            srv.submit(wid, window)
            srv.drain()
            srv.end_stream(wid)
        self.reset_metrics()

    def reset_metrics(self) -> None:
        """Fresh measurement interval on every replica."""
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.reset_metrics()

    def reset_streams(self) -> None:
        """Forget every stream on every replica AND the router's
        per-stream bookkeeping (route affinity, history, pending reset
        flags) — the cluster form of ``StreamServer.reset_streams``, used
        by the scenario harness's short-run reset.  Replicas, their
        compiled sessions, and the hash ring survive; undelivered results
        of removed replicas (the stash) are NOT dropped.  Call it
        quiescent (between submission rounds), not concurrently with
        ``submit``."""
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.reset_streams()
        with self._lock:
            self._route.clear()
            self._hist.clear()
            self._reset_pending.clear()

    def metrics_summary(self) -> Dict:
        """The cluster report: the aggregate block a single server would
        produce — merged rolling-window percentiles and cluster-wide
        samples/s via :meth:`MetricsSink.merge`, fault counters summed —
        plus ``replicas`` (the per-replica ``metrics_summary()``
        breakdown), ``samples_per_s_sum`` (sum of per-replica rates), and
        the ``ring`` routing block."""
        with self._lock:
            servers = dict(self._servers)
            ring_nodes = sorted(self._ring.nodes)
            unhealthy = dict(self._unhealthy)
            n_routed = len(self._route)
        per = {name: srv.metrics_summary() for name, srv in servers.items()}
        merged = MetricsSink.merge([srv.metrics for srv in servers.values()])
        s = merged.summary()
        s["replicas"] = per
        s["sessions"] = len(servers)
        s["stateful"] = self.config.serving.stateful
        s["samples_per_s_sum"] = float(sum(p.get("samples_per_s", 0.0)
                                           for p in per.values()))
        s["ring"] = {"replicas": ring_nodes, "unhealthy": unhealthy,
                     "vnodes": self.config.vnodes,
                     "streams_routed": n_routed}
        faults = {k: sum((p.get("faults") or {}).get(k, 0)
                         for p in per.values())
                  for k in _FAULT_SUM_KEYS}
        faults["deadline_miss_rate"] = max(
            ((p.get("faults") or {}).get("deadline_miss_rate", 0.0)
             for p in per.values()), default=0.0)
        backends = {(p.get("faults") or {}).get("backend")
                    for p in per.values()} - {None}
        faults["backend"] = ",".join(sorted(backends)) or None
        faults["degraded"] = any((p.get("faults") or {}).get("degraded")
                                 for p in per.values())
        faults["injected"] = None
        s["faults"] = faults
        if s["waves"]:
            # Per-device efficiency: GOP/s and W both scale with N, so the
            # cluster's GOP/s/W is the throughput-weighted mean over the
            # replicas that served work (≈ any one replica's, by design).
            g = [(p["gops_per_watt"], p["samples"]) for p in per.values()
                 if "gops_per_watt" in p]
            if g:
                w = sum(n for _, n in g) or 1
                s["gops_per_watt"] = float(sum(v * n for v, n in g) / w)
                s["ops_per_inference"] = next(
                    p["ops_per_inference"] for p in per.values()
                    if "ops_per_inference" in p)
        s["health"] = self.health()
        s["state"] = {
            k: int(np.sum([(p.get("state") or {}).get(k, 0)
                           for p in per.values()]))
            for k in ("live_streams", "capacity", "hits", "misses",
                      "evictions")}
        return s

    def health(self) -> Dict:
        """Cluster readiness: per-replica ``health()`` snapshots plus an
        overall ``status`` — ``failed`` when NO ring replica is ok-ish
        (the cluster cannot take traffic), ``degraded`` when any replica
        is unhealthy/failed/degraded/overloaded, else ``ok``."""
        with self._lock:
            servers = dict(self._servers)
            ring = set(self._ring.nodes)
            unhealthy = dict(self._unhealthy)
        per = {name: srv.health() for name, srv in servers.items()}
        serving = [n for n in ring if per.get(n, {}).get("status")
                   in ("ok", "degraded", "overloaded")]
        if not serving:
            status = "failed"
        elif unhealthy or any(h["status"] != "ok" for h in per.values()):
            status = "degraded"
        else:
            status = "ok"
        return {"status": status, "replicas": per,
                "ring": sorted(ring), "unhealthy": unhealthy}
