"""``StreamServer`` — many named client streams, one accelerator.

The paper's headline is *real-time* inference (§6: 32 873 samples/s on a
live sensor stream); the ROADMAP scenario is that stream multiplied by
"millions of users".  This module is the piece between the two: clients
``submit`` windows tagged with a stream id, the scheduler groups them into
fixed-size waves (one static shape for the jitted datapath), and — the part
the stateless ``Accelerator.serve`` path cannot do — each stream's
recurrent carry (whatever shape the model's cell spec declares)
survives across its windows, so window *k+1* continues the
recurrence window *k* left off, bit-exactly equal to running the stream's
concatenated sequence through the accelerator in one shot.

Deployment shape::

    server = StreamServer(session, batch=64, deadline_s=0.005)
    server.submit("sensor-17", window)        # (T, M) float, any thread
    for r in server.poll(timeout=0.1):        # StreamResult(stream_id, seq, y)
        route(r.stream_id, r.y)
    server.metrics_summary()                  # samples/s, p50/p95/p99, GOP/s/W
    server.close()

Multiple sessions (replicas of ONE configuration sharing one set of
weights, e.g. one per device) may be passed; waves are dispatched
round-robin across them by the single strictly-ordered compute thread
(load spreading — not yet parallel execution; the ordering is what keeps
per-stream carries consistent).  State lives either in a bounded host LRU
:class:`~repro.serving.state.StateStore` or — when the fused pallas
kernel heads the ladder (``ServingConfig.state_residency``, default
``auto``) — in a device-resident slot table
(:class:`~repro.serving.device_state.DeviceStateStore`): same LRU
semantics, but the (h, c) codes never cross the host/device boundary on
the hot path, only two (B,) slot-id vectors do.  An evicted or brand new
stream starts from the all-zero reset carry either way.

The round-robin is WAVE-level, not stream-level: with >= 2 sessions a
stream's consecutive windows may execute on DIFFERENT sessions
(``StreamResult.routed_replica`` records which, as the session index).
That is correct only because a multi-session server's carry lives
host-side in the shared ``StateStore`` — every session reads the same
store, so which session computed window *k* does not matter for window
*k+1*.  Device residency therefore requires a SINGLE session (one table
on one device; ``auto`` falls back to host for replicas): to scale
device-resident state across replicas use
``repro.serving.cluster.ClusterServer``, which pins every stream to
exactly one replica by consistent hash so its carry stays replica-local
(the routing invariant, pinned in ``tests/test_cluster.py``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import (Dict, Hashable, Iterable, Iterator, List, Optional,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.faults import FaultInjector
from repro.serving.metrics import MetricsSink, WaveRecord
from repro.serving.resilience import ExecutionGuard, ResiliencePolicy
from repro.serving.scheduler import (OverloadPolicy, Slot, Wave,
                                     WaveScheduler)
from repro.serving.state import StateStore


def _params_equal(a, b) -> bool:
    """True when two param pytrees hold identical weights (replica check —
    the model is tiny, so exact comparison at construction is cheap)."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        x is y or np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the streaming subsystem (docs/SERVING.md has the tuning
    guide).

    ``batch``: static wave size the jitted datapath sees.  ``deadline_s``:
    flush a padded partial wave once the oldest pending window has waited
    this long (None = wait for full waves).  ``queue_depth``: assembled
    waves the compute thread may fall behind by (2 = double buffering).
    ``max_pending``: submitted-but-unassembled window bound — ``submit``
    blocks past it (None = 4 * batch); when pending saturates and no full
    wave can form (one window per stream), a partial wave is flushed
    rather than deadlocking the blocked submitters.  ``max_results``:
    computed-but-unpolled result bound — past it the compute thread blocks
    before emitting, which stalls the whole pipeline back to ``submit``
    (full backpressure to a stalled consumer).  The default ``None`` is
    unbounded: required for the submit-everything-then-``drain()`` pattern
    (``drain`` flushes before polling, so a bound smaller than the
    outstanding windows would deadlock it); production servers with a
    concurrent ``poll`` loop should set it.  ``max_streams``: LRU
    state-store capacity.  ``stateful``: carry (h, c) across a stream's windows
    (requires ``path="int"``); False gives the stateless
    ``Accelerator.serve`` semantics.  ``backend``: engine override
    (``ref`` | ``pallas`` | ``xla`` — all three carry state; the default
    follows the plan's ``stateful_backend``, docs/API.md §Backends).

    ``resilience``: the guarded-execution policy (retry/backoff/timeout +
    backend degradation, docs/SERVING.md §Reliability); every wave runs
    under it.  ``overload``: admission-control / load-shedding policy
    (None = legacy block-on-backpressure, never shed).

    ``state_residency``: where per-stream carries live on a stateful
    server.  ``auto`` follows the plan — the device-resident slot table
    when the fused pallas kernel heads the ladder (single-session
    servers; ``plan()['state_residency']``), else the host-side LRU
    ``StateStore``.  ``device`` forces the slot table (any stateful
    engine — ``ref``/``xla`` run the XLA-level slot adapter); ``host``
    forces the legacy host store.  Both sides are bit-identical; device
    residency just stops shipping (h, c) arrays across the host/device
    boundary every wave (docs/SERVING.md §State residency)."""

    batch: int = 256
    path: str = "int"
    backend: Optional[str] = None
    stateful: bool = True
    deadline_s: Optional[float] = 0.010
    queue_depth: int = 2
    max_pending: Optional[int] = None
    max_results: Optional[int] = None
    max_streams: int = 1024
    resilience: ResiliencePolicy = ResiliencePolicy()
    overload: Optional[OverloadPolicy] = None
    state_residency: str = "auto"

    def __post_init__(self):
        """Reject contradictory settings at construction time."""
        if self.stateful and self.path != "int":
            raise ValueError(
                f"stateful serving carries integer state codes, so it "
                f"requires path='int' (got path={self.path!r}); set "
                f"stateful=False for the float/qat paths")
        if self.state_residency not in ("auto", "host", "device"):
            raise ValueError(
                f"state_residency must be auto|host|device, got "
                f"{self.state_residency!r}")
        if self.state_residency == "device" and not self.stateful:
            raise ValueError(
                "state_residency='device' is a stateful-serving knob; a "
                "stateless server carries no per-stream state to place")
        if self.max_results is not None and self.max_results < 1:
            raise ValueError(
                f"max_results must be >= 1, got {self.max_results}")
        if self.resilience is None:
            raise ValueError(
                "resilience cannot be None — pass ResiliencePolicy("
                "max_retries=0) to minimise guarding instead of disabling "
                "it")


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """One prediction — or one structured per-stream failure.

    ``stream_id``/``seq`` identify the window (``seq`` is the value
    ``submit`` returned).  ``y`` is the (P,) float prediction, or ``None``
    when ``error`` is set: ``"shed"`` (deadline-aware load shedding
    dropped the window uncomputed) or a ``"compute_failed: ..."``
    description (every engine of the degradation ladder failed the wave).
    ``state_reset`` flags a window computed from the all-zero reset carry
    although the stream had history (LRU eviction, injected state loss, or
    a failed wave dropped it) — the prediction is a valid model output, it
    just lost the history; silent before, now reported.  ``backend`` names
    the engine that computed the window (None for error rows).

    ``routed_replica`` says WHERE the window ran: on a ``StreamServer``
    it is the index of the session that executed the wave (None for shed
    windows, which never executed anywhere) — with >= 2 sessions a
    stream's windows may carry DIFFERENT indices, the wave-level
    round-robin documented in the module docstring.  Through
    ``ClusterServer`` it is the replica NAME, and the routing invariant
    guarantees one stream always reports one replica."""

    stream_id: Hashable
    seq: int
    y: Optional[np.ndarray]
    error: Optional[str] = None
    state_reset: bool = False
    backend: Optional[str] = None
    routed_replica: Optional[Hashable] = None

    @property
    def ok(self) -> bool:
        """True for a real prediction, False for a shed/failed window."""
        return self.error is None


class StreamServer:
    """Stateful streaming front-end over one or more ``Accelerator``
    sessions (see the module docstring for the deployment shape).

    Results are delivered through :meth:`poll` / :meth:`drain` as
    :class:`StreamResult` rows; padded slots of partial waves are computed
    and dropped — they are never emitted and never touch the state store."""

    def __init__(self, sessions, config: Optional[ServingConfig] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 **overrides):
        """``sessions``: one ``Accelerator`` or a list of replicas of the
        same configuration (waves round-robin across them).  ``config`` or
        keyword overrides (``batch=``, ``deadline_s=``, ...) set the
        :class:`ServingConfig`.  ``fault_injector`` (tests/chaos drills
        only) wraps the execute path and the state store with a seeded
        fault schedule — see ``repro.serving.faults``."""
        sessions = list(sessions) if isinstance(sessions, (list, tuple)) \
            else [sessions]
        if not sessions:
            raise ValueError("need at least one Accelerator session")
        for s in sessions[1:]:
            if s.model != sessions[0].model:
                raise ValueError(
                    "all sessions must be replicas of one configuration; "
                    f"got models {s.model} != {sessions[0].model}")
            if not _params_equal(s.params, sessions[0].params):
                # Same config but different weights would round-robin waves
                # across bit-incompatible models (and cross-pollinate their
                # carries through the shared state store).
                raise ValueError(
                    "all sessions must be replicas sharing one set of "
                    "weights; the given sessions' params differ")
        cfg = config or ServingConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.config = cfg
        self._sessions = sessions
        self.fault_injector = fault_injector
        # Resolve the degradation ladder and compile/validate NOW: a bad
        # path/backend or an unquantised session fails at construction,
        # not in the compute thread.  jit closures are lazy, so non-
        # preferred ladder levels cost nothing until a degradation
        # actually runs them.
        from repro import backends as _backends
        #: Resolved carry placement: "device" | "host" on a stateful
        #: server, None on a stateless one (ServingConfig.state_residency
        #: documents the knob; auto follows plan()["state_residency"]).
        self.state_residency: Optional[str] = None
        if cfg.stateful:
            ladder = _backends.degradation_ladder(
                sessions[0].model, sessions[0].accel, override=cfg.backend,
                stateful=True)
            residency = cfg.state_residency
            if residency == "auto":
                residency = ("device" if ladder[0] == "pallas"
                             and len(sessions) == 1 else "host")
            elif residency == "device" and len(sessions) > 1:
                # One table lives on one device; replicas round-robining
                # waves into private tables would shear a stream's carry
                # across them.  Sharding streams across per-replica tables
                # is ClusterServer's job (consistent routing).
                raise ValueError(
                    "state_residency='device' requires a single session; "
                    "use ClusterServer to shard streams across replicas, "
                    "each with its own device-resident table")
            self.state_residency = residency
            if residency == "device":
                self._fns = [[(n, s.compiled_stateful_slots(n))
                              for n in ladder] for s in sessions]
            else:
                self._fns = [[(n, s.compiled_stateful(n)) for n in ladder]
                             for s in sessions]
        elif cfg.path == "int":
            ladder = _backends.degradation_ladder(
                sessions[0].model, sessions[0].accel, override=cfg.backend,
                stateful=False)
            self._fns = [[(n, s.compiled(cfg.path, n)) for n in ladder]
                         for s in sessions]
        else:
            # float/qat run one plan-resolved graph; the ladder is trivial
            # but the guard's retry/timeout protection still applies.
            ladder = (cfg.path,)
            self._fns = [[(cfg.path, s.compiled(cfg.path, cfg.backend))]
                         for s in sessions]
        if fault_injector is not None:
            self._fns = [[(n, fault_injector.wrap_fn(fn, label=n))
                          for n, fn in per_session]
                         for per_session in self._fns]
        self.guard = ExecutionGuard(ladder, cfg.resilience)
        if not cfg.stateful:
            self.states = None
        elif self.state_residency == "device":
            from repro.serving.device_state import DeviceStateStore
            self.states = DeviceStateStore(sessions[0], cfg.max_streams)
            if fault_injector is not None:
                self.states = fault_injector.wrap_device_state_store(
                    self.states)
        else:
            self.states = StateStore(cfg.max_streams)
            if fault_injector is not None:
                self.states = fault_injector.wrap_state_store(self.states)
        self.metrics = MetricsSink()
        self._results: "queue.Queue" = queue.Queue(
            maxsize=cfg.max_results or 0)
        self._seq: Dict[Hashable, int] = {}
        # stream_id -> submission watermark of an end_stream request:
        # carries of windows submitted before it are not re-stored.  Every
        # tombstone is pruned once the stream has no windows in flight
        # (tracked in _outstanding), so neither dict can grow beyond the
        # streams currently inside the pipeline.
        self._ended: Dict[Hashable, int] = {}
        self._outstanding: Dict[Hashable, int] = {}
        self._seq_lock = threading.Lock()
        self._window_shape = None
        self._rr = 0
        self._sched = WaveScheduler(
            cfg.batch, self._execute, one_per_stream=cfg.stateful,
            deadline_s=cfg.deadline_s, queue_depth=cfg.queue_depth,
            max_pending=cfg.max_pending, overload=cfg.overload,
            on_shed=self._shed)

    # -- client surface -----------------------------------------------------

    def submit(self, stream_id: Hashable,
               window: Union[np.ndarray, "jnp.ndarray"]) -> int:
        """Enqueue one (T, M) float window for ``stream_id``; returns the
        window's per-stream sequence number.  Blocks under backpressure
        (``max_pending``); with a reject-mode ``OverloadPolicy`` it raises
        ``ServerOverloaded`` instead of blocking when the server is
        saturated.  All windows of a server must share one shape (the
        jitted datapath is compiled for it).

        Inputs are validated HERE, per call: a malformed window (wrong
        rank, wrong feature width, non-float-convertible dtype, NaN/Inf)
        raises ``ValueError`` to this caller only — it never reaches the
        compute thread, where it would poison a whole wave of other
        clients' windows."""
        try:
            w = np.asarray(window, dtype=np.float32)
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"window is not convertible to a float32 array: {e}"
            ) from None
        if w.ndim != 2:
            raise ValueError(
                f"window must be a (T, M) array, got shape {w.shape}")
        m = self._sessions[0].model.input_size
        if w.shape[0] < 1 or w.shape[1] != m:
            raise ValueError(
                f"window shape {w.shape} does not match the model's "
                f"(T>=1, input_size={m})")
        if not np.isfinite(w).all():
            raise ValueError(
                "window contains NaN/Inf; the int datapath would quantise "
                "them to arbitrary codes and corrupt the stream's carry — "
                "rejected at submit")
        with self._seq_lock:
            if self._window_shape is None:
                self._window_shape = w.shape
            elif w.shape != self._window_shape:
                raise ValueError(f"window shape {w.shape} != first window's "
                                 f"{self._window_shape}; one server serves "
                                 f"one static shape")

        def alloc_seq() -> int:
            # Runs inside the scheduler's critical section, so the seq a
            # thread gets and its position in the FIFO cannot be reordered
            # against another thread submitting to the same stream.
            with self._seq_lock:
                seq = self._seq.get(stream_id, 0)
                self._seq[stream_id] = seq + 1
                if self.config.stateful:
                    self._outstanding[stream_id] = \
                        self._outstanding.get(stream_id, 0) + 1
                return seq

        self.metrics.note_submit(time.perf_counter())
        return self._sched.submit(stream_id, w, alloc_seq)

    def poll(self, timeout: float = 0.0) -> List[StreamResult]:
        """Completed predictions, in wave order (per-stream order is always
        submission order).  Returns immediately with whatever is ready;
        with ``timeout`` > 0, waits up to that long for the first result.
        Re-raises a compute-thread failure."""
        out: List[StreamResult] = []
        end = time.perf_counter() + timeout
        while True:
            try:
                while True:
                    out.append(self._results.get_nowait())
            except queue.Empty:
                pass
            if out:
                return out
            err = self._sched.error
            if err is not None:
                raise err
            remaining = end - time.perf_counter()
            if remaining <= 0:
                return out
            try:
                out.append(self._results.get(timeout=min(remaining, 0.25)))
            except queue.Empty:
                pass

    def flush(self, timeout: Optional[float] = None) -> None:
        """Barrier: force partial waves and wait until every window
        submitted before the call has been computed."""
        self._sched.flush(timeout=timeout)

    def drain(self, timeout: Optional[float] = None) -> List[StreamResult]:
        """``flush`` then collect everything: all outstanding predictions."""
        self.flush(timeout=timeout)
        return self.poll()

    def end_stream(self, stream_id: Hashable) -> None:
        """Forget a stream (explicit end-of-stream): its carry on stateful
        servers, and its sequence numbering on every server — the next
        window under the same id starts a fresh stream, from the reset
        state and with its sequence numbering restarted at 0.  On
        stateless servers this is also the only way to prune a retired
        id's ``_seq`` entry, so long-lived deployments with rotating
        client ids should call it.

        Safe against in-flight windows: carries of windows submitted
        before this call are never re-stored (a tombstone watermark makes
        the compute thread skip their scatter), so a window submitted
        AFTER the call is guaranteed the zero reset carry."""
        if self.states is None:
            with self._seq_lock:
                self._seq.pop(stream_id, None)
            return
        watermark = self._sched.submission_watermark()
        with self._seq_lock:
            self._seq.pop(stream_id, None)
            # A tombstone is only needed while windows are in flight; it is
            # pruned by _retire once the last of them clears the pipeline.
            if self._outstanding.get(stream_id, 0) > 0:
                self._ended[stream_id] = max(watermark,
                                             self._ended.get(stream_id, 0))
            # Inside the lock: _scatter holds it across its tombstone check
            # AND its states.put, so the pop here cannot interleave with a
            # put and erase a reborn stream's carry (or miss a stale one).
            self.states.pop(stream_id)

    def reset_streams(self) -> None:
        """Forget EVERY stream — carries and sequence numbering — without
        tearing down the server: threads, compiled sessions, and (on the
        device path) the resident slot table all survive, so the next
        window is served by a warm datapath from a zero carry.

        This is the scenario harness's short-run reset
        (``repro.explore.serving_objective``): warm up once, then
        ``reset_streams()`` + ``reset_metrics()`` give a fresh measurement
        interval on an already-compiled server, point after point.
        Flushes first; call it between submission rounds, not concurrently
        with ``submit``."""
        self.flush()
        with self._seq_lock:
            ids = set(self._seq)
        if self.states is not None:
            # Streams seeded via seed_stream_state but never submitted
            # hold a carry without a _seq entry — end those too.
            ids.update(self.states.ids())
        for sid in ids:
            self.end_stream(sid)

    def read_stream_state(self, stream_id: Hashable):
        """A host-side copy of a stream's carry (per layer, a tuple of the
        cell's ``state_arity`` int32 rows — ``[(h, c), ...]`` for the
        LSTM), or ``None`` when the server holds none.  On a
        device-resident server this is the one sanctioned state read-back,
        meant for PLANNED stream movement (``ClusterServer`` drain) — not
        for the hot path.  Call only with the stream quiescent (no windows
        in flight), e.g. after ``flush()``."""
        if self.states is None:
            return None
        if self.state_residency == "device":
            return self.states.read_state(stream_id)
        st = self.states.get(stream_id)
        if st is None:
            return None
        return [tuple(a.copy() for a in layer) for layer in st]

    def seed_stream_state(self, stream_id: Hashable, state) -> None:
        """Plant a carry for ``stream_id`` (same per-layer carry-tuple
        layout ``read_stream_state`` returns) as if the server had
        computed it — the destination
        half of a warm stream handoff.  The stream's next window continues
        the recurrence from ``state`` with no ``state_reset`` flag.  Any
        streams the insertion LRU-evicts are reconciled exactly like a
        wave's own evictions."""
        if self.states is None:
            raise ValueError("cannot seed state on a stateless server")
        with self._seq_lock:
            if self.state_residency == "device":
                evicted = set(self.states.seed_state(stream_id, state))
            else:
                evicted = set(self.states.put(
                    stream_id,
                    [tuple(np.asarray(a).copy() for a in layer)
                     for layer in state]))
        self._reconcile_evictions(evicted)

    def close(self, abandon: bool = False,
              timeout: float = 30.0) -> List[str]:
        """Stop the server.  Default: drain submitted windows first;
        ``abandon=True`` discards pending work immediately.  A drain that
        cannot complete (a ``max_results``-bounded queue wedged by a
        consumer that stopped polling) escalates to abandon after
        ``timeout`` instead of leaking the worker threads.  Returns the
        names of any threads that survived the escalated join (empty =
        clean shutdown; also visible in ``health()["leaked_threads"]``)."""
        leaked = self._sched.close(abandon=abandon, timeout=timeout)
        self.guard.close()
        return leaked

    def __enter__(self) -> "StreamServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(abandon=exc_type is not None)

    # -- metrics ------------------------------------------------------------

    def reset_metrics(self) -> None:
        """Start a fresh metrics window (e.g. after a warm-up wave, so the
        compile time stays out of the measured interval)."""
        self.metrics = MetricsSink()

    def metrics_summary(self) -> Dict:
        """The serving report: achieved samples/s, per-wave latency
        p50/p95/p99, occupancy, deadline flushes, state-store counters, and
        the energy model's GOP/s/W at the MEASURED operating point (mean
        wave compute latency, mean occupancy) — the paper's Table-4 metric
        evaluated where the server actually runs."""
        s = self.metrics.summary()
        s["stateful"] = self.config.stateful
        s["sessions"] = len(self._sessions)
        s["state"] = self.states.stats() if self.states is not None else None
        s["state_residency"] = self.state_residency
        g = self.guard.stats()
        sched = self._sched.stats()
        counters = self.metrics.counters()
        s["faults"] = {
            "retries": g["retries"],
            "timeouts": g["timeouts"],
            "wave_failures": g["wave_failures"],
            "degradations": g["degradations"],
            "promotions": g["promotions"],
            "probes": g["probes"],
            "backend": g["backend"],
            "degraded": g["level"] > 0,
            "sheds": sched["sheds"],
            "rejections": sched["rejections"],
            "recoveries": sched["recoveries"],
            "deadline_miss_rate": sched["deadline_miss_rate"],
            "state_resets": counters.get("state_resets", 0),
            "stream_errors": counters.get("stream_errors", 0),
            "injected": (self.fault_injector.stats()
                         if self.fault_injector is not None else None),
        }
        # Per-wave host<->device state traffic: the device-residency win is
        # to_device/from_device pinned at 0 while only slot ids travel.
        s["state_transfer"] = {
            "to_device_bytes": counters.get("state_bytes_to_device", 0),
            "from_device_bytes": counters.get("state_bytes_from_device", 0),
            "slot_id_bytes": counters.get("slot_id_bytes", 0),
        }
        s["health"] = self.health()
        if s["waves"]:
            sess = self._sessions[0]
            occupancy = max(1, round(s["mean_occupancy"]))
            rep = sess.report(latency_s=s["compute_ms_mean"] / 1e3,
                              batch=occupancy)
            s["ops_per_inference"] = rep["ops_per_inference"]
            s["energy"] = rep["energy"]
            s["gops_per_watt"] = rep["energy"]["gops_per_watt"]
        return s

    def health(self) -> Dict:
        """Live health snapshot — cheap enough for a readiness probe.

        ``status``: ``"failed"`` (an unrecovered compute-thread error is
        pending re-raise), ``"overloaded"`` (pending queue saturated),
        ``"degraded"`` (serving below the preferred engine), else
        ``"ok"``.  Plus the current engine and ladder, queue depths, the
        rolling deadline-miss rate, live stream count, and any leaked
        worker threads from the last ``close``.  Schema documented in
        docs/SERVING.md §Reliability."""
        g = self.guard.stats()
        sched = self._sched.stats()
        if sched["dead"]:
            status = "failed"
        elif sched["pending"] >= sched["max_pending"]:
            status = "overloaded"
        elif g["level"] > 0:
            status = "degraded"
        else:
            status = "ok"
        return {
            "status": status,
            "backend": g["backend"],
            "ladder": g["ladder"],
            "degraded": g["level"] > 0,
            "pending": sched["pending"],
            "max_pending": sched["max_pending"],
            "results_waiting": self._results.qsize(),
            "deadline_miss_rate": sched["deadline_miss_rate"],
            "live_streams": (len(self.states)
                             if self.states is not None else None),
            "state_residency": self.state_residency,
            "leaked_threads": list(self._sched.leaked_threads),
        }

    # -- compute thread -----------------------------------------------------

    def _execute(self, wave: Wave) -> None:
        """Gather carries -> GUARDED device datapath -> scatter carries ->
        emit.  Runs on the scheduler's compute thread, waves strictly in
        order — which is what makes the gather/scatter of consecutive
        windows of one stream consistent.

        The guard absorbs engine failures (retry, backoff, degradation
        down the bit-identical ladder); only a wave that fails on EVERY
        engine is converted into per-stream error results — the compute
        thread survives either way."""
        sess_idx = self._rr % len(self._fns)
        fns = self._fns[sess_idx]
        self._rr += 1
        t0 = time.perf_counter()
        x = jnp.asarray(wave.x)
        device_state = self.state_residency == "device"
        if device_state:
            # Slot path: the carries never leave the table — only two (B,)
            # int32 slot-id vectors cross to the device.  The allocator
            # transaction (lookup + assign + tombstone checks) happens
            # BEFORE compute, so faults can only strand slots, never
            # corrupt the allocator<->table correspondence.
            g, s, reset, rows, evicted = self._gather_slots(wave)
            self.metrics.count("slot_id_bytes", int(g.nbytes + s.nbytes))
            outcome = self.guard.run(fns, x, self.states.table,
                                     jnp.asarray(g), jnp.asarray(s))
        elif self.config.stateful:
            gathered, reset = self._gather(wave)
            outcome = self.guard.run(fns, x, gathered)
        else:
            reset = [False] * len(wave.slots)
            outcome = self.guard.run(fns, x)
        if not outcome.ok:
            self._fail_wave(wave, outcome, t0, sess_idx)
            if device_state:
                # Slot assignment (and any LRU evictions) happened before
                # compute; the victims are still gone even though the
                # wave's table update was discarded.
                self._reconcile_evictions(evicted)
            return
        if device_state:
            y, new_table = outcome.value
            y = np.asarray(y)
            self.states.commit(new_table, rows)
            self._retire(wave)
            self._reconcile_evictions(evicted)
        elif self.config.stateful:
            y, new_state = outcome.value
            y = np.asarray(y)
            evicted = self._scatter(wave, new_state)
            self._retire(wave)
            self._reconcile_evictions(evicted)
        else:
            y = np.asarray(outcome.value)
        n_reset = sum(reset)
        if n_reset:
            self.metrics.count("state_resets", n_reset)
        t1 = time.perf_counter()
        self.metrics.record_wave(WaveRecord(
            t_done=t1, compute_s=t1 - t0, latency_s=t1 - wave.t_oldest,
            occupancy=wave.occupancy, batch=self.config.batch,
            deadline_flush=wave.deadline_flush))
        for i, slot in enumerate(wave.slots):
            self._emit(StreamResult(slot.stream_id, slot.seq, y[i],
                                    state_reset=reset[i],
                                    backend=outcome.backend,
                                    routed_replica=sess_idx))

    def _fail_wave(self, wave: Wave, outcome, t0: float,
                   sess_idx: int) -> None:
        """Every ladder engine failed this wave: isolate the damage to the
        wave's own streams.  Their carries are dropped (a window was lost,
        so continuing from the pre-wave carry would be a silent gap — the
        next window restarts from the reset state and is FLAGGED
        ``state_reset``), each slot gets a structured error result, and
        the compute thread moves on."""
        err = f"compute_failed: {outcome.error}"
        if self.config.stateful:
            for slot in wave.slots:
                self.states.pop(slot.stream_id)
            self._retire(wave)
        self.metrics.count("stream_errors", wave.occupancy)
        t1 = time.perf_counter()
        self.metrics.record_wave(WaveRecord(
            t_done=t1, compute_s=t1 - t0, latency_s=t1 - wave.t_oldest,
            occupancy=wave.occupancy, batch=self.config.batch,
            deadline_flush=wave.deadline_flush))
        for slot in wave.slots:
            self._emit(StreamResult(slot.stream_id, slot.seq, None,
                                    error=err, routed_replica=sess_idx))

    def _shed(self, slot: Slot) -> None:
        """Scheduler shed callback (assembler thread): the window was
        dropped uncomputed.  On a stateful server the stream's carry is
        dropped too — its recurrence now has a hole, and a silently wrong
        continuation is worse than a flagged reset — so the next window
        restarts from zero with ``state_reset=True``."""
        if self.config.stateful:
            with self._seq_lock:
                self.states.pop(slot.stream_id)
            self._retire_slot(slot.stream_id)
        self.metrics.count("sheds")
        self._emit(StreamResult(slot.stream_id, slot.seq, None,
                                error="shed"))

    def _emit(self, r: StreamResult) -> None:
        """Deliver one result.  With max_results set this blocks, stalling
        the compute thread and — through the wave queue and pending bounds
        — eventually submit(): full backpressure to a stalled consumer.
        Give up on abandon so close(abandon=True) cannot hang on a full
        results queue."""
        while True:
            try:
                self._results.put(r, timeout=0.1)
                return
            except queue.Full:
                if self._sched.stopped:
                    return

    def _gather(self, wave: Wave):
        """Per-layer carry batch arrays for the wave (the cell's
        ``state_arity`` arrays per layer — (h, c) for the LSTM): stored
        carries for known streams, the zero reset state for new/evicted
        streams and padding rows.  Also returns per-slot ``state_reset``
        flags: True when a stream WITH HISTORY (seq > 0) found no carry —
        it was evicted, lost, or dropped by a failed wave, and its result
        must say so instead of silently continuing from zeros."""
        nl, arity, hidden = self._sessions[0].plan["state_shape"]
        bufs = [[np.zeros((self.config.batch, hidden), np.int32)
                 for _ in range(arity)] for _ in range(nl)]
        reset = [False] * len(wave.slots)
        for i, slot in enumerate(wave.slots):
            st = self.states.get(slot.stream_id)
            if st is not None:
                for li, layer_carry in enumerate(st):
                    for s, arr in enumerate(layer_carry):
                        bufs[li][s][i] = arr
            elif slot.seq > 0:
                reset[i] = True
        state = tuple(tuple(jnp.asarray(a) for a in layer)
                      for layer in bufs)
        self.metrics.count("state_bytes_to_device",
                           sum(int(a.nbytes) for layer in state
                               for a in layer))
        return state, reset

    def _gather_slots(self, wave: Wave):
        """The device-residency counterpart of :meth:`_gather` +
        :meth:`_scatter`'s bookkeeping, run BEFORE compute: one allocator
        transaction under ``_seq_lock`` producing the wave's slot-id
        vectors.  Returns ``(gather, scatter, reset, rows, evicted)``:

        * ``gather[i]``: table row whose carry seeds batch row ``i`` at
          t == 0 — the stream's slot, or ZERO for new/evicted streams and
          padding (``reset[i]`` is flagged exactly like :meth:`_gather`);
        * ``scatter[i]``: row for the final carry at t == T-1 — the
          stream's (possibly new) slot, or TRASH for padding, tombstoned
          windows, and same-wave eviction victims;
        * ``rows``: the real scatters as ``(batch_row, stream_id)``, the
          unit the fault injector draws per-put faults over;
        * ``evicted``: ids LRU-evicted by this wave's assignments.

        The two phases replay the host path's store-op order — every
        lookup (get), then every assignment (put) in batch-row order — so
        hit/miss/eviction counters and any injected fault schedule match
        the host store draw for draw."""
        store = self.states
        batch = self.config.batch
        g = np.full(batch, store.zero_slot, dtype=np.int32)
        s = np.full(batch, store.trash_slot, dtype=np.int32)
        reset = [False] * len(wave.slots)
        rows: List[Tuple[int, Hashable]] = []
        evicted_all: set = set()
        with self._seq_lock:
            for i, slot in enumerate(wave.slots):
                sl = store.lookup(slot.stream_id)
                if sl is not None:
                    g[i] = sl
                elif slot.seq > 0:
                    reset[i] = True
            row_of_slot: Dict[int, int] = {}
            for i, slot in enumerate(wave.slots):
                sid = slot.stream_id
                watermark = self._ended.get(sid)
                if watermark is not None:
                    if slot.sub_idx < watermark:
                        continue   # ended-generation carry: scatter=TRASH
                    del self._ended[sid]   # stream reborn after the end
                sl, evicted = store.assign(sid)
                evicted_all.update(evicted)
                j = row_of_slot.pop(sl, None)
                if j is not None:
                    # An earlier row of THIS wave was assigned this slot
                    # and its stream was just LRU-evicted (batch >
                    # capacity): its carry would be dropped by the host
                    # store too — redirect its dead scatter to TRASH.
                    s[j] = store.trash_slot
                row_of_slot[sl] = i
                s[i] = sl
                rows.append((i, sid))
        return g, s, reset, rows, evicted_all

    def _scatter(self, wave: Wave, new_state) -> set:
        """Store each real slot's updated carry; returns the ids evicted by
        the wave's puts (reconciled by :meth:`_reconcile_evictions` after
        :meth:`_retire`).  Padding rows are dropped (they never touch the
        store); so are carries tombstoned by ``end_stream`` — windows
        submitted before the end must not resurrect the stream's state."""
        rows = [tuple(np.asarray(a) for a in layer) for layer in new_state]
        self.metrics.count("state_bytes_from_device",
                           sum(int(a.nbytes) for layer in rows
                               for a in layer))
        evicted_all = set()
        for i, slot in enumerate(wave.slots):
            sid = slot.stream_id
            # One lock section spans the tombstone check AND the put: an
            # end_stream between them could otherwise be silently undone
            # by the put, resurrecting the ended stream's carry.  The
            # store's own lock never takes _seq_lock, so no cycle.
            with self._seq_lock:
                watermark = self._ended.get(sid)
                if watermark is not None:
                    if slot.sub_idx < watermark:
                        continue       # ended-generation carry: drop it
                    del self._ended[sid]   # stream reborn after the end
                # copy(): a view of row i would pin the whole
                # (batch, hidden) wave array in the store for the stream's
                # lifetime.
                evicted_all.update(
                    self.states.put(sid, [tuple(a[i].copy() for a in layer)
                                          for layer in rows]))
        return evicted_all

    def _reconcile_evictions(self, evicted: set) -> None:
        """An evicted stream is forgotten ENTIRELY — carry and sequence
        numbering — so a returning client looks like a new stream (and a
        stateful server's _seq cannot grow without bound; state.py's
        docstring scenario is millions of users).  Runs after
        :meth:`_retire`, and only prunes a victim that is really gone:

        * a victim that was a LATER slot of the evicting wave re-stored
          its (correctly continued) carry — never really evicted, keeps
          its numbering;
        * a victim with windows still in flight keeps its numbering too —
          its pending window's scatter will re-store its carry before any
          later wave gathers it (waves compute strictly in order), so
          pruning here would hand out duplicate (stream_id, seq) keys."""
        with self._seq_lock:
            for vid in evicted:
                if vid not in self.states \
                        and self._outstanding.get(vid, 0) == 0:
                    self._seq.pop(vid, None)

    def _retire(self, wave: Wave) -> None:
        """Per-stream in-flight accounting: once a stream has no windows
        left in the pipeline, its end_stream tombstone (if any) can never
        match again and is pruned — this bounds ``_ended``/``_outstanding``
        by the streams currently inside the pipeline."""
        with self._seq_lock:
            for slot in wave.slots:
                self._retire_slot_locked(slot.stream_id)

    def _retire_slot(self, sid: Hashable) -> None:
        """One window left the pipeline outside a wave (it was shed)."""
        with self._seq_lock:
            self._retire_slot_locked(sid)

    def _retire_slot_locked(self, sid: Hashable) -> None:
        """Decrement a stream's in-flight count; prune its bookkeeping at
        zero.  Caller holds ``_seq_lock``."""
        left = self._outstanding.get(sid, 1) - 1
        if left > 0:
            self._outstanding[sid] = left
        else:
            self._outstanding.pop(sid, None)
            self._ended.pop(sid, None)


def serve_windows(session, stream: Iterable, batch: int = 256,
                  path: str = "int",
                  backend: Optional[str] = None) -> Iterator[np.ndarray]:
    """Ordered stateless mapping of a window iterator — the
    ``Accelerator.serve`` semantics, executed by the streaming subsystem.

    Windows of shape (T, M) are assembled into fixed-size waves of
    ``batch``; predictions of shape (P,) are yielded in submission order.
    The final partial wave is PADDED to the static shape by repeating the
    last window; padded outputs are computed and dropped — exactly
    ``len(list(stream))`` predictions are yielded, never more.  Unlike the
    legacy synchronous path, wave *N+1* is assembled while wave *N*
    computes (the scheduler's double buffering), and a slow consumer
    exerts backpressure instead of unbounded buffering."""
    config = ServingConfig(batch=batch, path=path, backend=backend,
                           stateful=False, deadline_s=None)
    # Validate NOW (cached on the session): a bad path/backend or an
    # unquantised session fails at the call site, not at first iteration.
    # The server itself — two live threads — is only constructed once the
    # generator is actually consumed, so an abandoned call leaks nothing.
    session.compiled(path, backend)

    def _gen():
        server = StreamServer(session, config)
        try:
            for w in stream:
                server.submit(None, w)
                for r in server.poll():
                    yield r.y
            for r in server.drain():
                yield r.y
        finally:
            server.close(abandon=True)

    return _gen()
