"""Consistent-hash stream routing — the cluster's placement invariant.

The scaling story of ``repro.serving.cluster`` rests on ONE invariant:
every named stream is served by exactly one replica, so its recurrent
carry stays resident in that replica's :class:`~repro.serving.state.
StateStore` and never migrates across devices on the hot path (ELSA's
state-residency argument, applied at cluster scale).  This module is the
routing function that provides the invariant.

:class:`HashRing` is classic consistent hashing with virtual nodes: each
replica owns ``vnodes`` pseudo-random points on a 64-bit ring, and a
stream is served by the replica owning the first point clockwise of the
stream's own hash.  Two properties matter to the serving tier:

* **Determinism** — hashes come from ``blake2b`` over ``(seed, key)``,
  never Python's randomised ``hash()``, so the same (seed, replica set)
  routes the same stream to the same replica in every process, forever.
  A router in front of the cluster can compute placements independently.
* **Minimal disruption** — removing a replica moves ONLY the streams it
  owned (~K/N of K streams over N replicas) to their next-clockwise
  owner; adding one steals ~K/(N+1) streams from the others.  Everything
  else keeps its replica, its carry, and its numbering untouched —
  pinned property-style in ``tests/test_cluster.py``.

The ring itself is a plain data structure with no locking; the cluster
layer mutates it only under its own routing lock.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Dict, FrozenSet, Hashable, Iterable, List, Tuple


class HashRing:
    """Consistent-hash ring mapping stream keys to replica names.

    ``vnodes`` virtual nodes per replica smooth the load split (64 keeps
    the per-replica share within a few percent of uniform for realistic
    replica counts); ``seed`` namespaces the hash so independent rings
    (e.g. a blue/green pair) shuffle differently.  Not thread-safe —
    callers serialise mutation (``ClusterServer`` holds its routing lock).
    """

    def __init__(self, nodes: Iterable[str] = (), *, vnodes: int = 64,
                 seed: int = 0):
        """Build a ring over ``nodes`` (each added as by :meth:`add`)."""
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.vnodes = vnodes
        self.seed = seed
        self._points: List[Tuple[int, str]] = []   # sorted (hash, node)
        self._nodes: set = set()
        for n in nodes:
            self.add(n)

    def _hash(self, s: str) -> int:
        """Deterministic 64-bit point for ``s`` (seed-namespaced blake2b —
        stable across processes, unlike built-in ``hash``)."""
        digest = hashlib.blake2b(f"{self.seed}:{s}".encode(),
                                 digest_size=8).digest()
        return int.from_bytes(digest, "big")

    def add(self, node: str) -> None:
        """Insert a replica: ``vnodes`` points join the ring, stealing
        ~K/(N+1) streams from the existing replicas."""
        if node in self._nodes:
            raise ValueError(f"replica {node!r} is already on the ring")
        self._nodes.add(node)
        for v in range(self.vnodes):
            bisect.insort(self._points, (self._hash(f"n:{node}#{v}"), node))

    def remove(self, node: str) -> None:
        """Remove a replica: only the streams it owned move (each to its
        next-clockwise owner); every other stream's route is unchanged."""
        if node not in self._nodes:
            raise KeyError(f"replica {node!r} is not on the ring")
        self._nodes.discard(node)
        self._points = [p for p in self._points if p[1] != node]

    def route(self, key: Hashable) -> str:
        """The replica owning ``key``: the first ring point clockwise of
        the key's hash (wrapping).  Raises ``RuntimeError`` on an empty
        ring — the cluster has no healthy replica to serve the stream."""
        if not self._points:
            raise RuntimeError(
                "hash ring is empty: no replica available to route to")
        h = self._hash(f"k:{key}")
        i = bisect.bisect_left(self._points, (h, ""))
        return self._points[i % len(self._points)][1]

    def assignments(self, keys: Iterable[Hashable]) -> Dict[Hashable, str]:
        """Batch :meth:`route` — ``{key: replica}`` for capacity planning
        and the rebalance tests."""
        return {k: self.route(k) for k in keys}

    @property
    def nodes(self) -> FrozenSet[str]:
        """The replica names currently on the ring."""
        return frozenset(self._nodes)

    def __len__(self) -> int:
        """Number of replicas (not virtual nodes) on the ring."""
        return len(self._nodes)

    def __contains__(self, node: str) -> bool:
        """True when ``node`` is on the ring."""
        return node in self._nodes

    def __repr__(self) -> str:
        return (f"HashRing(nodes={sorted(self._nodes)}, "
                f"vnodes={self.vnodes}, seed={self.seed})")
