"""Serving metrics sink — per-wave records and the percentile summary.

Every computed wave appends one :class:`WaveRecord`; :meth:`MetricsSink.
summary` reduces them to the numbers the paper reports for its real-time
deployment (§6): achieved samples/s, per-wave latency percentiles
(p50/p95/p99), wave occupancy, and how often the deadline forced a partial
flush.  ``StreamServer.metrics_summary`` extends this with the energy
model's GOP/s/W *at the measured throughput* (the paper's 11.89 GOP/s/W
headline is exactly this quantity at 32 873 samples/s).

Latency definitions (the metrics glossary in docs/SERVING.md):

  * ``compute_s``  — device time for the wave (dispatch to results ready).
  * ``latency_s``  — end-to-end for the wave's OLDEST window: submit ->
    results ready.  Queueing + assembly + compute; the quantity the
    deadline bounds, and what p50/p95/p99 are computed over.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class WaveRecord:
    """One computed wave, as recorded by the scheduler's compute thread."""

    t_done: float           # perf_counter when results were ready
    compute_s: float        # device compute time for the wave
    latency_s: float        # oldest-window end-to-end latency
    occupancy: int          # real (non-padding) windows in the wave
    batch: int              # static wave size the datapath saw
    deadline_flush: bool    # True when the deadline forced a partial wave


class MetricsSink:
    """Thread-safe accumulator of :class:`WaveRecord` rows.

    ``note_submit`` timestamps the first submission so achieved samples/s
    is measured over the full submit -> last-result wall interval.

    The sink is bounded: a long-lived server records one wave forever, so
    only the most recent ``window`` records are retained for the
    percentile/mean reductions (latency p50/p95/p99 then read as *current*
    behaviour, not lifetime history), while counts — waves, samples,
    deadline flushes, padded slots — and the samples/s wall interval are
    lifetime totals kept as O(1) counters."""

    def __init__(self, window: int = 4096):
        """Create an empty sink retaining the last ``window`` wave records;
        records arrive via :meth:`record_wave`."""
        self._lock = threading.Lock()
        self._recent: Deque[WaveRecord] = collections.deque(maxlen=window)
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._n_waves = 0
        self._n_samples = 0
        self._n_deadline_flushes = 0
        self._n_padded_slots = 0
        self._compute_s_total = 0.0
        self._counters: Dict[str, int] = collections.defaultdict(int)

    def count(self, name: str, n: int = 1) -> None:
        """Bump a named event counter (``state_resets``, ``sheds``,
        ``stream_errors``, ... — the reliability layer's events); read
        back with :meth:`counters`."""
        with self._lock:
            self._counters[name] += n

    def counters(self) -> Dict[str, int]:
        """Snapshot of the named event counters."""
        with self._lock:
            return dict(self._counters)

    def note_submit(self, t: float) -> None:
        """Record a submission timestamp (keeps the earliest)."""
        with self._lock:
            if self._t_first_submit is None or t < self._t_first_submit:
                self._t_first_submit = t

    def record_wave(self, record: WaveRecord) -> None:
        """Append one computed wave (rolls the window, bumps the lifetime
        counters)."""
        with self._lock:
            self._recent.append(record)
            if self._t_last_done is None or record.t_done > self._t_last_done:
                self._t_last_done = record.t_done
            self._n_waves += 1
            self._n_samples += record.occupancy
            self._n_deadline_flushes += bool(record.deadline_flush)
            self._n_padded_slots += record.batch - record.occupancy
            self._compute_s_total += record.compute_s

    @property
    def waves(self) -> List[WaveRecord]:
        """A snapshot copy of the retained (most recent ``window``) waves."""
        with self._lock:
            return list(self._recent)

    def summary(self) -> Dict:
        """Reduce the records to the serving report's throughput/latency
        block (see the module and class docstrings for the latency
        definitions and the rolling-window vs lifetime split)."""
        with self._lock:
            recent = list(self._recent)
            t0 = self._t_first_submit
            t_end = self._t_last_done
            n_waves = self._n_waves
            n_samples = self._n_samples
            n_flushes = self._n_deadline_flushes
            n_padded = self._n_padded_slots
            compute_total = self._compute_s_total
        if not recent:
            return {"waves": 0, "samples": 0, "samples_per_s": 0.0}
        lat = np.asarray([w.latency_s for w in recent])
        comp = np.asarray([w.compute_s for w in recent])
        wall_s = (t_end - t0) if t0 is not None else compute_total
        p50, p95, p99 = np.percentile(lat, [50, 95, 99])
        return {
            "waves": n_waves,
            "samples": n_samples,
            "wall_s": float(wall_s),
            "samples_per_s": n_samples / wall_s if wall_s > 0 else 0.0,
            "latency_ms": {"p50": float(p50 * 1e3), "p95": float(p95 * 1e3),
                           "p99": float(p99 * 1e3),
                           "mean": float(lat.mean() * 1e3)},
            "compute_ms_mean": float(comp.mean() * 1e3),
            "mean_occupancy": n_samples / n_waves,
            "batch": recent[-1].batch,
            "deadline_flushes": n_flushes,
            "padded_slots": int(n_padded),
        }
