"""Serving metrics sink — per-wave records and the percentile summary.

Every computed wave appends one :class:`WaveRecord`; :meth:`MetricsSink.
summary` reduces them to the numbers the paper reports for its real-time
deployment (§6): achieved samples/s, per-wave latency percentiles
(p50/p95/p99), wave occupancy, and how often the deadline forced a partial
flush.  ``StreamServer.metrics_summary`` extends this with the energy
model's GOP/s/W *at the measured throughput* (the paper's 11.89 GOP/s/W
headline is exactly this quantity at 32 873 samples/s).

Latency definitions (the metrics glossary in docs/SERVING.md):

  * ``compute_s``  — device time for the wave (dispatch to results ready).
  * ``latency_s``  — end-to-end for the wave's OLDEST window: submit ->
    results ready.  Queueing + assembly + compute; the quantity the
    deadline bounds, and what p50/p95/p99 are computed over.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class WaveRecord:
    """One computed wave, as recorded by the scheduler's compute thread."""

    t_done: float           # perf_counter when results were ready
    compute_s: float        # device compute time for the wave
    latency_s: float        # oldest-window end-to-end latency
    occupancy: int          # real (non-padding) windows in the wave
    batch: int              # static wave size the datapath saw
    deadline_flush: bool    # True when the deadline forced a partial wave


class MetricsSink:
    """Thread-safe accumulator of :class:`WaveRecord` rows.

    ``note_submit`` timestamps the first submission so achieved samples/s
    is measured over the full submit -> last-result wall interval.

    The sink is bounded: a long-lived server records one wave forever, so
    only the most recent ``window`` records are retained for the
    percentile/mean reductions (latency p50/p95/p99 then read as *current*
    behaviour, not lifetime history), while counts — waves, samples,
    deadline flushes, padded slots — and the samples/s wall interval are
    lifetime totals kept as O(1) counters."""

    def __init__(self, window: int = 4096):
        """Create an empty sink retaining the last ``window`` wave records;
        records arrive via :meth:`record_wave`."""
        self._lock = threading.Lock()
        self._recent: Deque[WaveRecord] = collections.deque(maxlen=window)
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None
        self._n_waves = 0
        self._n_samples = 0
        self._n_deadline_flushes = 0
        self._n_padded_slots = 0
        self._compute_s_total = 0.0
        self._counters: Dict[str, int] = collections.defaultdict(int)

    def count(self, name: str, n: int = 1) -> None:
        """Bump a named event counter (``state_resets``, ``sheds``,
        ``stream_errors``, ... — the reliability layer's events); read
        back with :meth:`counters`."""
        with self._lock:
            self._counters[name] += n

    def counters(self) -> Dict[str, int]:
        """Snapshot of the named event counters."""
        with self._lock:
            return dict(self._counters)

    def note_submit(self, t: float) -> None:
        """Record a submission timestamp (keeps the earliest)."""
        with self._lock:
            if self._t_first_submit is None or t < self._t_first_submit:
                self._t_first_submit = t

    def record_wave(self, record: WaveRecord) -> None:
        """Append one computed wave (rolls the window, bumps the lifetime
        counters)."""
        with self._lock:
            self._recent.append(record)
            if self._t_last_done is None or record.t_done > self._t_last_done:
                self._t_last_done = record.t_done
            self._n_waves += 1
            self._n_samples += record.occupancy
            self._n_deadline_flushes += bool(record.deadline_flush)
            self._n_padded_slots += record.batch - record.occupancy
            self._compute_s_total += record.compute_s

    @property
    def waves(self) -> List[WaveRecord]:
        """A snapshot copy of the retained (most recent ``window``) waves."""
        with self._lock:
            return list(self._recent)

    def _snapshot(self) -> Dict:
        """One consistent copy of every internal accumulator (for
        :meth:`merge` — taken under the lock, so a sink being merged while
        its server still records stays self-consistent)."""
        with self._lock:
            return {
                "recent": list(self._recent),
                "window": self._recent.maxlen,
                "t_first_submit": self._t_first_submit,
                "t_last_done": self._t_last_done,
                "n_waves": self._n_waves,
                "n_samples": self._n_samples,
                "n_deadline_flushes": self._n_deadline_flushes,
                "n_padded_slots": self._n_padded_slots,
                "compute_s_total": self._compute_s_total,
                "counters": dict(self._counters),
            }

    @classmethod
    def merge(cls, sinks: "List[MetricsSink]",
              window: Optional[int] = None) -> "MetricsSink":
        """Cluster aggregation: one sink summarising many replicas' sinks.

        Lifetime counters (waves, samples, deadline flushes, padded slots,
        named event counters) are SUMMED; the wall interval spans the
        earliest first-submit to the latest last-done across all replicas,
        so the merged ``samples_per_s`` is the cluster's aggregate
        throughput over the common measurement window.  The rolling
        percentile window is the union of the replicas' retained
        :class:`WaveRecord` rows ordered by completion time and truncated
        to ``window`` (default: the largest input window), so the merged
        p50/p95/p99 describe *current* cluster-wide wave latency exactly
        as a single server's sink would.  ``merge([])`` is the empty sink;
        empty inputs contribute nothing."""
        sinks = list(sinks)
        if window is None:
            window = max((s._recent.maxlen or 4096 for s in sinks),
                         default=4096)
        out = cls(window=window)
        snaps = [s._snapshot() for s in sinks]
        records = sorted((r for sn in snaps for r in sn["recent"]),
                         key=lambda r: r.t_done)
        out._recent.extend(records)          # deque keeps the most recent
        firsts = [sn["t_first_submit"] for sn in snaps
                  if sn["t_first_submit"] is not None]
        lasts = [sn["t_last_done"] for sn in snaps
                 if sn["t_last_done"] is not None]
        out._t_first_submit = min(firsts) if firsts else None
        out._t_last_done = max(lasts) if lasts else None
        out._n_waves = sum(sn["n_waves"] for sn in snaps)
        out._n_samples = sum(sn["n_samples"] for sn in snaps)
        out._n_deadline_flushes = sum(sn["n_deadline_flushes"]
                                      for sn in snaps)
        out._n_padded_slots = sum(sn["n_padded_slots"] for sn in snaps)
        out._compute_s_total = sum(sn["compute_s_total"] for sn in snaps)
        for sn in snaps:
            for k, v in sn["counters"].items():
                out._counters[k] += v
        return out

    def summary(self) -> Dict:
        """Reduce the records to the serving report's throughput/latency
        block (see the module and class docstrings for the latency
        definitions and the rolling-window vs lifetime split)."""
        with self._lock:
            recent = list(self._recent)
            t0 = self._t_first_submit
            t_end = self._t_last_done
            n_waves = self._n_waves
            n_samples = self._n_samples
            n_flushes = self._n_deadline_flushes
            n_padded = self._n_padded_slots
            compute_total = self._compute_s_total
        if not recent:
            return {"waves": 0, "samples": 0, "samples_per_s": 0.0}
        lat = np.asarray([w.latency_s for w in recent])
        comp = np.asarray([w.compute_s for w in recent])
        wall_s = (t_end - t0) if t0 is not None else compute_total
        p50, p95, p99 = np.percentile(lat, [50, 95, 99])
        return {
            "waves": n_waves,
            "samples": n_samples,
            "wall_s": float(wall_s),
            "samples_per_s": n_samples / wall_s if wall_s > 0 else 0.0,
            "latency_ms": {"p50": float(p50 * 1e3), "p95": float(p95 * 1e3),
                           "p99": float(p99 * 1e3),
                           "mean": float(lat.mean() * 1e3)},
            "compute_ms_mean": float(comp.mean() * 1e3),
            "mean_occupancy": n_samples / n_waves,
            "batch": recent[-1].batch,
            "deadline_flushes": n_flushes,
            "padded_slots": int(n_padded),
        }
