"""Bounded per-stream recurrent carry — the serving layer's state store.

Each live client stream owns one accelerator carry: per layer, a tuple
of the cell's ``state_arity`` int32 code vectors after the stream's last
window (the LSTM's (h, c) pair, a single h row for GRU/rGLRU — one batch
row of ``repro.cells.init_state``).  The store itself is shape-agnostic:
it never inspects the arrays, so one store serves every registered cell.
The store is a bounded LRU map: the paper's deployment
target is an embedded device with fixed state memory, and the ROADMAP
scenario is "millions of users" — so the store must evict, not grow.  An
evicted stream restarts from the reset state (all-zero carry) on its next
window, exactly as if it were a new stream — and since PR 6 that restart
is REPORTED, not silent: the window computed from the reset carry comes
back with ``StreamResult.state_reset=True`` and bumps the
``state_resets`` counter in the metrics.  The eviction counter in
:meth:`StateStore.stats` is the capacity-planning signal to raise
``max_streams`` when resets matter.

Thread-safety: all methods take the internal lock — the store is shared
between the scheduler's compute thread (gather/scatter) and client threads
(``end_stream``).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

# Per-stream carry: per layer, a tuple of the cell's ``state_arity``
# (hidden_size,) int32 code vectors (2 for the LSTM's (h, c), 1 for
# GRU/rGLRU).
StreamState = List[Tuple[np.ndarray, ...]]


class StateStore:
    """LRU map ``stream_id -> StreamState`` with a hard capacity.

    ``get`` refreshes recency; ``put`` inserts/updates and evicts the
    least-recently-used stream when over ``capacity``.  Hit/miss/eviction
    counters feed the serving metrics report."""

    def __init__(self, capacity: int = 1024):
        """``capacity``: maximum number of live stream carries (>= 1)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._states: "OrderedDict[Hashable, StreamState]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, stream_id: Hashable) -> Optional[StreamState]:
        """The stream's carry (refreshing its recency), or ``None`` when the
        stream is new or was evicted — the caller starts from zeros."""
        with self._lock:
            state = self._states.get(stream_id)
            if state is None:
                self.misses += 1
                return None
            self._states.move_to_end(stream_id)
            self.hits += 1
            return state

    def put(self, stream_id: Hashable,
            state: StreamState) -> List[Hashable]:
        """Store the carry after a window; evicts the LRU stream(s) if
        full.  Returns the evicted stream ids so the caller can release
        any per-stream bookkeeping of its own."""
        evicted: List[Hashable] = []
        with self._lock:
            self._states[stream_id] = state
            self._states.move_to_end(stream_id)
            while len(self._states) > self.capacity:
                victim, _ = self._states.popitem(last=False)
                self.evictions += 1
                evicted.append(victim)
        return evicted

    def pop(self, stream_id: Hashable) -> Optional[StreamState]:
        """Drop a stream's carry (explicit end-of-stream); returns it."""
        with self._lock:
            return self._states.pop(stream_id, None)

    def ids(self) -> List[Hashable]:
        """Snapshot of the live stream ids, LRU-first — the server's
        ``reset_streams()`` walks it to end every stream."""
        with self._lock:
            return list(self._states)

    def __len__(self) -> int:
        with self._lock:
            return len(self._states)

    def __contains__(self, stream_id: Hashable) -> bool:
        with self._lock:
            return stream_id in self._states

    def stats(self) -> Dict[str, int]:
        """Counters for the metrics report: live streams, capacity,
        hits/misses (carry found vs reset), and evictions."""
        with self._lock:
            return {"live_streams": len(self._states),
                    "capacity": self.capacity, "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}
