"""Async double-buffered wave scheduler — host assembly overlapped with
device compute.

Built on the ``data/pipeline.py`` prefetch-queue pattern: a bounded
``queue.Queue`` of assembled waves decouples two threads,

  * the ASSEMBLER, which groups pending windows into fixed-size waves
    (stacking them into one contiguous ``(batch, T, M)`` array and padding
    partial waves), and
  * the COMPUTE thread, which pops waves and runs the caller's ``execute``
    hook (state gather -> device datapath -> state scatter -> results),

so the host assembles wave *N+1* while the device computes wave *N*.
Backpressure is configurable at both ends: ``max_pending`` bounds
submitted-but-unassembled windows (``submit`` blocks), ``queue_depth``
bounds assembled-but-uncomputed waves (default 2 — classic double
buffering).

Tail latency is bounded by the DEADLINE: a wave normally waits until
``batch`` windows are available (maximum device efficiency), but once the
oldest pending window has waited ``deadline_s`` the scheduler flushes a
partial wave — padded to the static shape, padding dropped — instead of
stalling a slow stream behind a full-wave quorum.  ``deadline_s=None``
waits for full waves (the strict ``Accelerator.serve`` semantics; the
final partial wave still flushes on drain/close).  Independent of the
deadline, a SATURATION flush fires when pending hits ``max_pending`` and
no full wave can be assembled (one-window-per-stream, or ``max_pending``
< ``batch``): submitters are blocked at that point, so waiting for a
quorum that cannot form would deadlock the pipeline.

OVERLOAD behaviour is opt-in via :class:`OverloadPolicy`: admission
control turns the blocking ``submit`` into a bounded-latency reject
(:class:`ServerOverloaded`) once the pending queue is saturated and the
rolling deadline-miss rate says the backlog is not clearing, and
deadline-aware load shedding drops pending windows whose wait already
exceeds ``shed_after_s`` (their deadline is hopeless; computing them
would only delay windows that can still make theirs) through the
``on_shed`` callback instead of computing them.  Both are accounted:
``stats()`` feeds the serving health snapshot.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import (Callable, Deque, Dict, Hashable, List, Optional,
                    Tuple)

import numpy as np

_SENTINEL = object()


class ServerOverloaded(RuntimeError):
    """``submit`` rejected by admission control: the pending queue is
    saturated and the rolling deadline-miss rate shows the backlog is not
    clearing.  The client should back off (or route elsewhere) — blocking
    it would only add latency to a request that will miss its deadline
    anyway."""


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Admission-control and load-shedding knobs (all opt-in; the default
    scheduler keeps the legacy block-on-backpressure behaviour).

    ``admission``: ``"reject"`` raises :class:`ServerOverloaded` from
    ``submit`` instead of blocking once pending is saturated AND the
    rolling deadline-miss rate is >= ``reject_miss_rate``; ``"block"``
    keeps blocking (shedding can still be on).  ``reject_miss_rate``: the
    miss-rate gate for rejection — 0.0 rejects on queue depth alone; with
    no deadline configured the miss rate is always 0.0, so any positive
    gate disables rejection.  ``shed_after_s``: a pending window that has
    already waited this long is dropped (reported through the scheduler's
    ``on_shed`` callback as an error result) rather than computed —
    deadline-aware shedding, typically a small multiple of ``deadline_s``.
    ``miss_window``: waves in the rolling deadline-miss window."""

    admission: str = "reject"
    reject_miss_rate: float = 0.0
    shed_after_s: Optional[float] = None
    miss_window: int = 64

    def __post_init__(self):
        """Validate the policy's gates and bounds."""
        if self.admission not in ("reject", "block"):
            raise ValueError(f"admission must be 'reject' or 'block', got "
                             f"{self.admission!r}")
        if not 0.0 <= self.reject_miss_rate <= 1.0:
            raise ValueError(f"reject_miss_rate must be in [0, 1], got "
                             f"{self.reject_miss_rate}")
        if self.shed_after_s is not None and self.shed_after_s <= 0:
            raise ValueError(f"shed_after_s must be > 0, got "
                             f"{self.shed_after_s}")
        if self.miss_window < 1:
            raise ValueError(f"miss_window must be >= 1, got "
                             f"{self.miss_window}")


@dataclasses.dataclass(frozen=True)
class Slot:
    """One real (non-padding) row of a wave."""

    stream_id: Hashable
    seq: int          # per-stream sequence number (the submit return value)
    sub_idx: int      # global submission index — strictly increasing across
                      # the scheduler's lifetime, orders windows ACROSS
                      # streams (end_stream tombstones compare against it)


@dataclasses.dataclass(frozen=True)
class Wave:
    """One assembled wave, ready for the compute thread."""

    x: np.ndarray                             # (batch, T, M) float32
    slots: Tuple[Slot, ...]                   # one per real row
    t_oldest: float                           # submit time of oldest window
    deadline_flush: bool                      # partial wave forced by deadline

    @property
    def occupancy(self) -> int:
        """Number of real (non-padding) rows."""
        return len(self.slots)


@dataclasses.dataclass(frozen=True)
class _Pending:
    stream_id: Hashable
    seq: int
    sub_idx: int
    window: np.ndarray
    t_submit: float


class WaveScheduler:
    """Threaded wave assembly/compute pipeline behind ``StreamServer``.

    ``execute(wave)`` runs on the compute thread and owns everything
    device-side; the scheduler owns grouping, padding, deadlines,
    backpressure, and the drain/close lifecycle.  With
    ``one_per_stream=True`` (stateful serving) a wave carries at most one
    window per stream — window *k+1* of a stream must see the carry
    produced by window *k*, so it waits for the next wave."""

    def __init__(self, batch: int, execute: Callable[[Wave], None], *,
                 one_per_stream: bool, deadline_s: Optional[float] = None,
                 queue_depth: int = 2, max_pending: Optional[int] = None,
                 overload: Optional[OverloadPolicy] = None,
                 on_shed: Optional[Callable[[Slot], None]] = None):
        """``batch``: static wave size; ``queue_depth``: assembled waves the
        compute thread may fall behind by; ``max_pending``: bound on
        unassembled windows (None -> 4 * batch); ``overload``: admission/
        shedding policy (None = always block, never shed); ``on_shed``:
        called (assembler thread) once per shed window with its
        :class:`Slot` so the owner can emit an error result."""
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        if max_pending is not None and max_pending < 1:
            # 0 would block the first submit forever: nothing pending, so
            # the saturation flush can never fire either.
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.batch = batch
        self.deadline_s = deadline_s
        self.max_pending = 4 * batch if max_pending is None else max_pending
        self.overload = overload
        self._on_shed = on_shed
        self._execute = execute
        self._one_per_stream = one_per_stream
        self._pending: List[_Pending] = []
        self._cond = threading.Condition()
        self._waveq: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self._submitted = 0
        self._completed = 0
        self._draining = 0          # active flush() calls
        self._closing = False       # drain everything, then stop
        self._stop = False          # stop ASAP, abandon pending work
        self._error: Optional[BaseException] = None
        # Rolling deadline-miss window (True = the wave's oldest window
        # exceeded deadline_s end-to-end) — drives admission control.
        self._misses: Deque[bool] = collections.deque(
            maxlen=overload.miss_window if overload else 64)
        self._sheds = 0
        self._rejections = 0
        self._recoveries = 0
        #: Thread names still alive after the last close() — leaked.
        self.leaked_threads: List[str] = []
        self._assembler = threading.Thread(target=self._assemble_loop,
                                           daemon=True,
                                           name="wave-assembler")
        self._compute = threading.Thread(target=self._compute_loop,
                                         daemon=True, name="wave-compute")
        self._assembler.start()
        self._compute.start()

    # -- client side --------------------------------------------------------

    def submit(self, stream_id: Hashable, window: np.ndarray,
               alloc_seq: Callable[[], int]) -> int:
        """Enqueue one window; blocks while ``max_pending`` windows wait
        (backpressure).  Raises if the scheduler is closed or the compute
        thread has failed.

        ``alloc_seq`` is called INSIDE the critical section, immediately
        before the window joins the pending list — so the caller's
        per-stream sequence numbering and the FIFO insertion order cannot
        be reordered between concurrently submitting threads.  Returns the
        allocated sequence number.

        With a reject-mode :class:`OverloadPolicy`, a submit that would
        block on a saturated queue while the rolling deadline-miss rate is
        at or above ``reject_miss_rate`` raises :class:`ServerOverloaded`
        instead — bounded-latency admission control."""
        with self._cond:
            while (not self._closing and self._error is None
                   and len(self._pending) >= self.max_pending):
                if (self.overload is not None
                        and self.overload.admission == "reject"
                        and self._miss_rate_locked()
                        >= self.overload.reject_miss_rate):
                    self._rejections += 1
                    raise ServerOverloaded(
                        f"admission rejected: {len(self._pending)}/"
                        f"{self.max_pending} windows pending, rolling "
                        f"deadline-miss rate "
                        f"{self._miss_rate_locked():.2f} >= "
                        f"{self.overload.reject_miss_rate:.2f}")
                self._cond.wait(timeout=0.1)
            self._raise_if_dead()
            seq = alloc_seq()
            self._pending.append(_Pending(stream_id, seq, self._submitted,
                                          window, time.perf_counter()))
            self._submitted += 1
            self._cond.notify_all()
            return seq

    def submission_watermark(self) -> int:
        """Number of windows ever submitted; a window enqueued strictly
        before this call has ``sub_idx`` < the returned value."""
        with self._cond:
            return self._submitted

    def flush(self, timeout: Optional[float] = None) -> None:
        """Barrier: force partial waves and block until every window
        submitted before the call has been computed."""
        with self._cond:
            self._raise_if_dead()
            target = self._submitted   # every window submitted before now
            self._draining += 1
            self._cond.notify_all()
        deadline = None if timeout is None else time.perf_counter() + timeout
        try:
            with self._cond:
                while self._completed < target and self._error is None:
                    remaining = None if deadline is None \
                        else deadline - time.perf_counter()
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(
                            f"flush timed out: {self._completed}/{target} "
                            f"windows completed")
                    self._cond.wait(timeout=remaining if remaining is not None
                                    else 0.5)
                if self._error is not None:
                    raise self._error
        finally:
            with self._cond:
                self._draining -= 1
                self._cond.notify_all()

    def close(self, abandon: bool = False,
              timeout: float = 30.0) -> List[str]:
        """Stop the pipeline.  Default: drain pending windows first (every
        submitted window gets computed); ``abandon=True`` stops ASAP and
        discards pending work (the consumer walked away).

        If the drain cannot complete within ``timeout`` — e.g. a bounded
        results queue (``max_results``) wedged by a consumer that stopped
        polling — close escalates to abandon so the worker threads exit
        instead of leaking, and returns in bounded time.  Returns the
        names of any threads STILL alive after the escalated join (also
        kept on :attr:`leaked_threads`) — an empty list is the clean
        shutdown; a non-empty one means a wave is wedged inside the
        datapath and the daemon thread will die with the process."""
        with self._cond:
            if abandon:
                self._stop = True
            self._closing = True
            self._cond.notify_all()
        self._assembler.join(timeout=timeout)
        self._compute.join(timeout=timeout)
        if self._assembler.is_alive() or self._compute.is_alive():
            with self._cond:
                self._stop = True
                self._cond.notify_all()
            self._assembler.join(timeout=timeout)
            self._compute.join(timeout=timeout)
        self.leaked_threads = [t.name for t in (self._assembler,
                                                self._compute)
                               if t.is_alive()]
        return self.leaked_threads

    @property
    def error(self) -> Optional[BaseException]:
        """The compute thread's MOST RECENT unrecovered failure (re-raised
        by submit/flush and by ``StreamServer.poll``).  Cleared when a
        later wave completes cleanly — a transient fault must not poison
        every subsequent call forever (``stats()["recoveries"]`` counts
        the clears)."""
        return self._error

    def _miss_rate_locked(self) -> float:
        """Rolling deadline-miss rate; caller holds ``_cond``."""
        return (sum(self._misses) / len(self._misses)) if self._misses \
            else 0.0

    def miss_rate(self) -> float:
        """Fraction of the last ``miss_window`` waves whose oldest window
        exceeded ``deadline_s`` end-to-end (0.0 with no deadline)."""
        with self._cond:
            return self._miss_rate_locked()

    def stats(self) -> Dict[str, float]:
        """Overload/recovery counters for the serving health snapshot:
        pending depth, rolling miss rate, lifetime sheds/rejections/
        recoveries, and the error-state flag."""
        with self._cond:
            return {"pending": len(self._pending),
                    "max_pending": self.max_pending,
                    "deadline_miss_rate": self._miss_rate_locked(),
                    "sheds": self._sheds,
                    "rejections": self._rejections,
                    "recoveries": self._recoveries,
                    "dead": self._error is not None}

    @property
    def stopped(self) -> bool:
        """True once ``close(abandon=True)`` was requested — long blocking
        operations on the compute path should give up."""
        return self._stop

    def _raise_if_dead(self):
        if self._error is not None:
            raise self._error
        if self._closing:
            raise RuntimeError("scheduler is closed")

    # -- assembler thread ---------------------------------------------------

    def _select(self):
        """Pick up to ``batch`` pending windows, oldest first, at most one
        per stream when the carry demands it.  Returns (chosen, rest)."""
        chosen: List[_Pending] = []
        rest: List[_Pending] = []
        seen = set()
        for p in self._pending:
            if len(chosen) < self.batch and \
                    (not self._one_per_stream or p.stream_id not in seen):
                chosen.append(p)
                seen.add(p.stream_id)
            else:
                rest.append(p)
        return chosen, rest

    def _assemble_loop(self):
        while True:
            shed = self._shed_expired()
            if shed:
                for p in shed:
                    if self._on_shed is not None:
                        self._on_shed(Slot(p.stream_id, p.seq, p.sub_idx))
                with self._cond:
                    # A shed window is accounted as completed (flush must
                    # not wait forever for work that was dropped) only
                    # AFTER its error result was emitted, so drain() sees
                    # the row.
                    self._completed += len(shed)
                    self._sheds += len(shed)
                    self._cond.notify_all()
                continue
            with self._cond:
                if self._stop:
                    break
                chosen, rest = self._select()
                now = time.perf_counter()
                full = len(chosen) == self.batch
                force = self._draining > 0 or self._closing
                deadline_hit = (self.deadline_s is not None and chosen
                                and now - chosen[0].t_submit
                                >= self.deadline_s)
                # Saturation flush: with submitters blocked on max_pending
                # and no full wave assemblable (one window per stream, or
                # max_pending < batch), waiting for quorum would deadlock —
                # ship what is eligible and free pending slots.
                saturated = len(self._pending) >= self.max_pending
                if not chosen or not (full or force or deadline_hit
                                      or saturated):
                    if self._closing and not self._pending:
                        break
                    wait = None
                    if self.deadline_s is not None and chosen:
                        wait = max(0.0, chosen[0].t_submit + self.deadline_s
                                   - now)
                    self._cond.wait(timeout=wait if wait is not None else 0.5)
                    continue
                self._pending = rest
                self._cond.notify_all()   # wake submitters (backpressure)
            wave = self._build_wave(chosen, deadline_flush=not full
                                    and deadline_hit and not force)
            if not self._put_wave(wave):
                break
        self._put_wave(_SENTINEL)

    def _shed_expired(self) -> List[_Pending]:
        """Remove and return pending windows whose wait already exceeds
        the policy's ``shed_after_s`` (their deadline is hopeless —
        computing them would only delay windows that can still make
        theirs).  Empty when shedding is off."""
        if self.overload is None or self.overload.shed_after_s is None:
            return []
        with self._cond:
            if self._stop or not self._pending:
                return []
            cutoff = time.perf_counter() - self.overload.shed_after_s
            shed = [p for p in self._pending if p.t_submit <= cutoff]
            if shed:
                self._pending = [p for p in self._pending
                                 if p.t_submit > cutoff]
                self._cond.notify_all()   # wake blocked submitters
            return shed

    def _build_wave(self, chosen: List[_Pending],
                    deadline_flush: bool) -> Wave:
        rows = [p.window for p in chosen]
        # Pad the partial wave to the static shape by repeating the last
        # real window; padded rows are computed and DROPPED — they are
        # never emitted as results and never touch the state store.
        rows.extend([rows[-1]] * (self.batch - len(rows)))
        return Wave(x=np.stack(rows, axis=0),
                    slots=tuple(Slot(p.stream_id, p.seq, p.sub_idx)
                                for p in chosen),
                    t_oldest=min(p.t_submit for p in chosen),
                    deadline_flush=deadline_flush)

    def _put_wave(self, item) -> bool:
        # On abandon (_stop) give up rather than block: the compute loop
        # exits on its own _stop check, so the sentinel is not needed there.
        while True:
            try:
                self._waveq.put(item, timeout=0.1)
                return True
            except queue.Full:
                if self._stop:
                    return False

    # -- compute thread -----------------------------------------------------

    def _compute_loop(self):
        while True:
            try:
                item = self._waveq.get(timeout=0.1)
            except queue.Empty:
                if self._stop:
                    return
                continue
            if item is _SENTINEL:
                return
            if not self._stop:
                # Waves keep executing even while _error is set: one
                # failed wave must not condemn every later one unseen.
                try:
                    self._execute(item)
                    with self._cond:
                        if self._error is not None:
                            # A later wave completed cleanly: the failure
                            # was transient, stop re-raising it forever.
                            self._error = None
                            self._recoveries += 1
                except BaseException as e:  # surfaced to clients
                    with self._cond:
                        self._error = e
            with self._cond:
                if self.deadline_s is not None:
                    self._misses.append(
                        time.perf_counter() - item.t_oldest
                        > self.deadline_s)
                self._completed += item.occupancy
                self._cond.notify_all()
