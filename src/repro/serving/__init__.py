"""``repro.serving`` — the stateful streaming serving subsystem.

The paper's deployment story is a single real-time sensor stream (§6:
32 873 samples/s); this package is the production form of that story —
many named client streams multiplexed onto one or more ``Accelerator``
sessions, each stream's LSTM (h, c) carry held across windows, waves
double-buffered against device compute, tail latency bounded by a
deadline, and the paper's metrics (samples/s, GOP/s/W, latency
percentiles) measured where the server actually runs.

Public surface (docs/SERVING.md is the deployment guide):

  * :class:`StreamServer` — submit/poll/flush/close over named streams.
  * :class:`ServingConfig` — batch, deadline, backpressure, state-store
    capacity.
  * :class:`StreamResult` — (stream_id, seq, prediction) rows.
  * :class:`StateStore` — the bounded LRU carry store (exposed for tests
    and capacity planning).
  * :func:`serve_windows` — ordered stateless mapping; the engine behind
    the ``Accelerator.serve`` / ``WaveBatcher.for_accelerator`` compat
    wrappers.
"""

from repro.serving.metrics import MetricsSink, WaveRecord        # noqa: F401
from repro.serving.scheduler import Wave, WaveScheduler          # noqa: F401
from repro.serving.server import (ServingConfig, StreamResult,   # noqa: F401
                                  StreamServer, serve_windows)
from repro.serving.state import StateStore, StreamState          # noqa: F401

__all__ = [
    "MetricsSink", "ServingConfig", "StateStore", "StreamResult",
    "StreamServer", "StreamState", "Wave", "WaveRecord", "WaveScheduler",
    "serve_windows",
]
