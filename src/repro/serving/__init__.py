"""``repro.serving`` — the stateful streaming serving subsystem.

The paper's deployment story is a single real-time sensor stream (§6:
32 873 samples/s); this package is the production form of that story —
many named client streams multiplexed onto one or more ``Accelerator``
sessions, each stream's recurrent carry held across windows, waves
double-buffered against device compute, tail latency bounded by a
deadline, and the paper's metrics (samples/s, GOP/s/W, latency
percentiles) measured where the server actually runs.

Public surface (docs/SERVING.md is the deployment guide):

  * :class:`StreamServer` — submit/poll/flush/close over named streams.
  * :class:`ServingConfig` — batch, deadline, backpressure, state-store
    capacity, resilience and overload policies.
  * :class:`StreamResult` — (stream_id, seq, prediction) rows, plus the
    structured error/``state_reset`` reliability flags.
  * :class:`StateStore` — the bounded LRU carry store (exposed for tests
    and capacity planning).
  * :class:`DeviceStateStore` / :class:`SlotAllocator` — the
    device-resident alternative (``repro.serving.device_state``): carries
    live in an on-accelerator slot table, the host keeps only the LRU
    ``stream_id -> slot`` map, and the hot path ships slot ids instead of
    (h, c) arrays (``ServingConfig.state_residency``).
  * :class:`ResiliencePolicy` / :class:`ExecutionGuard` — guarded wave
    execution: retry, timeout, backend degradation pallas -> xla -> ref
    with recovery probes (``repro.serving.resilience``).
  * :class:`OverloadPolicy` / :class:`ServerOverloaded` — admission
    control and deadline-aware load shedding.
  * :class:`FaultInjector` / :class:`FaultConfig` — the seeded chaos
    harness (``repro.serving.faults``); :class:`InjectedFault` is what it
    raises.
  * :func:`serve_windows` — ordered stateless mapping; the engine behind
    the ``Accelerator.serve`` / ``WaveBatcher.for_accelerator`` compat
    wrappers.
  * :class:`ClusterServer` / :class:`ClusterConfig` — N per-device
    replica servers behind a consistent-hash front door
    (``repro.serving.cluster``; docs/SERVING.md §Scaling out).
  * :class:`HashRing` — the routing primitive itself
    (``repro.serving.routing``), exposed for external load balancers
    that want to compute the same stream -> replica mapping.
"""

from repro.serving.cluster import ClusterConfig, ClusterServer   # noqa: F401
from repro.serving.device_state import (DeviceStateStore,        # noqa: F401
                                        SlotAllocator)
from repro.serving.faults import (FaultConfig, FaultInjector,    # noqa: F401
                                  InjectedFault)
from repro.serving.metrics import MetricsSink, WaveRecord        # noqa: F401
from repro.serving.resilience import (ExecutionGuard,            # noqa: F401
                                      GuardOutcome, ResiliencePolicy,
                                      WaveTimeout)
from repro.serving.routing import HashRing                       # noqa: F401
from repro.serving.scheduler import (OverloadPolicy,             # noqa: F401
                                     ServerOverloaded, Wave,
                                     WaveScheduler)
from repro.serving.server import (ServingConfig, StreamResult,   # noqa: F401
                                  StreamServer, serve_windows)
from repro.serving.state import StateStore, StreamState          # noqa: F401

__all__ = [
    "ClusterConfig", "ClusterServer", "DeviceStateStore", "ExecutionGuard",
    "FaultConfig", "FaultInjector", "GuardOutcome", "HashRing",
    "InjectedFault", "SlotAllocator",
    "MetricsSink", "OverloadPolicy", "ResiliencePolicy", "ServerOverloaded",
    "ServingConfig", "StateStore", "StreamResult", "StreamServer",
    "StreamState", "Wave", "WaveRecord", "WaveScheduler", "WaveTimeout",
    "serve_windows",
]
