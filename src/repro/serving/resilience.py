"""Guarded wave execution — retry, timeout, and backend degradation.

One malformed wave or one transient device error must not take down every
stream of an always-on server.  :class:`ExecutionGuard` is the layer that
makes the compute thread unkillable by ordinary failures:

  * each wave attempt runs under an optional **timeout** (a hung attempt
    is abandoned, not waited on forever);
  * a failed attempt is **retried** with exponential backoff, a bounded
    number of times per engine;
  * when the preferred engine keeps failing, the guard **degrades** down a
    ladder of bit-identical engines — ``pallas -> xla -> ref`` — and keeps
    serving.  Because the int path is verified bit-exact across all three
    (tests/test_api.py), degradation changes *latency only, never
    results*: this is the graceful-degradation lever a single-engine
    design does not have;
  * after ``promote_after`` clean waves at a degraded level, a **recovery
    probe** tries the faster engine again and promotes back on success.

The guard is datapath-agnostic: :meth:`ExecutionGuard.run` takes the
wave's ordered ``(name, callable)`` ladder and returns a
:class:`GuardOutcome` — it never raises for an attempt failure.  Only a
wave that fails on *every* level of the ladder comes back ``ok=False``;
the server then converts it into per-stream error results instead of a
dead compute thread.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class WaveTimeout(RuntimeError):
    """An execute attempt exceeded ``wave_timeout_s`` and was abandoned."""


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Knobs of the guarded execute path (docs/SERVING.md §Reliability).

    ``max_retries``: extra attempts per engine per wave (total attempts at
    one level = 1 + max_retries).  ``backoff_base_s`` * ``backoff_factor``
    ^ (attempt-1), capped at ``backoff_max_s``, is slept between attempts.
    ``wave_timeout_s``: per-attempt wall bound (None = no timeout, no
    helper thread).  ``degrade_after``: consecutive waves on which the
    preferred engine failed before the guard degrades to the next ladder
    level.  ``promote_after``: clean waves at a degraded level before a
    recovery probe re-tries the faster engine."""

    max_retries: int = 2
    backoff_base_s: float = 0.001
    backoff_factor: float = 2.0
    backoff_max_s: float = 0.100
    wave_timeout_s: Optional[float] = None
    degrade_after: int = 2
    promote_after: int = 8

    def __post_init__(self):
        """Reject nonsensical retry/backoff/threshold values."""
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff bounds must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError(f"backoff_factor must be >= 1, got "
                             f"{self.backoff_factor}")
        if self.wave_timeout_s is not None and self.wave_timeout_s <= 0:
            raise ValueError(f"wave_timeout_s must be > 0, got "
                             f"{self.wave_timeout_s}")
        if self.degrade_after < 1 or self.promote_after < 1:
            raise ValueError("degrade_after and promote_after must be >= 1")

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based): exponential, capped."""
        return min(self.backoff_max_s,
                   self.backoff_base_s * self.backoff_factor ** (attempt - 1))


@dataclasses.dataclass(frozen=True)
class GuardOutcome:
    """What one guarded wave execution produced.

    ``ok``: some ladder level succeeded; ``value`` is that level's return
    and ``backend`` its name.  ``ok=False`` means every level failed;
    ``error`` holds the last failure, one entry per failed attempt in
    ``attempt_errors``.  ``retries``/``timeouts`` count this wave's extra
    attempts and abandoned (timed-out) attempts; ``degraded``/``promoted``
    flag ladder moves the wave triggered."""

    ok: bool
    value: Any = None
    backend: Optional[str] = None
    retries: int = 0
    timeouts: int = 0
    degraded: bool = False
    promoted: bool = False
    error: Optional[str] = None
    attempt_errors: Tuple[str, ...] = ()


class ExecutionGuard:
    """Retry/degrade/promote state machine for the compute thread.

    Holds the current ladder level and its failure/clean-streak counters;
    :meth:`run` executes one wave through the ladder the caller passes
    (ordered fastest first — the same order every wave).  The guard never
    raises on attempt failure and is intentionally ignorant of waves,
    streams, and state — it guards *callables*, which keeps it unit-
    testable with plain lambdas."""

    def __init__(self, ladder_names: Sequence[str],
                 policy: Optional[ResiliencePolicy] = None):
        """``ladder_names``: engine names, fastest first (level 0 is the
        preferred engine); ``policy`` defaults to
        :class:`ResiliencePolicy()`."""
        if not ladder_names:
            raise ValueError("the degradation ladder cannot be empty")
        self.ladder = tuple(ladder_names)
        self.policy = policy or ResiliencePolicy()
        self._lock = threading.Lock()
        self._level = 0                 # current preferred ladder index
        self._fail_streak = 0           # consecutive waves level failed on
        self._clean_streak = 0          # consecutive clean waves at level
        self._counts: Dict[str, int] = {
            "waves": 0, "retries": 0, "timeouts": 0, "wave_failures": 0,
            "degradations": 0, "promotions": 0, "probes": 0,
            "abandoned_attempts": 0}
        self._executor: Optional[ThreadPoolExecutor] = None

    # -- introspection -------------------------------------------------------

    @property
    def backend(self) -> str:
        """Name of the engine the next wave will try first."""
        with self._lock:
            return self.ladder[self._level]

    @property
    def degraded(self) -> bool:
        """True while serving below the preferred (level-0) engine."""
        with self._lock:
            return self._level > 0

    def stats(self) -> Dict[str, Any]:
        """Lifetime guard counters plus the current ladder position —
        the ``faults.guard`` block of ``metrics_summary()``."""
        with self._lock:
            return {**self._counts, "backend": self.ladder[self._level],
                    "level": self._level, "ladder": list(self.ladder),
                    "fail_streak": self._fail_streak,
                    "clean_streak": self._clean_streak}

    # -- execution -----------------------------------------------------------

    def run(self, fns: Sequence[Tuple[str, Callable]], *args) -> GuardOutcome:
        """Execute one wave through the ladder.

        ``fns``: ordered ``(name, callable)`` pairs matching the ladder
        this guard was built with (the caller may pass a prefix-compatible
        ladder, e.g. per-session callables; names are matched by the
        guard's current level name, falling back to positional order).
        ``*args`` are passed to the chosen callable.  Never raises for an
        attempt failure — inspect the returned :class:`GuardOutcome`."""
        by_name = dict(fns)
        order = [n for n, _ in fns]
        with self._lock:
            level = self._level
            probe = (level > 0
                     and self._clean_streak >= self.policy.promote_after)
            if probe:
                self._counts["probes"] += 1
            self._counts["waves"] += 1
        start = max(0, level - 1) if probe else level
        start = min(start, len(order) - 1)

        retries = timeouts = 0
        errors: List[str] = []
        preferred_failed = False
        for idx in range(start, len(order)):
            name = order[idx]
            ok, value, att_r, att_t, errs = self._attempt_level(
                by_name[name], name, args)
            retries += att_r
            timeouts += att_t
            errors.extend(errs)
            if ok:
                return self._note_success(idx, level, probe, value, name,
                                          retries, timeouts, errors,
                                          preferred_failed)
            if idx == level:
                preferred_failed = True
        return self._note_total_failure(level, retries, timeouts, errors)

    def _attempt_level(self, fn: Callable, name: str, args):
        """Up to ``1 + max_retries`` attempts of ``fn`` with backoff;
        returns (ok, value, retries, timeouts, error strings)."""
        retries = timeouts = 0
        errors: List[str] = []
        for attempt in range(1 + self.policy.max_retries):
            if attempt > 0:
                retries += 1
                time.sleep(self.policy.backoff_s(attempt))
            try:
                return True, self._call(fn, args), retries, timeouts, errors
            except WaveTimeout as e:
                timeouts += 1
                errors.append(f"{name}: {e}")
            except Exception as e:  # noqa: BLE001 — isolate, don't die
                errors.append(f"{name}: {type(e).__name__}: {e}")
        return False, None, retries, timeouts, errors

    def _call(self, fn: Callable, args):
        """One attempt, under the policy timeout when one is set."""
        if self.policy.wave_timeout_s is None:
            return fn(*args)
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="wave-guard")
        fut = self._executor.submit(fn, *args)
        try:
            return fut.result(timeout=self.policy.wave_timeout_s)
        except _FutureTimeout:
            # The worker may be stuck inside the attempt: abandon the
            # whole executor (shutdown without wait) and start a fresh
            # one, so the next attempt is not queued behind a zombie.
            stale = self._executor
            self._executor = None
            stale.shutdown(wait=False)
            with self._lock:
                self._counts["abandoned_attempts"] += 1
            raise WaveTimeout(
                f"attempt exceeded wave_timeout_s="
                f"{self.policy.wave_timeout_s}") from None

    def _note_success(self, idx: int, level: int, probe: bool, value,
                      name: str, retries: int, timeouts: int,
                      errors: List[str],
                      preferred_failed: bool) -> GuardOutcome:
        degraded = promoted = False
        with self._lock:
            self._counts["retries"] += retries
            self._counts["timeouts"] += timeouts
            if probe and idx < level:
                # Recovery probe landed: promote back one level.
                self._level = idx
                self._clean_streak = 0
                self._fail_streak = 0
                self._counts["promotions"] += 1
                promoted = True
            elif preferred_failed:
                # The preferred engine failed this wave (a lower level
                # carried it).  Repeated failures degrade the preference.
                self._fail_streak += 1
                self._clean_streak = 0
                if self._fail_streak >= self.policy.degrade_after \
                        and self._level < len(self.ladder) - 1:
                    self._level = min(idx, len(self.ladder) - 1)
                    self._fail_streak = 0
                    self._counts["degradations"] += 1
                    degraded = True
            else:
                self._fail_streak = 0
                # A failed probe (the faster engine raised, the current
                # level carried the wave) resets the streak: wait another
                # promote_after clean waves before probing again.
                self._clean_streak = 0 if probe else self._clean_streak + 1
        return GuardOutcome(ok=True, value=value, backend=name,
                            retries=retries, timeouts=timeouts,
                            degraded=degraded, promoted=promoted,
                            attempt_errors=tuple(errors))

    def _note_total_failure(self, level: int, retries: int, timeouts: int,
                            errors: List[str]) -> GuardOutcome:
        with self._lock:
            self._counts["retries"] += retries
            self._counts["timeouts"] += timeouts
            self._counts["wave_failures"] += 1
            self._fail_streak += 1
            self._clean_streak = 0
        return GuardOutcome(ok=False, retries=retries, timeouts=timeouts,
                            error=errors[-1] if errors else "no attempts",
                            attempt_errors=tuple(errors))

    def close(self) -> None:
        """Release the timeout helper thread, if one was ever started."""
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
