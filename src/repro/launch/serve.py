"""Serving launcher — batched decode with a KV/recurrent-state cache.

  python -m repro.launch.serve --arch qwen1.5-0.5b --batch 4 --gen 16
  python -m repro.launch.serve --arch rwkv6-7b --quant w8 --kv-int8

The paper's kind is inference acceleration, so this is the e2e serve
driver: it prefeeds a prompt through decode steps (cache warm-up), then
generates greedily, reporting tokens/s and the quantisation mode in use.
LM archs run the REDUCED config on CPU (--preset full for the real one).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_CONFIGS, reduce_config
from repro.core.quant import QuantConfig
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--quant", default=None, choices=[None, "w8", "w8a8"])
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    base = ARCH_CONFIGS[args.arch]
    cfg = base if args.preset == "full" else reduce_config(base)
    if args.quant or args.kv_int8:
        cfg = cfg.replace(quant=QuantConfig(args.quant or "w8",
                                            quantize_kv=args.kv_int8))

    key = jax.random.key(args.seed)
    params, axes = T.init_model(cfg, key)
    if cfg.quant.enabled:
        params, axes = T.quantize_model_params(params, axes, cfg)
        print(f"[serve] weights quantised: mode={cfg.quant.mode} "
              f"int8-KV={cfg.quant.quantize_kv}")

    b = args.batch
    cache = T.init_cache(cfg, b, args.max_seq)

    @jax.jit
    def decode(params, cache, tokens, pos):
        batch = {"tokens": tokens, "cache_pos": pos}
        if cfg.attn and cfg.attn.mrope_sections:
            batch["position_ids"] = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        if not cfg.embed_inputs:
            # frontend stub: embed token ids through the embedding table
            emb = params["embed"]
            e = (emb["q"][tokens].astype(jnp.bfloat16) * emb["s"].astype(jnp.bfloat16)
                 ) if isinstance(emb, dict) else emb[tokens].astype(jnp.bfloat16)
            batch = {"inputs_embeds": e, "cache_pos": pos}
            if cfg.attn and cfg.attn.mrope_sections:
                batch["position_ids"] = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        logits, cache = T.forward_decode(params, cache, batch, cfg)
        return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), cache

    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(0, cfg.vocab_size, (b, args.prompt_len)).astype(np.int32)

    # prefill via decode steps (cache warm-up)
    tok = jnp.asarray(prompt[:, :1])
    for t in range(args.prompt_len):
        tok, cache = decode(params, cache, jnp.asarray(prompt[:, t:t + 1]),
                            jnp.asarray(t, jnp.int32))
    jax.block_until_ready(tok)

    out = []
    t0 = time.perf_counter()
    for t in range(args.prompt_len, args.prompt_len + args.gen):
        tok, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32))
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = b * args.gen
    print(f"[serve] {args.arch} ({cfg.n_layers}L d={cfg.d_model}) generated "
          f"{toks} tokens in {dt:.2f}s = {toks / dt:.1f} tok/s "
          f"(batch={b}, CPU host)")
    gen = np.concatenate(out, 1)
    print("[serve] sample:", gen[0][:12], "...")
    return gen


if __name__ == "__main__":
    main()
