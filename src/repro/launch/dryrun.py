import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^^^ MUST run before any jax import: jax locks the device count on first
# initialisation.  Everything below (including `from repro...`) may import
# jax freely.
#
# Multi-pod dry-run: AOT lower + compile every (architecture x input-shape x
# mesh) cell against the production meshes, print memory_analysis (fits) and
# cost_analysis (FLOPs/bytes for §Roofline), parse collective bytes from the
# optimized HLO, and append everything to a JSON results file.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.configs import ARCH_CONFIGS, ASSIGNED_ARCHS, SHAPES
from repro.configs.base import (ModelConfig, ShapeSpec, input_specs,
                                shape_applicable)
from repro.core import energy
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.modules import unroll_mode
from repro.sharding.partition import (logical_to_spec, param_shardings,
                                      resolve_rules, rules_context)
from repro.training.step import (TrainPlan, init_train_state,
                                 make_decode_step, make_train_step)


def _abstract_model(cfg: ModelConfig, mesh, dtype=None, quantize=False):
    """(ShapeDtypeStructs-with-sharding, axes) for the model params —
    no allocation (init traced under eval_shape).  quantize=True builds the
    W8 serve tree ({"q": int8, "s": scale} leaves)."""
    captured = {}

    def f(k):
        p, a = T.init_model(cfg, k)
        if quantize:
            p, a = T.quantize_model_params(p, a, cfg)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.key(0))
    axes = captured["axes"]
    shardings = param_shardings(axes, mesh, cfg.sharding_overrides, shapes)

    def mk(s, sh):
        dt = dtype if (dtype is not None and
                       jnp.issubdtype(s.dtype, jnp.floating)) else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)

    structs = jax.tree.map(mk, shapes, shardings)
    return structs, axes, shardings


def _batch_structs(cfg: ModelConfig, shape: ShapeSpec, mesh, overrides=None):
    rules = resolve_rules(mesh, cfg.sharding_overrides
                          if overrides is None else overrides)
    out = {}
    for name, (shp, dt, laxes) in input_specs(cfg, shape).items():
        sh = NamedSharding(mesh, logical_to_spec(laxes, rules, shp, mesh))
        out[name] = jax.ShapeDtypeStruct(shp, dt, sharding=sh)
    return out


def _microbatches_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Grad-accum count: keep per-device per-microbatch tokens bounded so
    activations (even with full remat the residual-stream checkpoints scale
    with d_model * layers) fit HBM — larger models get more microbatches."""
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    per_dev_batch = max(1, shape.global_batch // dp)
    target_tokens = 16384 if cfg.d_model < 4096 else 8192
    nm = max(shape.microbatches,
             (per_dev_batch * shape.seq_len + target_tokens - 1) // target_tokens)
    nm = min(nm, per_dev_batch)
    while per_dev_batch % nm and nm > 1:
        nm -= 1
    return nm


def serve_overrides(cfg):
    """(§Perf iteration 4 — REFUTED, kept for the record/tests.)
    TP-only serve weights: replicating the FSDP ('embed'->data) dim was
    hypothesised to remove serve-time gathers; measurement showed the
    d-sharded layout is beneficial 2D weight-parallelism at decode, and the
    16x weight replication pushes mixtral/phi3.5 prefill over HBM.  Serving
    therefore keeps the training sharding (see EXPERIMENTS.md §Perf)."""
    return tuple(cfg.sharding_overrides) + (("embed", None),)


def _lower_step(cfg, shape, mesh, quant_serve):
    """Build step fn + abstract args, return jax.jit(...).lower(...)."""
    extra = {}
    if shape.kind == "train":
        nm = _microbatches_for(cfg, shape, mesh)
        plan = TrainPlan(microbatches=nm)
        params, axes, shardings = _abstract_model(cfg, mesh)
        state_struct = jax.eval_shape(
            lambda p: init_train_state(p, plan), params)
        state_shard = {
            "params": shardings,
            "opt": {"mu": shardings, "nu": shardings,
                    "count": NamedSharding(mesh, P())},
            "step": NamedSharding(mesh, P()),
        }
        state_struct = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            state_struct, state_shard)
        batch = _batch_structs(cfg, shape, mesh)
        step = make_train_step(cfg, plan)
        lowered = jax.jit(step, donate_argnums=0).lower(state_struct, batch)
        extra["microbatches"] = nm
    elif shape.kind == "prefill":
        params, axes, shardings = _abstract_model(cfg, mesh, jnp.bfloat16)
        batch = _batch_structs(cfg, shape, mesh)
        from repro.training.step import make_prefill_step
        lowered = jax.jit(make_prefill_step(cfg)).lower(params, batch)
    else:  # decode
        # NOTE (§Perf iteration 4, REFUTED then revised): dropping the FSDP
        # 'embed'->data rule for decode was hypothesised to kill per-step
        # weight gathers; measurement showed the d-sharded weights actually
        # act as beneficial 2D weight-parallelism at decode (weights stay
        # put, tiny activation reduces move) — replication regressed 9/11
        # decode cells up to 7x.  Decode therefore KEEPS the training
        # sharding; prefill (weight reads amortised over 32k tokens) keeps
        # the TP-only override.
        ov = tuple(cfg.sharding_overrides)
        if quant_serve:  # C1 at LM scale: int8 weights + int8 KV cache
            from repro.core.quant import QuantConfig
            scfg = cfg.replace(quant=QuantConfig("w8", quantize_kv=True))
        else:
            scfg = cfg
        params, axes, shardings = _abstract_model(
            scfg, mesh, jnp.bfloat16, quantize=scfg.quant.enabled)
        rules = resolve_rules(mesh, ov)
        cache_struct = {
            k: jax.ShapeDtypeStruct(
                shp, dt, sharding=NamedSharding(
                    mesh, logical_to_spec(laxes, rules, shp, mesh)))
            for k, (shp, dt, laxes) in
            T.cache_spec(scfg, shape.global_batch, shape.seq_len).items()}
        batch = _batch_structs(scfg, shape, mesh, ov)
        step = make_decode_step(scfg)
        lowered = jax.jit(step, donate_argnums=1).lower(
            params, cache_struct, batch)
    return lowered, extra


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant_serve: bool = False, skip_cost_pass: bool = False) -> dict:
    cfg: ModelConfig = ARCH_CONFIGS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
           "chips": chips, "kind": shape.kind}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    t0 = time.time()
    with rules_context(mesh, cfg.sharding_overrides):
        lowered, extra = _lower_step(cfg, shape, mesh, quant_serve)
        rec.update(extra)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # Cost-exact pass: HloCostAnalysis counts while bodies ONCE, so FLOP
    # accounting re-lowers (no backend compile) with every model loop
    # unrolled.  The UNCOMPILED module is the GLOBAL program (SPMD
    # partitioning happens at compile), so per-device = global / chips.
    def _ca(obj):
        # jax < 0.5 returns a per-device list of dicts; newer jax a dict.
        out = obj.cost_analysis() or {}
        return out[0] if isinstance(out, (list, tuple)) else out

    if skip_cost_pass:
        ca = _ca(compiled)
        rec["flops_per_device"] = float(ca.get("flops", 0.0))
    else:
        t1 = time.time()
        with rules_context(mesh, cfg.sharding_overrides), unroll_mode():
            lowered_cost, _ = _lower_step(cfg, shape, mesh, quant_serve)
        ca = _ca(lowered_cost)
        rec["cost_lower_s"] = round(time.time() - t1, 1)
        ca_scan = _ca(compiled)
        rec["flops_per_device_scanned_hlo"] = float(ca_scan.get("flops", 0.0))
        rec["flops_global"] = float(ca.get("flops", 0.0))
        rec["flops_per_device"] = rec["flops_global"] / chips
    # 'bytes accessed' on unoptimised HLO counts every op's operands (no
    # fusion) — recorded for reference only; the roofline memory term uses
    # the traffic estimator below (EXPERIMENTS.md §Roofline documents this).
    rec["bytes_unfused_global"] = float(ca.get("bytes accessed", 0.0))
    rec["transcendentals"] = float(ca.get("transcendentals", 0.0))
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 2**30,
            "output_gb": ma.output_size_in_bytes / 2**30,
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "alias_gb": ma.alias_size_in_bytes / 2**30,
            "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                        ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    colls = collective_bytes(compiled.as_text())
    rec["collectives"] = colls

    # HBM-traffic estimator (per device), from the compiled memory_analysis:
    #   train:   read+write the state (params fp32 + adam moments) once,
    #            re-read bf16 weights fwd+bwd per microbatch (FSDP-gathered
    #            copies land in HBM), stream activations (~temp) twice per
    #            microbatch.
    #   prefill: read args once + 2x transient activations.
    #   decode:  read weights + KV cache once (the classic decode bound)
    #            + 2x transients.
    mem = rec.get("memory", {})
    arg_b = mem.get("argument_gb", 0.0) * 2**30
    tmp_b = mem.get("temp_gb", 0.0) * 2**30
    n_params = T.num_params(cfg)
    if shape.kind == "train":
        nm = rec.get("microbatches", 1)
        wb = 2 * n_params / chips          # bf16 weight copy per device
        hbm = 2 * arg_b + nm * 2 * wb + nm * 2 * tmp_b
    else:
        hbm = arg_b + 2 * tmp_b
    rec["hbm_bytes_per_device_est"] = hbm

    terms = energy.roofline_terms(rec["flops_per_device"], hbm,
                                  colls.get("total", 0.0))
    rec["roofline"] = terms.asdict()

    # MODEL_FLOPS (useful-compute ratio)
    n = T.num_params(cfg)
    n_act = T.num_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = energy.model_flops_train(n, tokens, n_act)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = energy.model_flops_decode(n, tokens, n_act) / 2 * 2  # fwd only
    else:
        mf = energy.model_flops_decode(n, shape.global_batch, n_act)
    rec["model_flops_total"] = mf
    hlo_total = rec["flops_per_device"] * chips
    rec["useful_flops_ratio"] = (mf / hlo_total) if hlo_total else None
    rec["params"] = n
    rec["active_params"] = n_act
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant-serve", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("quant", False))
            for r in results}

    for a, s, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        key = (a, s, mesh_name, args.quant_serve)
        if key in done:
            print(f"[skip-cached] {key}", flush=True)
            continue
        print(f"[run] {a} x {s} x {mesh_name}", flush=True)
        try:
            rec = run_cell(a, s, mp, args.quant_serve)
        except Exception as e:
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        rec["quant"] = args.quant_serve
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  -> {rec.get('status')} "
              f"compile={rec.get('compile_s', '-')}s "
              f"flops/dev={rec.get('flops_per_device', 0):.3g} "
              f"bound={rec.get('roofline', {}).get('bound', '-')}", flush=True)

    bad = [r for r in results if r.get("status") == "error"]
    print(f"\n{len(results)} cells recorded, {len(bad)} errors")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
