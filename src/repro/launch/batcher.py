"""Batched request serving — wave scheduling over the decode step.

The paper's deployment scenario is real-time batched inference (§6:
32 873 samples/s).  At LM scale the equivalent substrate is a request
batcher: requests queue up, are assembled into fixed-size WAVES (padding
with inactive slots), and each wave decodes in lockstep against one shared
cache allocation.  Finished sequences (EOS or length) retire at wave
boundaries; per-slot retirement within a wave masks the slot's output.

(Continuous batching — per-slot cache positions — needs per-row scatter
cache updates; wave scheduling is the static-shape-friendly form and what
the dry-run's decode cells model: every active slot advances together.)

Two modes share the queue/wave machinery:

  * LM decode (default): ``WaveBatcher(params, cfg, ...)`` — autoregressive
    lockstep decoding as above.
  * LSTM accelerator: ``WaveBatcher.for_accelerator(session, batch_size)``
    — requests are (T, M) windows; waves run through the streaming
    subsystem (``repro.serving.serve_windows``, the paper's int8
    datapath), one static batch shape, results are per-window predictions.
    This mode is a thin compat wrapper: for named streams with
    cross-window state carry use ``repro.serving.StreamServer`` directly.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # LM: (prompt_len,) int32; LSTM: (T, M) float
    max_new: int
    eos_id: Optional[int] = None
    output: Any = dataclasses.field(default_factory=list)
    done: bool = False


class WaveBatcher:
    def __init__(self, params, cfg: ModelConfig, batch_size: int = 8,
                 max_seq: int = 0, *, _lstm_mode: bool = False):
        self.params = params
        self.cfg = cfg
        self.bs = batch_size
        self.max_seq = max_seq
        self.queue: Deque[Request] = deque()
        self._next_id = 0
        self.accelerator = None     # set by for_accelerator()

        if _lstm_mode:
            return  # LSTM-accelerator mode: no decode graph
        if cfg is None:
            raise TypeError("LM mode needs a ModelConfig; for the LSTM-"
                            "accelerator mode use WaveBatcher.for_accelerator")
        if max_seq <= 0:
            raise ValueError("LM mode needs max_seq > 0 (the cache budget)")

        def decode(params, cache, tokens, pos):
            batch = {"tokens": tokens, "cache_pos": pos}
            if cfg.attn and cfg.attn.mrope_sections:
                batch["position_ids"] = jnp.broadcast_to(
                    pos, (3, batch_size, 1)).astype(jnp.int32)
            logits, cache = T.forward_decode(params, cache, batch, cfg)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        self._decode = jax.jit(decode)

    @classmethod
    def for_accelerator(cls, session, batch_size: int = 256,
                        path: str = "int") -> "WaveBatcher":
        """LSTM-accelerator mode over a built ``repro.Accelerator`` session.

        Requests are (T, M) float windows submitted with
        ``submit_window``; ``run()`` drains them in fixed-size waves
        through the streaming subsystem (``repro.serving.serve_windows``)
        and returns {rid: (P,) prediction}."""
        b = cls(None, None, batch_size=batch_size, _lstm_mode=True)
        b.accelerator = session
        b._serve_path = path
        return b

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new, eos_id))
        return rid

    def submit_window(self, window: np.ndarray) -> int:
        """LSTM mode: enqueue one (T, M) float window."""
        assert self.accelerator is not None, "use for_accelerator() first"
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(window, np.float32),
                                  max_new=0))
        return rid

    def _run_wave(self, wave: List[Request]) -> None:
        bs = self.bs
        plen = max(len(r.prompt) for r in wave)
        total = plen + max(r.max_new for r in wave)
        assert total <= self.max_seq, "request exceeds cache budget"
        cache = T.init_cache(self.cfg, bs, self.max_seq)

        # left-align prompts, pad with token 0 (masked by per-request plen)
        toks = np.zeros((bs, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, :len(r.prompt)] = r.prompt
        cur = jnp.asarray(toks[:, :1])
        for t in range(total - 1):
            nxt, cache = self._decode(self.params, cache,
                                      jnp.asarray(cur),
                                      jnp.asarray(t, jnp.int32))
            nxt_np = np.asarray(nxt)
            if t + 1 < plen:
                cur = toks[:, t + 1:t + 2]   # teacher-force the prompt
                continue
            cur = nxt_np[:, None]
            for i, r in enumerate(wave):
                if r.done or t + 1 < len(r.prompt):
                    continue
                tok = int(nxt_np[i])
                r.output.append(tok)
                if (r.eos_id is not None and tok == r.eos_id) or \
                        len(r.output) >= r.max_new:
                    r.done = True
            if all(r.done for r in wave):
                break
        for r in wave:
            r.done = True

    def run(self) -> Dict[int, Any]:
        """Drain the queue.

        LM mode: {rid: generated tokens}.  LSTM-accelerator mode:
        {rid: (P,) float prediction} via ``repro.serving.serve_windows``."""
        if self.accelerator is not None:
            return self._run_lstm()
        results: Dict[int, List[int]] = {}
        while self.queue:
            wave = []
            while self.queue and len(wave) < self.bs:
                wave.append(self.queue.popleft())
            while len(wave) < self.bs:   # pad with a dummy slot
                wave.append(Request(-1, np.zeros(1, np.int32), 1))
            self._run_wave(wave)
            for r in wave:
                if r.rid >= 0:
                    results[r.rid] = r.output
        return results

    def _run_lstm(self) -> Dict[int, np.ndarray]:
        from repro.serving import serve_windows
        reqs: List[Request] = []
        while self.queue:
            reqs.append(self.queue.popleft())
        stream = (r.prompt for r in reqs)
        preds = serve_windows(self.accelerator, stream, batch=self.bs,
                              path=self._serve_path)
        results: Dict[int, np.ndarray] = {}
        for r, y in zip(reqs, preds):
            r.output = y
            r.done = True
            results[r.rid] = y
        return results
