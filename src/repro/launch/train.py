"""Training launcher.

  python -m repro.launch.train --arch lstm-pems --steps 400        # the paper
  python -m repro.launch.train --arch qwen1.5-0.5b --preset tiny --steps 50
  python -m repro.launch.train --arch gemma2-2b --preset tiny --quant w8a8 --hard-acts

LM archs run their REDUCED config by default on this CPU container
(--preset full uses the real config — sized for the TPU meshes, see
launch/dryrun.py).  Fault tolerance: checkpoints land in --ckpt-dir; rerun
the same command to resume; SIGTERM checkpoints-and-exits.
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_CONFIGS, reduce_config
from repro.core.qlstm import QLSTMConfig
from repro.core.quant import QuantConfig
from repro.data.lm_data import SyntheticLM
from repro.data.timeseries import pems_like_dataset
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.sharding.partition import param_shardings, rules_context
from repro.training.optimizer import OptConfig
from repro.training.step import TrainPlan, init_train_state, make_train_step
from repro.training.train_loop import LoopConfig, Trainer


def train_lstm(args):
    """The paper's model: QAT on PeMS-like data (§6.1), through the session
    API: build -> train_qat -> quantize -> infer (docs/API.md)."""
    import repro
    cfg: QLSTMConfig = ARCH_CONFIGS["lstm-pems"]
    data = pems_like_dataset(seq_len=cfg.seq_len, seed=0)

    acc = repro.build(cfg, seed=args.seed)
    acc.train_qat(data, steps=args.steps, batch=args.batch,
                  lr=args.lr or 3e-3, seed=args.seed,
                  ckpt_dir=args.ckpt_dir)
    acc.quantize()

    # Evaluation: float vs QAT vs the bit-exact integer (accelerator) path.
    xte, yte = map(jnp.asarray, data["test"])
    for name, path in [("float", "float"), ("qat", "qat"),
                       ("int8-kernel", "int")]:
        mse = float(jnp.mean((acc.infer(xte, path=path) - yte) ** 2))
        print(f"  test MSE [{name:12s}] = {mse:.5f}")
    return acc.train_summary


def train_lm(args):
    base = ARCH_CONFIGS[args.arch]
    cfg = base if args.preset == "full" else reduce_config(base)
    if args.preset == "100m":
        cfg = base.replace(n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
                           head_dim=64, d_ff=2048, vocab_size=32768,
                           remat="none")
    if args.quant:
        cfg = cfg.replace(quant=QuantConfig(args.quant))
    if args.hard_acts:
        cfg = cfg.replace(hard_acts=True)

    mesh = make_host_mesh()
    with rules_context(mesh, cfg.sharding_overrides):
        params, axes = T.init_model(cfg, jax.random.key(args.seed))
        plan = TrainPlan(opt=OptConfig(lr=args.lr or 3e-4,
                                       warmup_steps=10,
                                       total_steps=args.steps),
                         microbatches=args.microbatches,
                         grad_compress=args.grad_compress)
        state = init_train_state(params, plan)
        step_fn = jax.jit(make_train_step(cfg, plan), donate_argnums=0)

        src = SyntheticLM(cfg.vocab_size, seed=args.seed)

        def batch_fn(step):
            b = src.batch(step, args.batch, args.seq)
            out = {"tokens": jnp.asarray(b["tokens"]),
                   "labels": jnp.asarray(b["labels"])}
            if cfg.attn and cfg.attn.mrope_sections:
                pos = jnp.broadcast_to(jnp.arange(args.seq),
                                       (args.batch, args.seq))
                out["position_ids"] = jnp.stack([pos] * 3)
            if not cfg.embed_inputs:
                rng = np.random.default_rng((args.seed, step))
                out["inputs_embeds"] = jnp.asarray(
                    rng.normal(0, 1, (args.batch, args.seq, cfg.d_model))
                    .astype(np.float32)).astype(jnp.bfloat16)
                del out["tokens"]
            return out

        trainer = Trainer(step_fn, state, batch_fn,
                          LoopConfig(total_steps=args.steps,
                                     ckpt_dir=args.ckpt_dir,
                                     ckpt_every=args.ckpt_every,
                                     log_every=10))
        trainer.maybe_resume()
        return trainer.run()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-pems",
                    choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--quant", default=None, choices=[None, "w8", "w8a8"])
    ap.add_argument("--hard-acts", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)
    if args.arch == "lstm-pems":
        return train_lstm(args)
    return train_lm(args)


if __name__ == "__main__":
    main()
