"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes, devices=None):
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: pod = pure DP across pods; data = FSDP; model = TP(+EP)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return _make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def serving_devices(n: int, devices=None, *, oversubscribe: bool = True):
    """The device list for an ``n``-replica serving cluster.

    ``devices`` pins an explicit list (must hold at least ``n``; the first
    ``n`` are used — the caller controls placement).  With the default
    ``devices=None`` the visible ``jax.devices()`` are dealt out
    round-robin; when ``n`` exceeds the device count, ``oversubscribe``
    (default, the CPU-test posture — also how the CI cluster smoke runs
    before XLA_FLAGS forces extra host devices) reuses devices cyclically,
    while ``oversubscribe=False`` raises — the production posture, where a
    "replica" that silently shares a device is a capacity-planning bug.
    """
    if n < 1:
        raise ValueError(f"need n >= 1 replicas, got {n}")
    if devices is not None:
        devices = list(devices)
        if len(devices) < n:
            raise ValueError(
                f"need {n} devices for {n} replicas, got {len(devices)} "
                f"explicit devices")
        return devices[:n]
    avail = jax.devices()
    if len(avail) < n and not oversubscribe:
        raise RuntimeError(
            f"need {n} devices for {n} replicas, have {len(avail)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N for CPU "
            "testing, or pass oversubscribe=True to share devices")
    return [avail[i % len(avail)] for i in range(n)]


def make_serving_mesh(n: int, devices=None, *,
                      oversubscribe: bool = True) -> Mesh:
    """1-D ``("replica",)`` mesh over the serving cluster's devices.

    Each coordinate along the ``replica`` axis is one serving replica's
    device (``serving_devices`` picks them); per-replica parameter
    placement then falls out of ``sharding.partition.replica_shardings``.
    Requires ``n`` DISTINCT devices — a jax mesh cannot repeat a device,
    so the oversubscribed CPU-test posture skips the mesh and pins each
    replica directly (``sharding.partition.pin_to_device``)."""
    devs = serving_devices(n, devices, oversubscribe=oversubscribe)
    if len(set(d.id for d in devs)) != len(devs):
        raise RuntimeError(
            f"make_serving_mesh needs {n} distinct devices (a mesh cannot "
            "repeat one); oversubscribed replicas are pinned directly via "
            "sharding.partition.pin_to_device instead")
    return _make_mesh((n,), ("replica",), devices=devs)
