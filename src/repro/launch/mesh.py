"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes, devices=None):
    if AxisType is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: pod = pure DP across pods; data = FSDP; model = TP(+EP)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return _make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))
