"""Host data pipeline: step-keyed, deterministic, prefetching.

Contract: ``source(step) -> dict[str, np.ndarray]`` is a pure function of
the step index, so a job restarted from a step-K checkpoint replays the
exact same batches — bit-reproducible training across failures/elastic
resizes.  A background thread keeps ``prefetch`` batches ahead; arrays are
device_put with the batch sharding (on real multi-host TPU the same code
feeds each process its addressable shard via
``jax.make_array_from_process_local_data``).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding


class Pipeline:
    def __init__(self, source: Callable[[int], Dict[str, np.ndarray]],
                 shardings: Optional[Dict[str, NamedSharding]] = None,
                 start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.shardings = shardings or {}
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put_device(self, batch: Dict[str, np.ndarray]):
        out = {}
        for k, v in batch.items():
            sh = self.shardings.get(k)
            out[k] = jax.device_put(v, sh) if sh is not None else jax.device_put(v)
        return out

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                batch = self.source(step)
            except Exception as e:  # surface in consumer
                self._q.put(e)
                return
            self._q.put((step, self._put_device(batch)))
            step += 1

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        step, batch = item
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
