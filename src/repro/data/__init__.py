from repro.data.timeseries import pems_like_dataset  # noqa: F401
from repro.data.lm_data import SyntheticLM  # noqa: F401
from repro.data.pipeline import Pipeline  # noqa: F401
