"""Synthetic LM token stream: Zipf-distributed vocabulary with first-order
Markov structure (so cross-entropy has real headroom below the unigram
entropy and training curves are meaningful), generated deterministically
from (seed, step) — the restart-reproducibility contract the checkpoint
tests rely on (DESIGN.md §5 fault tolerance).
"""

from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Step-keyed batch source: batch(step) is a pure function."""

    def __init__(self, vocab_size: int, seed: int = 0, n_states: int = 64):
        self.vocab = vocab_size
        self.seed = seed
        rng = np.random.default_rng(seed)
        # Hidden-state Markov chain; each state emits a distinct Zipf slice.
        self.n_states = n_states
        self.trans = rng.dirichlet(np.ones(n_states) * 0.3, n_states)
        self.state_shift = rng.integers(0, vocab_size, n_states)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self.zipf = p / p.sum()

    def batch(self, step: int, batch_size: int, seq_len: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        states = rng.integers(0, self.n_states, batch_size)
        toks = np.empty((batch_size, seq_len + 1), np.int32)
        base = rng.choice(self.vocab, (batch_size, seq_len + 1), p=self.zipf)
        for t in range(seq_len + 1):
            toks[:, t] = (base[:, t] + self.state_shift[states]) % self.vocab
            nxt = rng.random(batch_size)
            cum = np.cumsum(self.trans[states], axis=1)
            states = (cum < nxt[:, None]).sum(1).clip(0, self.n_states - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
