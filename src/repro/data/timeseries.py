"""Synthetic PeMS-4W-like traffic-speed data (the paper's dataset is a
zenodo download — offline here, so we synthesise a statistically similar
stream: daily periodicity, AM/PM rush-hour congestion, weekly structure,
noise, and occasional incident drops), plus the paper's windowing
(length-N sliding windows, single-step-ahead target, §3).

Deterministic in (seed); normalised to [0, 1] like [15] so the (4,8)
fixed-point input range is exercised the same way.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

SAMPLES_PER_HOUR = 12  # 5-minute bins, like PeMS


def generate_speeds(n_days: int = 28, seed: int = 0,
                    free_flow_mph: float = 65.0) -> np.ndarray:
    """1-D speed series, 5-min resolution."""
    rng = np.random.default_rng(seed)
    n = n_days * 24 * SAMPLES_PER_HOUR
    t_hour = (np.arange(n) / SAMPLES_PER_HOUR) % 24.0
    day = (np.arange(n) // (24 * SAMPLES_PER_HOUR)) % 7

    speed = np.full(n, free_flow_mph, np.float64)

    def rush(center, width, depth):
        return depth * np.exp(-0.5 * ((t_hour - center) / width) ** 2)

    weekday = (day < 5).astype(np.float64)
    speed -= weekday * (rush(8.0, 1.2, 28.0) + rush(17.5, 1.5, 32.0))
    speed -= (1 - weekday) * rush(14.0, 2.5, 10.0)   # weekend midday
    # slow seasonal drift + AR(1) noise
    speed += 2.0 * np.sin(2 * np.pi * np.arange(n) / (7 * 24 * SAMPLES_PER_HOUR))
    ar = np.zeros(n)
    eps = rng.normal(0, 1.3, n)
    for i in range(1, n):
        ar[i] = 0.9 * ar[i - 1] + eps[i]
    speed += ar
    # incidents: sudden capacity drops with exponential recovery
    n_inc = max(1, n_days // 2)
    for s in rng.integers(0, n - 40, n_inc):
        dur = int(rng.integers(6, 36))
        drop = rng.uniform(15, 35)
        speed[s:s + dur] -= drop * np.exp(-np.arange(dur) / (dur / 3))
    return np.clip(speed, 3.0, 75.0)


def normalize(x: np.ndarray) -> Tuple[np.ndarray, Dict[str, float]]:
    lo, hi = float(x.min()), float(x.max())
    return (x - lo) / (hi - lo + 1e-9), {"lo": lo, "hi": hi}


def make_windows(series: np.ndarray, seq_len: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Sliding windows: X (N, seq_len, 1), y (N, 1) = next value (§3)."""
    n = len(series) - seq_len
    idx = np.arange(n)[:, None] + np.arange(seq_len)[None, :]
    x = series[idx][..., None].astype(np.float32)
    y = series[seq_len:][:, None].astype(np.float32)
    return x, y


def pems_like_dataset(seq_len: int = 6, n_days: int = 28, seed: int = 0,
                      test_frac: float = 0.2):
    """Returns dict(train=(x, y), test=(x, y), norm=meta)."""
    speeds = generate_speeds(n_days, seed)
    norm, meta = normalize(speeds)
    x, y = make_windows(norm, seq_len)
    n_test = int(len(x) * test_frac)
    return {
        "train": (x[:-n_test], y[:-n_test]),
        "test": (x[-n_test:], y[-n_test:]),
        "norm": meta,
    }
