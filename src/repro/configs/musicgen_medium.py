"""MusicGen-medium decoder backbone over EnCodec tokens; audio frontend
(EnCodec + codebook interleaving) stubbed — input_specs supplies frame
embeddings. [arXiv:2306.05284]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    norm="layernorm", act="gelu", mlp_type="mlp",
    attn=AttnConfig(sinusoidal=True),
    embed_inputs=False,
    notes="MHA (kv=24), sinusoidal positions, LayerNorm, plain GELU MLP. "
          "24 heads over 16-way TP relies on GSPMD padding.",
)
