"""CodeQwen1.5-7B (qwen1.5 arch, MHA). [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    act="silu", mlp_type="swiglu",
    attn=AttnConfig(rope_theta=1e6, qkv_bias=True),
)
