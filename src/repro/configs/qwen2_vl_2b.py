"""Qwen2-VL-2B — LM backbone with M-RoPE; vision frontend is a stub
(input_specs supplies precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    act="silu", mlp_type="swiglu", tie_embeddings=True,
    attn=AttnConfig(rope_theta=1e6, mrope_sections=(16, 24, 24), qkv_bias=True),
    embed_inputs=False,
    notes="M-RoPE (temporal/height/width rotary sections); dynamic-resolution "
          "ViT frontend stubbed per task spec. 12 heads over 16-way TP relies "
          "on GSPMD padding (DESIGN.md §5).",
)
