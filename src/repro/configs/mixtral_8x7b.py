"""Mixtral 8x7B: 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    act="silu", mlp_type="swiglu",
    attn=AttnConfig(rope_theta=1e6, window=4096),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336),
    notes="SWA bounds the KV cache to 4096 => long_500k decode runs with a "
          "ring-buffer cache (DESIGN.md §4). TP-MoE (8 experts !% 16 shards: "
          "experts replicated, expert-ff TP-sharded).",
)
