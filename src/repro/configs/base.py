"""Model / shape configuration schema.

One ``ModelConfig`` describes any of the assigned architectures; one
``ShapeSpec`` describes one input-shape cell.  ``input_specs`` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, NO_QUANT


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None  # qwen2-vl M-RoPE
    qkv_bias: bool = False                            # qwen1.5 family
    window: Optional[int] = None                      # uniform SWA (mixtral)
    alt_window: Optional[int] = None                  # gemma2: even layers local
    attn_softcap: Optional[float] = None              # gemma2: 50.0
    query_scale: Optional[float] = None               # gemma2-27b override
    sinusoidal: bool = False                          # musicgen positions


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    expert_parallel: bool = False  # EP over the model axis (phi3.5: 16e/16)


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:  # RecurrentGemma / Griffin
    lru_width: int
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    c_exponent: float = 8.0


@dataclasses.dataclass(frozen=True)
class RWKVConfig:  # RWKV-6 "Finch"
    head_dim: int = 64
    lora_r: int = 64     # ddlerp LoRA rank
    lora_w: int = 128    # decay LoRA rank
    chunk: int = 128     # chunked-wkv chunk length


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm | lstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    norm: str = "rmsnorm"        # rmsnorm | layernorm | gemma_rmsnorm
    post_norms: bool = False     # gemma2 pre+post sublayer norms
    act: str = "silu"            # MLP activation
    mlp_type: str = "swiglu"     # swiglu | geglu | mlp
    tie_embeddings: bool = False
    final_softcap: Optional[float] = None
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    rwkv: Optional[RWKVConfig] = None
    embed_inputs: bool = True    # False => input_specs() provides embeddings
    # --- paper technique knobs (C1/C2/C4 as first-class features) ---
    quant: QuantConfig = NO_QUANT
    hard_acts: bool = False      # C2: swap soft nonlinearities for hard ones
    # --- execution ---
    dtype: str = "bfloat16"
    remat: str = "full"          # full | none
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()
    # long-context: layers are sub-quadratic iff every attn layer is windowed
    notes: str = ""

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def uniform_window(self) -> Optional[int]:
        return self.attn.window if (self.attn and self.attn.window) else None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind (attention/recurrent), resolved from family."""
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        if self.family == "hybrid":
            pat = self.recurrent.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return ("attn",) * self.n_layers

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Effective attention window per attention layer (SWA / gemma2
        alternation).  A window >= seq_len means global."""
        out = []
        a = self.attn
        for i, kind in enumerate(self.layer_kinds()):
            if kind != "attn":
                continue
            if a and a.window:
                out.append(min(a.window, seq_len))
            elif a and a.alt_window and i % 2 == 0:
                out.append(min(a.alt_window, seq_len))  # even layers local
            else:
                out.append(seq_len)
        return tuple(out)

    def subquadratic(self) -> bool:
        """True iff decoding at very long context needs only bounded state."""
        kinds = self.layer_kinds()
        if all(k in ("rwkv", "rec") for k in kinds):
            return True
        a = self.attn
        win = a.window or a.alt_window if a else None
        # every attention layer must be windowed
        if self.family == "hybrid":
            return win is not None
        return a is not None and a.window is not None


# ---------------------------------------------------------------------------
# ShapeSpec — the assigned input-shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1  # grad-accum microbatches (train only)


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256, microbatches=4),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a cell runs (DESIGN.md §4 long_500k rule)."""
    if shape.name == "long_500k" and not cfg.subquadratic():
        return False, ("skip: full-attention arch at 500k context is "
                       "quadratic / unbounded-KV (DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins (+ logical shardings)
# ---------------------------------------------------------------------------

def batch_axes():
    return ("batch", None)  # (batch, seq)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Tuple]:
    """Returns {name: (shape, dtype, logical_axes)} for every model input of
    the given cell.  launch/dryrun.py turns these into sharded
    ShapeDtypeStructs; tests/examples allocate real arrays from them."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    specs = {}
    if shape.kind == "train":
        if cfg.embed_inputs:
            specs["tokens"] = ((b, s), jnp.int32, ("batch", None))
        else:  # vlm/audio: the frontend stub supplies embeddings
            specs["inputs_embeds"] = ((b, s, d), jnp.bfloat16, ("batch", None, None))
        specs["labels"] = ((b, s), jnp.int32, ("batch", None))
        if cfg.attn and cfg.attn.mrope_sections:
            specs["position_ids"] = ((3, b, s), jnp.int32, (None, "batch", None))
    elif shape.kind == "prefill":
        if cfg.embed_inputs:
            specs["tokens"] = ((b, s), jnp.int32, ("batch", None))
        else:
            specs["inputs_embeds"] = ((b, s, d), jnp.bfloat16, ("batch", None, None))
        if cfg.attn and cfg.attn.mrope_sections:
            specs["position_ids"] = ((3, b, s), jnp.int32, (None, "batch", None))
    else:  # decode: one new token against a seq_len-deep cache
        if cfg.embed_inputs:
            specs["tokens"] = ((b, 1), jnp.int32, ("batch", None))
        else:
            specs["inputs_embeds"] = ((b, 1, d), jnp.bfloat16, ("batch", None, None))
        specs["cache_pos"] = ((), jnp.int32, ())
        if cfg.attn and cfg.attn.mrope_sections:
            specs["position_ids"] = ((3, b, 1), jnp.int32, (None, "batch", None))
    return specs
