"""RWKV-6 'Finch' 7B: attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    norm="layernorm",
    rwkv=RWKVConfig(head_dim=64, lora_r=64, lora_w=128, chunk=128),
    notes="Chunked block-parallel WKV for train/prefill (C3 philosophy: keep "
          "the MXU busy); sequential O(1)-state recurrence for decode. "
          "long_500k runs (state-based).",
)
