"""Gemma2-27B. [arXiv:2408.00118]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    norm="gemma_rmsnorm", post_norms=True, act="gelu_tanh", mlp_type="geglu",
    tie_embeddings=True, final_softcap=30.0,
    attn=AttnConfig(rope_theta=10000.0, alt_window=4096, attn_softcap=50.0,
                    query_scale=(4608 / 32) ** -0.5),
    notes="query_pre_attn_scalar = d_model/n_heads = 144 (27B-specific).",
)
