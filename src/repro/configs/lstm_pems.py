"""The paper's own model: 1 LSTM cell (hidden 20) + dense, PeMS-4W
single-step-ahead traffic prediction, (4,8) fixed point, HardSigmoid*/
HardTanh — §6.1 experimental settings."""
from repro.core.qlstm import QLSTMConfig, PAPER_ACTS

CONFIG = QLSTMConfig(input_size=1, hidden_size=20, num_layers=1,
                     out_features=1, seq_len=6, acts=PAPER_ACTS)
