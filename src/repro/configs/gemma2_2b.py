"""Gemma2-2B: local/global alternating attention, logit softcaps, GeGLU,
pre+post sublayer norms. [arXiv:2408.00118]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    norm="gemma_rmsnorm", post_norms=True, act="gelu_tanh", mlp_type="geglu",
    tie_embeddings=True, final_softcap=30.0,
    attn=AttnConfig(rope_theta=10000.0, alt_window=4096, attn_softcap=50.0),
    notes="Even layers local (4096), odd global; attn softcap 50, final 30. "
          "hard_acts=True turns softcaps into clips (C2 beyond-paper).",
)
