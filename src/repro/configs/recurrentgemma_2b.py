"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427]

The strongest non-LSTM target for the paper's technique: the RG-LRU gates
are sigmoids (hard_acts => HardSigmoid*), the recurrence is a quantisable
fixed-point-friendly state update, and decode keeps O(1) state."""
from repro.configs.base import AttnConfig, ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    norm="gemma_rmsnorm", act="gelu_tanh", mlp_type="geglu",
    tie_embeddings=True, final_softcap=30.0,
    attn=AttnConfig(rope_theta=10000.0, window=2048),
    recurrent=RecurrentConfig(lru_width=2560, conv_width=4,
                              block_pattern=("rec", "rec", "attn")),
    notes="26 layers = 8 x (rec,rec,attn) + 2 rec tail. long_500k runs: "
          "RG-LRU state is O(1), attn KV ring-bounded at 2048.",
)
