"""Phi-3.5-MoE (42B total / 6.6B active): 16 experts, top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    act="silu", mlp_type="swiglu",
    attn=AttnConfig(rope_theta=10000.0),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400, expert_parallel=True),
    sharding_overrides=(("experts", "model"), ("expert_mlp", None)),
    notes="16 experts / 16-way TP => true expert parallelism (1 expert per "
          "model shard); router kept fp32/softmax-exact.",
)
