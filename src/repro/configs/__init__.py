"""--arch config registry + reduced (smoke-test) config derivation."""

from __future__ import annotations

import dataclasses

from repro.configs.base import (AttnConfig, ModelConfig, MoEConfig,
                                RecurrentConfig, RWKVConfig, ShapeSpec,
                                SHAPES, input_specs, shape_applicable)
from repro.configs.codeqwen15_7b import CONFIG as _codeqwen
from repro.configs.gemma2_27b import CONFIG as _gemma27
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.lstm_pems import CONFIG as _lstm
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.phi35_moe import CONFIG as _phi
from repro.configs.qwen15_05b import CONFIG as _qwen05
from repro.configs.qwen2_vl_2b import CONFIG as _qwenvl
from repro.configs.recurrentgemma_2b import CONFIG as _rg
from repro.configs.rwkv6_7b import CONFIG as _rwkv

ARCH_CONFIGS = {
    "qwen2-vl-2b": _qwenvl,
    "phi3.5-moe": _phi,
    "mixtral-8x7b": _mixtral,
    "musicgen-medium": _musicgen,
    "gemma2-2b": _gemma2,
    "gemma2-27b": _gemma27,
    "qwen1.5-0.5b": _qwen05,
    "codeqwen1.5-7b": _codeqwen,
    "recurrentgemma-2b": _rg,
    "rwkv6-7b": _rwkv,
    "lstm-pems": _lstm,
}

ASSIGNED_ARCHS = [k for k in ARCH_CONFIGS if k != "lstm-pems"]


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to a CPU-smoke-testable one of the SAME family:
    few layers (>= one full block pattern), narrow dims, tiny vocab, few
    experts — per the task's smoke-test requirement."""
    kw = dict(
        n_layers=3 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=96,
        vocab_size=128,
        remat="none",
    )
    if cfg.family == "hybrid":
        kw["n_layers"] = 4  # one (rec,rec,attn) period + 1 tail rec
        kw["recurrent"] = dataclasses.replace(cfg.recurrent, lru_width=64)
        kw["attn"] = dataclasses.replace(cfg.attn, window=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, lora_r=8,
                                         lora_w=8, chunk=8)
        kw["n_heads"] = 4
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        d_ff=96)
    if cfg.attn is not None and "attn" not in kw:
        sec = (2, 3, 3) if cfg.attn.mrope_sections else None
        kw["attn"] = dataclasses.replace(
            cfg.attn, mrope_sections=sec,
            window=min(cfg.attn.window, 8) if cfg.attn.window else None,
            alt_window=8 if cfg.attn.alt_window else None)
    return cfg.replace(**kw)
