"""Pallas TPU kernels for the paper's compute hot-spots (validated with
``interpret=True`` on CPU against the pure-jnp oracles in ``ref.py``):

  * ``qlstm_cell``   — fused quantised-LSTM sequence (pipelined ALU, C3):
    single-layer and fused multi-layer entries, both stateful — the
    per-layer (h, c) VMEM scratch is seeded from a carried state and the
    final state is returned, so the serving hot path resumes streams
    mid-sequence on the fused kernel (docs/KERNELS.md is the internals
    guide).
  * ``quant_matmul`` — tiled W8A8 matmul, int32 accum, fused S5 requant
    (C1).
  * ``hard_act``     — HardSigmoid*/HardTanh elementwise methods (C2).
"""

from repro.kernels import ops, ref  # noqa: F401
