"""Fused quantised-LSTM sequence kernel — the paper's pipelined ALU (C3)
re-thought for the TPU memory hierarchy.

FPGA design (paper §5.2)                 This kernel
----------------------------------       ------------------------------------
5-stage pipeline: load W[i],x[i] ∥       Pallas grid pipeline: HBM→VMEM DMA of
  multiply ∥ accumulate                    x_{t+1} overlapped with step-t MXU/
                                           VPU compute (double buffering).
Weights in BRAM, no off-chip access      Weights fetched once into VMEM and
                                           resident across all T grid steps
                                           (constant index_map ⇒ no re-fetch).
16-bit accumulator, round ONCE (S5)      int32 accumulator in VMEM scratch,
                                           single round-half-up shift per MAC.
ALU_resource_type = DSP | LUT            compute_unit = mxu (int8 systolic
                                           matmul) | vpu (vector mul-reduce).
HardSigmoid* methods                      arithmetic (shift+add+selects) and
                                           step (unrolled comparator cascade);
                                           both bit-identical to the oracle.

Grid = (batch_blocks, T); T is the minor axis, so the (h, c) VMEM scratch
carries state across timesteps of one batch block and resets at t == 0.

Oracle: ``kernels/ref.py::qlstm_seq_ref`` (bit-exact).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig, product_config

Array = jax.Array


def _make_kernel(cfg: FixedPointConfig, hdim: int, hs_method: str,
                 hs_slope_shift: int, hs_bound: float,
                 ht_min: float, ht_max: float, compute_unit: str,
                 t_len: int):
    prod = product_config(cfg, cfg)
    shift = prod.frac_bits - cfg.frac_bits          # 2a -> a
    half = 1 << (shift - 1)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    lo = cfg.int_min
    hi = cfg.int_max
    # Shared integer spec (core/hard_act.py) — the kernel uses the exact
    # oracle helpers so the two implementations cannot drift.  The 'step'
    # method is the gather-free unrolled cascade; HardTanh is the same
    # pair of comparators the oracle clips with.
    hs = (hard_act.hs_star_int_step_unrolled if hs_method == "step"
          else hard_act.hs_star_int_arithmetic)
    ht = functools.partial(hard_act.hard_tanh_int, cfg=cfg,
                           min_val=ht_min, max_val=ht_max)

    def requant(v):  # round-half-up shift + saturate: the single S5 rounding
        return jnp.clip((v + half) >> shift, lo, hi)

    def kernel(x_ref, wx_ref, wh_ref, b_ref, out_ref, h_ref, c_ref):
        t = pl.program_id(1)

        @pl.when(t == 0)
        def _():
            h_ref[...] = jnp.zeros_like(h_ref)
            c_ref[...] = jnp.zeros_like(c_ref)

        x_t = x_ref[0]                       # (bb, M) int carrier
        h8 = h_ref[...].astype(x_t.dtype)    # stored codes fit the carrier
        if compute_unit == "mxu":
            # int8 x int8 -> int32 systolic matmul (the DSP analogue)
            acc = jax.lax.dot_general(
                x_t, wx_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            acc += jax.lax.dot_general(
                h8, wh_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        else:
            # VPU: broadcast multiply + reduce (the LUT-fabric analogue)
            acc = jnp.sum(x_t.astype(jnp.int32)[:, :, None]
                          * wx_ref[...].astype(jnp.int32)[None, :, :], axis=1)
            acc += jnp.sum(h8.astype(jnp.int32)[:, :, None]
                           * wh_ref[...].astype(jnp.int32)[None, :, :], axis=1)
        acc += b_ref[...]                    # bias at accumulator precision
        pre = requant(acc)                   # late rounding (S5)

        i = hs(pre[:, :hdim], spec)
        f = hs(pre[:, hdim:2 * hdim], spec)
        g = ht(pre[:, 2 * hdim:3 * hdim])
        o = hs(pre[:, 3 * hdim:], spec)

        c = c_ref[...]
        wide = f * c + i * g                 # both products wide, add, ...
        c_new = requant(wide)                # ... round once
        tanh_c = ht(c_new)
        h_new = requant(o * tanh_c)

        h_ref[...] = h_new
        c_ref[...] = c_new
        out_ref[0] = h_new.astype(out_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "batch_block",
                     "interpret"))
def qlstm_seq_pallas(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                     *, cfg: FixedPointConfig,
                     hs_method: str = "arithmetic",
                     hs_slope_shift: int = 3, hs_bound: float = 3.0,
                     ht_min: float = -1.0, ht_max: float = 1.0,
                     compute_unit: str = "mxu",
                     batch_block: Optional[int] = None,
                     interpret: bool = True) -> Array:
    """Run the fused kernel.

    x_int: (T, B, M) integer codes (storage dtype of cfg);
    w_x: (M, 4H); w_h: (H, 4H); b_wide: (4H,) int32.
    Returns (T, B, H) codes in the storage dtype.
    """
    t_len, bsz, m = x_int.shape
    hdim = w_h.shape[0]
    bb = batch_block or min(bsz, 128)
    pad = (-bsz) % bb
    if pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, pad), (0, 0)))
    bsz_p = bsz + pad
    nb = bsz_p // bb

    kernel = _make_kernel(cfg, hdim, hs_method, hs_slope_shift, hs_bound,
                          ht_min, ht_max, compute_unit, t_len)
    out = pl.pallas_call(
        kernel,
        grid=(nb, t_len),
        in_specs=[
            pl.BlockSpec((1, bb, m), lambda bi, t: (t, bi, 0)),
            pl.BlockSpec((m, 4 * hdim), lambda bi, t: (0, 0)),      # resident
            pl.BlockSpec((hdim, 4 * hdim), lambda bi, t: (0, 0)),   # resident
            pl.BlockSpec((1, 4 * hdim), lambda bi, t: (0, 0)),      # resident
        ],
        out_specs=pl.BlockSpec((1, bb, hdim), lambda bi, t: (t, bi, 0)),
        out_shape=jax.ShapeDtypeStruct((t_len, bsz_p, hdim), x_int.dtype),
        scratch_shapes=[pltpu.VMEM((bb, hdim), jnp.int32),
                        pltpu.VMEM((bb, hdim), jnp.int32)],
        interpret=interpret,
    )(x_int, w_x, w_h, b_wide.reshape(1, -1).astype(jnp.int32))
    return out[:, :bsz]
