"""Fused quantised-LSTM sequence kernel — the paper's pipelined ALU (C3)
re-thought for the TPU memory hierarchy.

FPGA design (paper §5.2)                 This kernel
----------------------------------       ------------------------------------
5-stage pipeline: load W[i],x[i] ∥       Pallas grid pipeline: HBM→VMEM DMA of
  multiply ∥ accumulate                    x_{t+1} overlapped with step-t MXU/
                                           VPU compute (double buffering).
Weights in BRAM, no off-chip access      Weights fetched once into VMEM and
                                           resident across all T grid steps
                                           (constant index_map ⇒ no re-fetch).
16-bit accumulator, round ONCE (S5)      int32 accumulator in VMEM scratch,
                                           single round-half-up shift per MAC.
ALU_resource_type = DSP | LUT            compute_unit = mxu (int8 systolic
                                           matmul) | vpu (vector mul-reduce).
HardSigmoid* methods                      arithmetic (shift+add+selects) and
                                           step (unrolled comparator cascade);
                                           both bit-identical to the oracle.
State registers (h, c) in SRAM           per-layer (h, c) VMEM scratch, seeded
                                           from the carried state at t == 0 and
                                           emitted as extra outputs at the last
                                           step — the stream-resume contract of
                                           ``repro.serving``.

Grid = (batch_blocks, T); T is the minor axis, so the (h, c) VMEM scratch
carries state across timesteps of one batch block.  At t == 0 the scratch
is seeded from the ``(h0, c0)`` inputs (all-zero for a fresh stream), and
at t == T-1 it is written to the final-state outputs, so a window-by-window
resumed run is bit-identical to one concatenated run.

Three public entry points share one cell-step implementation:

  * :func:`qlstm_seq_pallas` — one layer, optionally resumed from a carried
    ``(h0, c0)`` and optionally returning the final state.
  * :func:`qlstm_seq_multilayer_pallas` — the whole LSTM stack fused into
    ONE ``pallas_call``: every layer's (h, c) stays resident in VMEM and
    layer *l*'s hidden state at step *t* feeds layer *l+1* at the same step
    without ever round-tripping through HBM (the Python-level per-layer
    re-launch of ``backends.common.run_layered`` is exactly what this
    removes from the serving hot path).
  * :func:`qlstm_seq_slot_pallas` — the multi-layer kernel with
    DEVICE-RESIDENT stream state: instead of shipping ``(h0, c0)`` batch
    arrays from the host, the call carries a persistent state TABLE of
    shape ``(n_slots + 2, L, 2, H)`` plus two per-row int32 slot-id
    vectors.  At t == 0 each batch row gathers its carry from
    ``table[gather_slots[i]]``; at t == T-1 each row scatters its final
    (h, c) into ``table[scatter_slots[i]]`` — all inside the kernel, so
    the host ships only integer inputs and slot ids per wave.  Row
    ``n_slots`` is the ZERO slot (always the reset carry, gathered by
    fresh/reset streams, never written); row ``n_slots + 1`` is the TRASH
    slot (the scatter target for padding/retired rows, never read).
    Because every gather happens at t == 0 and every scatter at t == T-1,
    a slot freed and reassigned within one wave is still read before it
    is overwritten.

Oracle: ``kernels/ref.py::qlstm_seq_ref`` (bit-exact, including the carry).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig, product_config

Array = jax.Array


def _cell_math(cfg: FixedPointConfig, hs_method: str, hs_slope_shift: int,
               hs_bound: float, ht_min: float, ht_max: float):
    """The shared integer arithmetic of every kernel variant: the S5
    late-rounding requant plus the hard activations.  Built from the exact
    oracle helpers (core/hard_act.py) so the kernels cannot drift from
    ``kernels/ref.py``.  The 'step' method is the gather-free unrolled
    cascade; HardTanh is the same pair of comparators the oracle clips
    with."""
    prod = product_config(cfg, cfg)
    shift = prod.frac_bits - cfg.frac_bits          # 2a -> a
    half = 1 << (shift - 1)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    lo = cfg.int_min
    hi = cfg.int_max
    hs_fn = (hard_act.hs_star_int_step_unrolled if hs_method == "step"
             else hard_act.hs_star_int_arithmetic)
    hs = lambda v: hs_fn(v, spec)
    ht = functools.partial(hard_act.hard_tanh_int, cfg=cfg,
                           min_val=ht_min, max_val=ht_max)

    def requant(v):  # round-half-up shift + saturate: the single S5 rounding
        return jnp.clip((v + half) >> shift, lo, hi)

    return requant, hs, ht


def _stack_step(x_t, wx, wh, b, h_s, c_s, *, hdim, compute_unit,
                requant, hs, ht):
    """One timestep through the whole fused layer stack: reads and updates
    the per-layer (h, c) VMEM scratch refs in place and returns the final
    layer's new hidden state.  Layer li's step-t output feeds layer li+1
    at the same step, staying in VMEM/registers — no HBM round-trip
    between layers."""
    carrier = x_t.dtype
    inp = x_t
    for li in range(len(wh)):
        h8 = h_s[li][...].astype(carrier)  # stored codes fit the carrier
        if compute_unit == "mxu":
            # int8 x int8 -> int32 systolic matmul (the DSP analogue)
            acc = jax.lax.dot_general(
                inp, wx[li][...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            acc += jax.lax.dot_general(
                h8, wh[li][...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
        else:
            # VPU: broadcast multiply + reduce (the LUT-fabric analogue)
            acc = jnp.sum(inp.astype(jnp.int32)[:, :, None]
                          * wx[li][...].astype(jnp.int32)[None, :, :],
                          axis=1)
            acc += jnp.sum(h8.astype(jnp.int32)[:, :, None]
                           * wh[li][...].astype(jnp.int32)[None, :, :],
                           axis=1)
        acc += b[li][...]                # bias at accumulator precision
        pre = requant(acc)               # late rounding (S5)

        i = hs(pre[:, :hdim])
        f = hs(pre[:, hdim:2 * hdim])
        g = ht(pre[:, 2 * hdim:3 * hdim])
        o = hs(pre[:, 3 * hdim:])

        c = c_s[li][...]
        wide = f * c + i * g             # both products wide, add, ...
        c_new = requant(wide)            # ... round once
        tanh_c = ht(c_new)
        h_new = requant(o * tanh_c)

        h_s[li][...] = h_new
        c_s[li][...] = c_new
        inp = h_new.astype(carrier)
    return inp


def _make_kernel(cfg: FixedPointConfig, hdim: int, hs_method: str,
                 hs_slope_shift: int, hs_bound: float,
                 ht_min: float, ht_max: float, compute_unit: str,
                 t_len: int, num_layers: int):
    requant, hs, ht = _cell_math(cfg, hs_method, hs_slope_shift, hs_bound,
                                 ht_min, ht_max)

    def kernel(*refs):
        # Ref layout (L = num_layers): x, L*w_x, L*w_h, L*b, L*h0, L*c0 |
        # out, L*h_fin, L*c_fin | L*h_scratch, L*c_scratch.
        n = num_layers
        x_ref = refs[0]
        wx = refs[1:1 + n]
        wh = refs[1 + n:1 + 2 * n]
        b = refs[1 + 2 * n:1 + 3 * n]
        h0 = refs[1 + 3 * n:1 + 4 * n]
        c0 = refs[1 + 4 * n:1 + 5 * n]
        out_ref = refs[1 + 5 * n]
        h_fin = refs[2 + 5 * n:2 + 6 * n]
        c_fin = refs[2 + 6 * n:2 + 7 * n]
        h_s = refs[2 + 7 * n:2 + 8 * n]
        c_s = refs[2 + 8 * n:2 + 9 * n]
        t = pl.program_id(1)

        @pl.when(t == 0)
        def _():
            # Seed the state scratch from the carried (h0, c0) — the zero
            # reset state for a fresh stream, window k's final state when
            # resuming window k+1.
            for li in range(n):
                h_s[li][...] = h0[li][...]
                c_s[li][...] = c0[li][...]

        out_ref[0] = _stack_step(
            x_ref[0], wx, wh, b, h_s, c_s, hdim=hdim,
            compute_unit=compute_unit, requant=requant, hs=hs,
            ht=ht).astype(out_ref.dtype)         # final layer's h_t

        @pl.when(t == t_len - 1)
        def _():
            for li in range(n):
                h_fin[li][...] = h_s[li][...]
                c_fin[li][...] = c_s[li][...]

    return kernel


def _make_slot_kernel(cfg: FixedPointConfig, hdim: int, hs_method: str,
                      hs_slope_shift: int, hs_bound: float,
                      ht_min: float, ht_max: float, compute_unit: str,
                      t_len: int, num_layers: int):
    requant, hs, ht = _cell_math(cfg, hs_method, hs_slope_shift, hs_bound,
                                 ht_min, ht_max)

    def kernel(*refs):
        # Ref layout (L = num_layers): x, gather_slots, scatter_slots,
        # table | L*w_x, L*w_h, L*b | out, table_out | L*h_s, L*c_s.
        n = num_layers
        x_ref, g_ref, s_ref, tbl_ref = refs[:4]
        wx = refs[4:4 + n]
        wh = refs[4 + n:4 + 2 * n]
        b = refs[4 + 2 * n:4 + 3 * n]
        out_ref = refs[4 + 3 * n]
        tbl_out = refs[5 + 3 * n]
        h_s = refs[6 + 3 * n:6 + 4 * n]
        c_s = refs[6 + 4 * n:6 + 5 * n]
        t = pl.program_id(0)

        @pl.when(t == 0)
        def _():
            # In-kernel GATHER: row i's carry comes from table row
            # gather_slots[i] — the ZERO row for fresh/reset streams.
            g = g_ref[0]
            tbl = tbl_ref[...]
            for li in range(n):
                h_s[li][...] = jnp.take(tbl[:, li, 0, :], g, axis=0)
                c_s[li][...] = jnp.take(tbl[:, li, 1, :], g, axis=0)

        out_ref[0] = _stack_step(
            x_ref[0], wx, wh, b, h_s, c_s, hdim=hdim,
            compute_unit=compute_unit, requant=requant, hs=hs,
            ht=ht).astype(out_ref.dtype)

        @pl.when(t == t_len - 1)
        def _():
            # In-kernel SCATTER: row i's final (h, c) lands in table row
            # scatter_slots[i] — the TRASH row for retired/padding rows.
            # Duplicate targets only ever occur at TRASH (the allocator
            # hands out unique live slots), whose content is never read.
            s = s_ref[0]
            tbl = tbl_ref[...]
            for li in range(n):
                tbl = tbl.at[s, li, 0, :].set(h_s[li][...])
                tbl = tbl.at[s, li, 1, :].set(c_s[li][...])
            tbl_out[...] = tbl

    return kernel


def _qlstm_pallas(x_int, w_xs, w_hs, b_wides, h0s, c0s, *,
                  cfg: FixedPointConfig, hs_method: str, hs_slope_shift: int,
                  hs_bound: float, ht_min: float, ht_max: float,
                  compute_unit: str, batch_block: Optional[int],
                  interpret: bool):
    """Shared driver behind both public entries: one ``pallas_call`` over
    ``len(w_hs)`` fused layers, returning ``(out_seq, h_fin, c_fin)`` with
    the per-layer final state as tuples."""
    t_len, bsz, m = x_int.shape
    n = len(w_hs)
    hdim = w_hs[0].shape[0]
    bb = batch_block or min(bsz, 128)
    pad = (-bsz) % bb
    if pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, pad), (0, 0)))
        # Padding rows start from (and produce) garbage-free zero state;
        # they are sliced away before return either way.
        h0s = tuple(jnp.pad(h, ((0, pad), (0, 0))) for h in h0s)
        c0s = tuple(jnp.pad(c, ((0, pad), (0, 0))) for c in c0s)
    bsz_p = bsz + pad
    nb = bsz_p // bb

    kernel = _make_kernel(cfg, hdim, hs_method, hs_slope_shift, hs_bound,
                          ht_min, ht_max, compute_unit, t_len, n)
    resident = lambda bi, t: (0, 0)                    # fetched once, stays
    per_block = lambda bi, t: (bi, 0)                  # constant across t
    in_specs = [pl.BlockSpec((1, bb, m), lambda bi, t: (t, bi, 0))]
    in_specs += [pl.BlockSpec(w.shape, resident) for w in w_xs]
    in_specs += [pl.BlockSpec(w.shape, resident) for w in w_hs]
    in_specs += [pl.BlockSpec((1, 4 * hdim), resident)] * n
    in_specs += [pl.BlockSpec((bb, hdim), per_block)] * (2 * n)
    out_specs = [pl.BlockSpec((1, bb, hdim), lambda bi, t: (t, bi, 0))]
    out_specs += [pl.BlockSpec((bb, hdim), per_block)] * (2 * n)
    out_shape = [jax.ShapeDtypeStruct((t_len, bsz_p, hdim), x_int.dtype)]
    out_shape += [jax.ShapeDtypeStruct((bsz_p, hdim), jnp.int32)] * (2 * n)
    outs = pl.pallas_call(
        kernel,
        grid=(nb, t_len),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bb, hdim), jnp.int32)] * (2 * n),
        interpret=interpret,
    )(x_int, *w_xs, *w_hs,
      *(b.reshape(1, -1).astype(jnp.int32) for b in b_wides),
      *(h.astype(jnp.int32) for h in h0s),
      *(c.astype(jnp.int32) for c in c0s))
    out = outs[0][:, :bsz]
    h_fin = tuple(o[:bsz] for o in outs[1:1 + n])
    c_fin = tuple(o[:bsz] for o in outs[1 + n:])
    return out, h_fin, c_fin


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "batch_block",
                     "interpret", "return_state"))
def qlstm_seq_pallas(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                     *, cfg: FixedPointConfig,
                     hs_method: str = "arithmetic",
                     hs_slope_shift: int = 3, hs_bound: float = 3.0,
                     ht_min: float = -1.0, ht_max: float = 1.0,
                     compute_unit: str = "mxu",
                     batch_block: Optional[int] = None,
                     interpret: bool = True,
                     h0: Optional[Array] = None, c0: Optional[Array] = None,
                     return_state: bool = False):
    """Run the fused kernel for one layer.

    x_int: (T, B, M) integer codes (storage dtype of cfg);
    w_x: (M, 4H); w_h: (H, 4H); b_wide: (4H,) int32.
    h0/c0: optional (B, H) int32 initial carry (zeros when omitted — the
    accelerator's reset state), seeded into the VMEM state scratch at
    t == 0; bit-exact with ``kernels/ref.qlstm_seq_ref(h0, c0)``.
    Returns (T, B, H) codes in the storage dtype; with
    ``return_state=True``, ``(out, (h_last, c_last))`` so the caller can
    resume the next window where this one left off.
    """
    _, bsz, _ = x_int.shape
    hdim = w_h.shape[0]
    if h0 is None:
        h0 = jnp.zeros((bsz, hdim), jnp.int32)
    if c0 is None:
        c0 = jnp.zeros((bsz, hdim), jnp.int32)
    out, (h_f,), (c_f,) = _qlstm_pallas(
        x_int, (w_x,), (w_h,), (b_wide,), (h0,), (c0,),
        cfg=cfg, hs_method=hs_method, hs_slope_shift=hs_slope_shift,
        hs_bound=hs_bound, ht_min=ht_min, ht_max=ht_max,
        compute_unit=compute_unit, batch_block=batch_block,
        interpret=interpret)
    if return_state:
        return out, (h_f, c_f)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "batch_block",
                     "interpret"))
def qlstm_seq_multilayer_pallas(x_int: Array, w_xs: Tuple[Array, ...],
                                w_hs: Tuple[Array, ...],
                                b_wides: Tuple[Array, ...],
                                h0s: Tuple[Array, ...],
                                c0s: Tuple[Array, ...], *,
                                cfg: FixedPointConfig,
                                hs_method: str = "arithmetic",
                                hs_slope_shift: int = 3,
                                hs_bound: float = 3.0,
                                ht_min: float = -1.0, ht_max: float = 1.0,
                                compute_unit: str = "mxu",
                                batch_block: Optional[int] = None,
                                interpret: bool = True):
    """The whole LSTM stack, fused and stateful, in ONE ``pallas_call``.

    x_int: (T, B, M) integer codes; ``w_xs``/``w_hs``/``b_wides`` are
    per-layer tuples (layer 0's w_x is (M, 4H), deeper layers' (H, 4H);
    every w_h is (H, 4H), every b_wide (4H,) int32); ``h0s``/``c0s`` are
    the per-layer (B, H) int32 carry (``core.qlstm.init_int_state`` split
    into its h and c halves for a fresh stream).

    Every layer's (h, c) lives in VMEM scratch for the whole call and
    layer *l*'s step-t output feeds layer *l+1* at the same step without
    leaving the chip — unlike the layered Python loop, which launches one
    kernel per layer and round-trips the full (T, B, H) sequence through
    HBM between layers.

    Returns ``(out, state)``: out is the final layer's (T, B, H) hidden
    codes in the storage dtype; ``state`` is the per-layer
    ``((h_last, c_last), ...)`` int32 carry after the last step —
    bit-exact with threading ``kernels/ref.qlstm_seq_ref(h0, c0,
    return_state=True)`` through the stack layer by layer.
    """
    n = len(w_hs)
    if not (len(w_xs) == len(b_wides) == len(h0s) == len(c0s) == n):
        raise ValueError(
            f"per-layer tuples disagree on the layer count: "
            f"w_xs={len(w_xs)}, w_hs={n}, b_wides={len(b_wides)}, "
            f"h0s={len(h0s)}, c0s={len(c0s)}")
    out, h_fin, c_fin = _qlstm_pallas(
        x_int, tuple(w_xs), tuple(w_hs), tuple(b_wides), tuple(h0s),
        tuple(c0s),
        cfg=cfg, hs_method=hs_method, hs_slope_shift=hs_slope_shift,
        hs_bound=hs_bound, ht_min=ht_min, ht_max=ht_max,
        compute_unit=compute_unit, batch_block=batch_block,
        interpret=interpret)
    return out, tuple(zip(h_fin, c_fin))


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "interpret"))
def qlstm_seq_slot_pallas(x_int: Array, gather_slots: Array,
                          scatter_slots: Array, table: Array,
                          w_xs: Tuple[Array, ...], w_hs: Tuple[Array, ...],
                          b_wides: Tuple[Array, ...], *,
                          cfg: FixedPointConfig,
                          hs_method: str = "arithmetic",
                          hs_slope_shift: int = 3, hs_bound: float = 3.0,
                          ht_min: float = -1.0, ht_max: float = 1.0,
                          compute_unit: str = "mxu",
                          interpret: bool = True):
    """The fused multi-layer stack with DEVICE-RESIDENT stream state.

    x_int: (T, B, M) integer codes; ``table``: the persistent
    ``(n_slots + 2, L, 2, H)`` int32 state table (axis 2 is (h, c); row
    ``n_slots`` is the always-zero RESET slot, row ``n_slots + 1`` the
    write-only TRASH slot); ``gather_slots``/``scatter_slots``: (B,) int32
    table-row ids, one per batch row.  Weight tuples as in
    :func:`qlstm_seq_multilayer_pallas`.

    At t == 0 the kernel gathers row i's per-layer carry from
    ``table[gather_slots[i]]`` into VMEM scratch; at t == T-1 it scatters
    the final per-layer (h, c) into ``table[scatter_slots[i]]`` and emits
    the updated table.  The host therefore ships only the integer inputs
    and two (B,) slot vectors per wave — no (h, c) batch arrays cross the
    host/device boundary on the hot path.  Because all gathers precede all
    scatters inside one call, a slot evicted and reassigned within the
    same wave still sources its old owner's carry correctly.

    The whole batch runs as ONE grid block (grid is over time only): every
    row scatters into one shared table, so the grid must not parallelise
    over batch.  Returns ``(out, new_table)``: the final layer's (T, B, H)
    hidden codes and the updated state table.  Bit-exact with gathering
    ``(h0, c0)`` on the host and calling
    :func:`qlstm_seq_multilayer_pallas` with the same carries.
    """
    n = len(w_hs)
    if not (len(w_xs) == len(b_wides) == n):
        raise ValueError(
            f"per-layer tuples disagree on the layer count: "
            f"w_xs={len(w_xs)}, w_hs={n}, b_wides={len(b_wides)}")
    t_len, bsz, m = x_int.shape
    hdim = w_hs[0].shape[0]
    if table.ndim != 4 or table.shape[0] < 3 or table.shape[1:] != (n, 2,
                                                                    hdim):
        raise ValueError(
            f"state table must be (n_slots + 2, {n}, 2, {hdim}) with "
            f"n_slots >= 1, got {table.shape}")
    sd = x_int.dtype
    gather_slots = gather_slots.reshape(1, bsz).astype(jnp.int32)
    scatter_slots = scatter_slots.reshape(1, bsz).astype(jnp.int32)
    table = table.astype(jnp.int32)

    kernel = _make_slot_kernel(cfg, hdim, hs_method, hs_slope_shift,
                               hs_bound, ht_min, ht_max, compute_unit,
                               t_len, n)
    res2 = lambda t: (0, 0)                             # resident across t
    res4 = lambda t: (0, 0, 0, 0)
    in_specs = [pl.BlockSpec((1, bsz, m), lambda t: (t, 0, 0)),
                pl.BlockSpec((1, bsz), res2),
                pl.BlockSpec((1, bsz), res2),
                pl.BlockSpec(table.shape, res4)]
    in_specs += [pl.BlockSpec(w.shape, res2) for w in w_xs]
    in_specs += [pl.BlockSpec(w.shape, res2) for w in w_hs]
    in_specs += [pl.BlockSpec((1, 4 * hdim), res2)] * n
    out_specs = [pl.BlockSpec((1, bsz, hdim), lambda t: (t, 0, 0)),
                 pl.BlockSpec(table.shape, res4)]
    out_shape = [jax.ShapeDtypeStruct((t_len, bsz, hdim), sd),
                 jax.ShapeDtypeStruct(table.shape, jnp.int32)]
    outs = pl.pallas_call(
        kernel,
        grid=(t_len,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bsz, hdim), jnp.int32)] * (2 * n),
        interpret=interpret,
    )(x_int, gather_slots, scatter_slots, table, *w_xs, *w_hs,
      *(b.reshape(1, -1).astype(jnp.int32) for b in b_wides))
    return outs[0], outs[1]
