"""Fused quantised-LSTM sequence kernel — the paper's pipelined ALU (C3)
re-thought for the TPU memory hierarchy.

FPGA design (paper §5.2)                 This kernel
----------------------------------       ------------------------------------
5-stage pipeline: load W[i],x[i] ∥       Pallas grid pipeline: HBM→VMEM DMA of
  multiply ∥ accumulate                    x_{t+1} overlapped with step-t MXU/
                                           VPU compute (double buffering).
Weights in BRAM, no off-chip access      Weights fetched once into VMEM and
                                           resident across all T grid steps
                                           (constant index_map ⇒ no re-fetch).
16-bit accumulator, round ONCE (S5)      int32 accumulator in VMEM scratch,
                                           single round-half-up shift per MAC.
ALU_resource_type = DSP | LUT            compute_unit = mxu (int8 systolic
                                           matmul) | vpu (vector mul-reduce).
HardSigmoid* methods                      arithmetic (shift+add+selects) and
                                           step (unrolled comparator cascade);
                                           both bit-identical to the oracle.
State registers (h, c) in SRAM           per-layer (h, c) VMEM scratch, seeded
                                           from the carried state at t == 0 and
                                           emitted as extra outputs at the last
                                           step — the stream-resume contract of
                                           ``repro.serving``.

Grid = (batch_blocks, T); T is the minor axis, so the (h, c) VMEM scratch
carries state across timesteps of one batch block.  At t == 0 the scratch
is seeded from the ``(h0, c0)`` inputs (all-zero for a fresh stream), and
at t == T-1 it is written to the final-state outputs, so a window-by-window
resumed run is bit-identical to one concatenated run.

Two public entry points share one kernel builder:

  * :func:`qlstm_seq_pallas` — one layer, optionally resumed from a carried
    ``(h0, c0)`` and optionally returning the final state.
  * :func:`qlstm_seq_multilayer_pallas` — the whole LSTM stack fused into
    ONE ``pallas_call``: every layer's (h, c) stays resident in VMEM and
    layer *l*'s hidden state at step *t* feeds layer *l+1* at the same step
    without ever round-tripping through HBM (the Python-level per-layer
    re-launch of ``backends.common.run_layered`` is exactly what this
    removes from the serving hot path).

Oracle: ``kernels/ref.py::qlstm_seq_ref`` (bit-exact, including the carry).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig, product_config

Array = jax.Array


def _make_kernel(cfg: FixedPointConfig, hdim: int, hs_method: str,
                 hs_slope_shift: int, hs_bound: float,
                 ht_min: float, ht_max: float, compute_unit: str,
                 t_len: int, num_layers: int):
    prod = product_config(cfg, cfg)
    shift = prod.frac_bits - cfg.frac_bits          # 2a -> a
    half = 1 << (shift - 1)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    lo = cfg.int_min
    hi = cfg.int_max
    # Shared integer spec (core/hard_act.py) — the kernel uses the exact
    # oracle helpers so the two implementations cannot drift.  The 'step'
    # method is the gather-free unrolled cascade; HardTanh is the same
    # pair of comparators the oracle clips with.
    hs = (hard_act.hs_star_int_step_unrolled if hs_method == "step"
          else hard_act.hs_star_int_arithmetic)
    ht = functools.partial(hard_act.hard_tanh_int, cfg=cfg,
                           min_val=ht_min, max_val=ht_max)

    def requant(v):  # round-half-up shift + saturate: the single S5 rounding
        return jnp.clip((v + half) >> shift, lo, hi)

    def kernel(*refs):
        # Ref layout (L = num_layers): x, L*w_x, L*w_h, L*b, L*h0, L*c0 |
        # out, L*h_fin, L*c_fin | L*h_scratch, L*c_scratch.
        n = num_layers
        x_ref = refs[0]
        wx = refs[1:1 + n]
        wh = refs[1 + n:1 + 2 * n]
        b = refs[1 + 2 * n:1 + 3 * n]
        h0 = refs[1 + 3 * n:1 + 4 * n]
        c0 = refs[1 + 4 * n:1 + 5 * n]
        out_ref = refs[1 + 5 * n]
        h_fin = refs[2 + 5 * n:2 + 6 * n]
        c_fin = refs[2 + 6 * n:2 + 7 * n]
        h_s = refs[2 + 7 * n:2 + 8 * n]
        c_s = refs[2 + 8 * n:2 + 9 * n]
        t = pl.program_id(1)

        @pl.when(t == 0)
        def _():
            # Seed the state scratch from the carried (h0, c0) — the zero
            # reset state for a fresh stream, window k's final state when
            # resuming window k+1.
            for li in range(n):
                h_s[li][...] = h0[li][...]
                c_s[li][...] = c0[li][...]

        x_t = x_ref[0]                       # (bb, M) int carrier
        carrier = x_t.dtype
        inp = x_t
        for li in range(n):
            h8 = h_s[li][...].astype(carrier)  # stored codes fit the carrier
            if compute_unit == "mxu":
                # int8 x int8 -> int32 systolic matmul (the DSP analogue)
                acc = jax.lax.dot_general(
                    inp, wx[li][...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc += jax.lax.dot_general(
                    h8, wh[li][...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
            else:
                # VPU: broadcast multiply + reduce (the LUT-fabric analogue)
                acc = jnp.sum(inp.astype(jnp.int32)[:, :, None]
                              * wx[li][...].astype(jnp.int32)[None, :, :],
                              axis=1)
                acc += jnp.sum(h8.astype(jnp.int32)[:, :, None]
                               * wh[li][...].astype(jnp.int32)[None, :, :],
                               axis=1)
            acc += b[li][...]                # bias at accumulator precision
            pre = requant(acc)               # late rounding (S5)

            i = hs(pre[:, :hdim], spec)
            f = hs(pre[:, hdim:2 * hdim], spec)
            g = ht(pre[:, 2 * hdim:3 * hdim])
            o = hs(pre[:, 3 * hdim:], spec)

            c = c_s[li][...]
            wide = f * c + i * g             # both products wide, add, ...
            c_new = requant(wide)            # ... round once
            tanh_c = ht(c_new)
            h_new = requant(o * tanh_c)

            h_s[li][...] = h_new
            c_s[li][...] = c_new
            # Layer-to-layer stream: layer li's step-t hidden state feeds
            # layer li+1 at the same step, staying in VMEM/registers — no
            # HBM round-trip between layers.
            inp = h_new.astype(carrier)

        out_ref[0] = inp.astype(out_ref.dtype)   # final layer's h_t

        @pl.when(t == t_len - 1)
        def _():
            for li in range(n):
                h_fin[li][...] = h_s[li][...]
                c_fin[li][...] = c_s[li][...]

    return kernel


def _qlstm_pallas(x_int, w_xs, w_hs, b_wides, h0s, c0s, *,
                  cfg: FixedPointConfig, hs_method: str, hs_slope_shift: int,
                  hs_bound: float, ht_min: float, ht_max: float,
                  compute_unit: str, batch_block: Optional[int],
                  interpret: bool):
    """Shared driver behind both public entries: one ``pallas_call`` over
    ``len(w_hs)`` fused layers, returning ``(out_seq, h_fin, c_fin)`` with
    the per-layer final state as tuples."""
    t_len, bsz, m = x_int.shape
    n = len(w_hs)
    hdim = w_hs[0].shape[0]
    bb = batch_block or min(bsz, 128)
    pad = (-bsz) % bb
    if pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, pad), (0, 0)))
        # Padding rows start from (and produce) garbage-free zero state;
        # they are sliced away before return either way.
        h0s = tuple(jnp.pad(h, ((0, pad), (0, 0))) for h in h0s)
        c0s = tuple(jnp.pad(c, ((0, pad), (0, 0))) for c in c0s)
    bsz_p = bsz + pad
    nb = bsz_p // bb

    kernel = _make_kernel(cfg, hdim, hs_method, hs_slope_shift, hs_bound,
                          ht_min, ht_max, compute_unit, t_len, n)
    resident = lambda bi, t: (0, 0)                    # fetched once, stays
    per_block = lambda bi, t: (bi, 0)                  # constant across t
    in_specs = [pl.BlockSpec((1, bb, m), lambda bi, t: (t, bi, 0))]
    in_specs += [pl.BlockSpec(w.shape, resident) for w in w_xs]
    in_specs += [pl.BlockSpec(w.shape, resident) for w in w_hs]
    in_specs += [pl.BlockSpec((1, 4 * hdim), resident)] * n
    in_specs += [pl.BlockSpec((bb, hdim), per_block)] * (2 * n)
    out_specs = [pl.BlockSpec((1, bb, hdim), lambda bi, t: (t, bi, 0))]
    out_specs += [pl.BlockSpec((bb, hdim), per_block)] * (2 * n)
    out_shape = [jax.ShapeDtypeStruct((t_len, bsz_p, hdim), x_int.dtype)]
    out_shape += [jax.ShapeDtypeStruct((bsz_p, hdim), jnp.int32)] * (2 * n)
    outs = pl.pallas_call(
        kernel,
        grid=(nb, t_len),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bb, hdim), jnp.int32)] * (2 * n),
        interpret=interpret,
    )(x_int, *w_xs, *w_hs,
      *(b.reshape(1, -1).astype(jnp.int32) for b in b_wides),
      *(h.astype(jnp.int32) for h in h0s),
      *(c.astype(jnp.int32) for c in c0s))
    out = outs[0][:, :bsz]
    h_fin = tuple(o[:bsz] for o in outs[1:1 + n])
    c_fin = tuple(o[:bsz] for o in outs[1 + n:])
    return out, h_fin, c_fin


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "batch_block",
                     "interpret", "return_state"))
def qlstm_seq_pallas(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                     *, cfg: FixedPointConfig,
                     hs_method: str = "arithmetic",
                     hs_slope_shift: int = 3, hs_bound: float = 3.0,
                     ht_min: float = -1.0, ht_max: float = 1.0,
                     compute_unit: str = "mxu",
                     batch_block: Optional[int] = None,
                     interpret: bool = True,
                     h0: Optional[Array] = None, c0: Optional[Array] = None,
                     return_state: bool = False):
    """Run the fused kernel for one layer.

    x_int: (T, B, M) integer codes (storage dtype of cfg);
    w_x: (M, 4H); w_h: (H, 4H); b_wide: (4H,) int32.
    h0/c0: optional (B, H) int32 initial carry (zeros when omitted — the
    accelerator's reset state), seeded into the VMEM state scratch at
    t == 0; bit-exact with ``kernels/ref.qlstm_seq_ref(h0, c0)``.
    Returns (T, B, H) codes in the storage dtype; with
    ``return_state=True``, ``(out, (h_last, c_last))`` so the caller can
    resume the next window where this one left off.
    """
    _, bsz, _ = x_int.shape
    hdim = w_h.shape[0]
    if h0 is None:
        h0 = jnp.zeros((bsz, hdim), jnp.int32)
    if c0 is None:
        c0 = jnp.zeros((bsz, hdim), jnp.int32)
    out, (h_f,), (c_f,) = _qlstm_pallas(
        x_int, (w_x,), (w_h,), (b_wide,), (h0,), (c0,),
        cfg=cfg, hs_method=hs_method, hs_slope_shift=hs_slope_shift,
        hs_bound=hs_bound, ht_min=ht_min, ht_max=ht_max,
        compute_unit=compute_unit, batch_block=batch_block,
        interpret=interpret)
    if return_state:
        return out, (h_f, c_f)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "hs_method", "hs_slope_shift", "hs_bound",
                     "ht_min", "ht_max", "compute_unit", "batch_block",
                     "interpret"))
def qlstm_seq_multilayer_pallas(x_int: Array, w_xs: Tuple[Array, ...],
                                w_hs: Tuple[Array, ...],
                                b_wides: Tuple[Array, ...],
                                h0s: Tuple[Array, ...],
                                c0s: Tuple[Array, ...], *,
                                cfg: FixedPointConfig,
                                hs_method: str = "arithmetic",
                                hs_slope_shift: int = 3,
                                hs_bound: float = 3.0,
                                ht_min: float = -1.0, ht_max: float = 1.0,
                                compute_unit: str = "mxu",
                                batch_block: Optional[int] = None,
                                interpret: bool = True):
    """The whole LSTM stack, fused and stateful, in ONE ``pallas_call``.

    x_int: (T, B, M) integer codes; ``w_xs``/``w_hs``/``b_wides`` are
    per-layer tuples (layer 0's w_x is (M, 4H), deeper layers' (H, 4H);
    every w_h is (H, 4H), every b_wide (4H,) int32); ``h0s``/``c0s`` are
    the per-layer (B, H) int32 carry (``core.qlstm.init_int_state`` split
    into its h and c halves for a fresh stream).

    Every layer's (h, c) lives in VMEM scratch for the whole call and
    layer *l*'s step-t output feeds layer *l+1* at the same step without
    leaving the chip — unlike the layered Python loop, which launches one
    kernel per layer and round-trips the full (T, B, H) sequence through
    HBM between layers.

    Returns ``(out, state)``: out is the final layer's (T, B, H) hidden
    codes in the storage dtype; ``state`` is the per-layer
    ``((h_last, c_last), ...)`` int32 carry after the last step —
    bit-exact with threading ``kernels/ref.qlstm_seq_ref(h0, c0,
    return_state=True)`` through the stack layer by layer.
    """
    n = len(w_hs)
    if not (len(w_xs) == len(b_wides) == len(h0s) == len(c0s) == n):
        raise ValueError(
            f"per-layer tuples disagree on the layer count: "
            f"w_xs={len(w_xs)}, w_hs={n}, b_wides={len(b_wides)}, "
            f"h0s={len(h0s)}, c0s={len(c0s)}")
    out, h_fin, c_fin = _qlstm_pallas(
        x_int, tuple(w_xs), tuple(w_hs), tuple(b_wides), tuple(h0s),
        tuple(c0s),
        cfg=cfg, hs_method=hs_method, hs_slope_shift=hs_slope_shift,
        hs_bound=hs_bound, ht_min=ht_min, ht_max=ht_max,
        compute_unit=compute_unit, batch_block=batch_block,
        interpret=interpret)
    return out, tuple(zip(h_fin, c_fin))
