"""Public jit'd wrappers over the Pallas kernels.

Dispatch policy: kernels run in ``interpret=True`` on CPU (bit-exact
execution of the kernel body — the validation mode for this container) and
compiled on TPU.  ``use_kernel=False`` falls back to the pure-jnp oracle,
which is also what the multi-device pjit graphs use (Pallas kernels are
per-core; under shard_map they'd run per shard — LSTM batch shards are
embarrassingly parallel so both paths exist).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.accelerator import AcceleratorConfig, resolve_model
from repro.core.fixed_point import FixedPointConfig
from repro.core.qlstm import QLSTMConfig
from repro.kernels import ref
from repro.kernels.hard_act import hard_sigmoid_star_pallas, hard_tanh_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def qlstm_seq(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
              model: QLSTMConfig, accel: Optional[AcceleratorConfig] = None,
              use_kernel: bool = True) -> Array:
    """Time-major quantised LSTM layer: (T, B, M) codes -> (T, B, H) codes.

    Thin layer-level wrapper over the layered engines of the backend
    registry (`repro/backends/`): the fused Pallas kernel, or the pure-jnp
    oracle with ``use_kernel=False``.  Both implement exactly the pipelined
    (late-rounding) ALU with the hard activations; any other Table-2 point
    (per-step baseline ALU, LUT activations) raises ``BackendUnsupported``
    — run it through ``core.qlstm.forward_int`` / ``Accelerator.infer``
    (the xla engine) instead."""
    from repro import backends
    accel = accel or AcceleratorConfig()
    m = resolve_model(model, accel, warn=False)
    reason = backends.common.supports_fused(m, accel)
    if reason is not None:
        raise backends.BackendUnsupported(
            f"qlstm_seq runs the fused layered datapath only: {reason}")
    name = "pallas" if use_kernel else "ref"
    return backends.get(name).layer(x_int, w_x, w_h, b_wide, m, accel)


def quant_matmul(x_int8: Array, w_int8: Array, use_kernel: bool = True,
                 block=(128, 128, 128)) -> Array:
    """(M,K) x (K,N) int8 -> int32 accumulator."""
    if not use_kernel:
        return ref.quant_matmul_ref(x_int8, w_int8)
    return quant_matmul_pallas(x_int8, w_int8, out_mode="int32",
                               block=block, interpret=_interpret())


def quant_matmul_requant(x_int: Array, w_int: Array, cfg: FixedPointConfig,
                         use_kernel: bool = True, block=(128, 128, 128)) -> Array:
    """Fixed-point matmul with the fused S5 requantisation."""
    if not use_kernel:
        return ref.quant_matmul_requant_ref(x_int, w_int, cfg)
    return quant_matmul_pallas(x_int, w_int, out_mode="requant", cfg=cfg,
                               block=block, interpret=_interpret())


def hard_sigmoid_star_int(x_int: Array, cfg: FixedPointConfig,
                          method: str = "arithmetic", slope_shift: int = 3,
                          bound: float = 3.0, use_kernel: bool = True) -> Array:
    """Integer HardSigmoid* (paper C2), any shape of codes in ``cfg``; the
    three methods (arithmetic | 1to1 | step) are bit-identical."""
    if not use_kernel:
        return ref.hard_act_ref(x_int, cfg, method, slope_shift, bound)
    shape = x_int.shape
    x2 = x_int.reshape(-1, shape[-1]) if x_int.ndim != 2 else x_int
    out = hard_sigmoid_star_pallas(x2, cfg=cfg, method=method,
                                   slope_shift=slope_shift, bound=bound,
                                   interpret=_interpret())
    return out.reshape(shape)


def hard_tanh_int(x_int: Array, cfg: FixedPointConfig, min_val: float = -1.0,
                  max_val: float = 1.0, use_kernel: bool = True) -> Array:
    """Integer HardTanh (paper C2): clip the codes at the quantised
    [min_val, max_val] thresholds."""
    if not use_kernel:
        return ref.hard_tanh_ref(x_int, cfg, min_val, max_val)
    shape = x_int.shape
    x2 = x_int.reshape(-1, shape[-1]) if x_int.ndim != 2 else x_int
    out = hard_tanh_pallas(x2, cfg=cfg, min_val=min_val, max_val=max_val,
                           interpret=_interpret())
    return out.reshape(shape)


def mha_flash(q: Array, k: Array, v: Array, *, causal: bool = True,
              window=None, scale=None, block_q: int = 128,
              block_k: int = 128, use_kernel: bool = True) -> Array:
    """Multi-head (GQA) wrapper over the Pallas flash-attention kernel.

    q: (B, T, H, hd); k, v: (B, S, KV, hd) -> (B, T, H, hd)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    kr = jnp.repeat(k, g, axis=2) if g > 1 else k
    vr = jnp.repeat(v, g, axis=2) if g > 1 else v
    q2 = q.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    k2 = kr.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    v2 = vr.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    if use_kernel:
        o = flash_attention_pallas(q2, k2, v2, causal=causal, window=window,
                                   scale=scale, block_q=block_q,
                                   block_k=block_k, interpret=_interpret())
    else:
        o = ref.attention_ref(q2, k2, v2, causal=causal, window=window,
                              scale=scale)
    return o.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
