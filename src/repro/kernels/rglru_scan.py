"""Fused RG-LRU sequence kernel — the paper's pipelined-recurrence idea
(C3) applied to RecurrentGemma's linear recurrence.

Like ``qlstm_cell``: grid = (batch_blocks, T) with T minor, the recurrent
state h lives in VMEM scratch across timesteps, and the Pallas pipeline
overlaps the next timestep's (a_t, b_t) HBM→VMEM DMA with the current
step's VPU work.  The gates/decays are precomputed OUTSIDE the kernel
(they are pointwise in x_t — embarrassingly parallel MXU work); the kernel
fuses only the serial part:

    h_t = a_t * h_{t-1} + b_t,   a_t = exp(log_a_t)

For train/prefill the pure-JAX associative scan (log-depth) is usually the
better shape on TPU; this kernel is the LATENCY-OPTIMAL form (exact
sequential dependency, zero log-depth overhead) used for short sequences
and as the decode building block — the same trade the paper makes between
parallel ALUs and the pipelined single ALU (§4.3).

Oracle: ``kernels/ref.py::rglru_seq_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(loga_ref, b_ref, o_ref, h_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = jnp.exp(loga_ref[0].astype(jnp.float32))
    h_new = a * h_ref[...] + b_ref[0].astype(jnp.float32)
    h_ref[...] = h_new
    o_ref[0] = h_new.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_block", "interpret"))
def rglru_seq_pallas(log_a: Array, b: Array, *, batch_block: int = 128,
                     interpret: bool = True) -> Array:
    """log_a, b: (T, B, W) — returns h: (T, B, W) with h_0 = b_0 (zero
    initial state)."""
    t_len, bsz, w = log_a.shape
    bb = min(batch_block, bsz)
    pad = (-bsz) % bb
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nb = (bsz + pad) // bb
    out = pl.pallas_call(
        _kernel,
        grid=(nb, t_len),
        in_specs=[pl.BlockSpec((1, bb, w), lambda bi, t: (t, bi, 0)),
                  pl.BlockSpec((1, bb, w), lambda bi, t: (t, bi, 0))],
        out_specs=pl.BlockSpec((1, bb, w), lambda bi, t: (t, bi, 0)),
        out_shape=jax.ShapeDtypeStruct((t_len, bsz + pad, w), b.dtype),
        scratch_shapes=[pltpu.VMEM((bb, w), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
    return out[:, :bsz]
