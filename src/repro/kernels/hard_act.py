"""Elementwise hard-activation kernel — C2's three HardSigmoid* methods as
VPU lowerings, plus HardTanh.

methods:
  arithmetic — truncating shift + add, two saturation selects (the paper's
               two-sequential-ops datapath).
  step       — unrolled compile-time comparator cascade (the 14-entry merged
               LUT); pure selects, no gather.
  1to1       — full-table gather.  Supported in interpret mode and on TPU via
               one-hot matmul contraction; on real TPUs a 256-wide gather per
               element is VPU-hostile — which is this hardware's version of
               the paper's finding that the best method depends on the
               configuration (Table 1; see benchmarks/bench_activations.py).

Oracle: ``kernels/ref.py::hard_act_ref`` (bit-exact for every method).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig

Array = jax.Array


def _make_kernel(cfg: FixedPointConfig, method: str, slope_shift: int,
                 bound: float):
    spec = hard_act.HardSigmoidStarSpec(cfg, slope_shift, bound)

    def body(x):
        x = x.astype(jnp.int32)
        if method == "arithmetic":
            lin = jnp.clip((x >> spec.slope_shift) + spec.half_int,
                           0, spec.one_int)
            y = jnp.where(x < -spec.bound_int, 0,
                          jnp.where(x >= spec.bound_int, spec.one_int, lin))
            return jnp.clip(y, cfg.int_min, cfg.int_max)
        if method == "step":
            thresholds, outputs = hard_act.step_table(spec)
            y = jnp.full_like(x, int(outputs[0]))
            for thr, prev, nxt in zip(thresholds, outputs[:-1], outputs[1:]):
                y = y + jnp.where(x >= int(thr), int(nxt) - int(prev), 0)
            return y
        raise ValueError(method)

    if method == "1to1":
        # The table is a kernel INPUT (VMEM-resident across grid steps);
        # lookup via one-hot matmul contraction — the TPU-safe gather.
        def kernel(x_ref, t_ref, o_ref):
            x = x_ref[...].astype(jnp.int32)
            idx = x - cfg.int_min
            n = t_ref.shape[-1]
            onehot = (idx[..., None] == jax.lax.broadcasted_iota(
                jnp.int32, idx.shape + (n,), idx.ndim)).astype(jnp.int32)
            o_ref[...] = jnp.sum(onehot * t_ref[...][0], axis=-1).astype(o_ref.dtype)
        return kernel

    def kernel(x_ref, o_ref):
        o_ref[...] = body(x_ref[...]).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "method", "slope_shift", "bound", "block",
                     "interpret"))
def hard_sigmoid_star_pallas(x_int: Array, *, cfg: FixedPointConfig,
                             method: str = "arithmetic",
                             slope_shift: int = 3, bound: float = 3.0,
                             block: int = 1024,
                             interpret: bool = True) -> Array:
    """x_int: (rows, cols) integer codes -> codes (same dtype)."""
    rows, cols = x_int.shape
    brows = min(block, rows)
    pad = (-rows) % brows
    if pad:
        x_int = jnp.pad(x_int, ((0, pad), (0, 0)))
    in_specs = [pl.BlockSpec((brows, cols), lambda i: (i, 0))]
    args = [x_int]
    if method == "1to1":
        spec = hard_act.HardSigmoidStarSpec(cfg, slope_shift, bound)
        table = jnp.asarray(hard_act.one_to_one_table(spec)).reshape(1, -1)
        in_specs.append(pl.BlockSpec(table.shape, lambda i: (0, 0)))
        args.append(table)
    out = pl.pallas_call(
        _make_kernel(cfg, method, slope_shift, bound),
        grid=((rows + pad) // brows,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((brows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, cols), x_int.dtype),
        interpret=interpret,
    )(*args)
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("cfg", "min_val", "max_val",
                                             "block", "interpret"))
def hard_tanh_pallas(x_int: Array, *, cfg: FixedPointConfig,
                     min_val: float = -1.0, max_val: float = 1.0,
                     block: int = 1024, interpret: bool = True) -> Array:
    """HardTanh on (rows, cols) integer codes: clip at the quantised
    [min_val, max_val] thresholds (the same comparator pair the fused
    cell kernel uses)."""
    import numpy as np
    lo = int(np.clip(np.floor(min_val * (1 << cfg.frac_bits) + 0.5),
                     cfg.int_min, cfg.int_max))
    hi = int(np.clip(np.floor(max_val * (1 << cfg.frac_bits) + 0.5),
                     cfg.int_min, cfg.int_max))

    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.clip(x_ref[...].astype(jnp.int32), lo, hi).astype(o_ref.dtype)

    rows, cols = x_int.shape
    brows = min(block, rows)
    pad = (-rows) % brows
    if pad:
        x_int = jnp.pad(x_int, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        kernel,
        grid=((rows + pad) // brows,),
        in_specs=[pl.BlockSpec((brows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((brows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, cols), x_int.dtype),
        interpret=interpret,
    )(x_int)
    return out[:rows]
