"""Tiled W8A8 matmul kernel — the paper's C1 (narrow integer arithmetic,
late rounding) at LM scale.

Pallas grid (M/bm, N/bn, K/bk), int8 tiles in VMEM, int32 accumulator in
VMEM scratch across the K axis (the minor grid dim), and — exactly like the
paper's pipeline stage S5 — the accumulator is requantised ONCE, after the
final K step:

  * ``out_mode="int32"``: raw accumulator (float scales applied outside —
    the generic W8A8 path used by the LM layers).
  * ``out_mode="requant"``: fused round-half-up shift back to (a,b) codes —
    the paper-faithful fixed-point pipeline.

The grid pipeline double-buffers the next (x, w) tiles' HBM→VMEM DMA behind
the current MXU matmul: the TPU re-expression of load ∥ multiply ∥ accumulate.

Oracle: ``kernels/ref.py::quant_matmul_ref`` / ``quant_matmul_requant_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fixed_point import FixedPointConfig, product_config

Array = jax.Array


def _make_kernel(out_mode: str, cfg: Optional[FixedPointConfig]):
    if out_mode == "requant":
        prod = product_config(cfg, cfg)
        shift = prod.frac_bits - cfg.frac_bits
        half = 1 << (shift - 1)
        lo, hi = cfg.int_min, cfg.int_max

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

        @pl.when(k == pl.num_programs(2) - 1)
        def _():
            acc = acc_ref[...]
            if out_mode == "requant":
                acc = jnp.clip((acc + half) >> shift, lo, hi)
            o_ref[...] = acc.astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("out_mode", "cfg", "block", "interpret"))
def quant_matmul_pallas(x: Array, w: Array, *,
                        out_mode: str = "int32",
                        cfg: Optional[FixedPointConfig] = None,
                        block: Tuple[int, int, int] = (128, 128, 128),
                        interpret: bool = True) -> Array:
    """x: (M, K) int8, w: (K, N) int8 -> (M, N) int32 (or int8 codes when
    out_mode='requant').  Dims are padded up to the block multiples; MXU
    tiles want 128-multiples (DESIGN.md: MXU-fill is the DSP-occupancy
    analogue)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    mp, np_, kp = m + pm, n + pn, k + pk

    out_dtype = jnp.int32 if out_mode == "int32" else cfg.storage_dtype
    out = pl.pallas_call(
        _make_kernel(out_mode, cfg),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w)
    return out[:m, :n]
