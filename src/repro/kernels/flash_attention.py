"""Pallas flash-attention kernel — the C3 pipeline philosophy applied to
the LM archs' dominant prefill hot-spot.

Grid (batch*heads, q_blocks, kv_blocks) with the kv axis minor: the online-
softmax state (m, l, acc) lives in VMEM scratch across kv steps — exactly
the qlstm kernel's accumulate-wide/round-once structure, with softmax
renormalisation in place of the fixed-point requant.  The Pallas pipeline
double-buffers the next (k, v) tiles' HBM→VMEM DMA behind the current
block's MXU matmuls.

Causality: kv blocks strictly above the diagonal are skipped with
``pl.when`` (compute suppressed; DMA still pipelined — on TPU the fetch
overlaps the previous block's compute, so skipped blocks cost ~0 MXU time).

Oracle: ``kernels/ref.py::attention_ref`` (fp32 softmax attention).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _make_kernel(bq: int, bk: int, hd: int, scale: float, causal: bool,
                 window: Optional[int], s_valid: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        kj = pl.program_id(2)
        qi = pl.program_id(1)

        @pl.when(kj == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        def compute():
            q = q_ref[0].astype(jnp.float32)      # (bq, hd)
            k = k_ref[0].astype(jnp.float32)      # (bk, hd)
            v = v_ref[0].astype(jnp.float32)
            sc = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # (bq, bk)
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos < s_valid   # padded kv columns are invalid
            if causal:
                mask = mask & (kpos <= qpos)
            if window is not None:
                mask = mask & ((qpos - kpos) < window)
            sc = jnp.where(mask, sc, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, sc.max(-1))
            p = jnp.exp(sc - m_new[:, None])
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + p.sum(-1)
            acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        if causal:
            # skip blocks strictly above the diagonal
            pl.when(kj * bk <= qi * bq + (bq - 1))(compute)
        else:
            compute()

        @pl.when(kj == pl.num_programs(2) - 1)
        def _():
            l = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> Array:
    """q: (BH, T, hd), k/v: (BH, S, hd) -> (BH, T, hd).

    Head grouping (GQA) is the caller's job (see ops.mha_flash)."""
    bh, t, hd = q.shape
    s = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    bq, bk = min(block_q, t), min(block_k, s)
    tp, sp = -t % bq, -s % bk
    if tp:
        q = jnp.pad(q, ((0, 0), (0, tp), (0, 0)))
    if sp:  # padded kv columns are masked inside the kernel (kpos < s)
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0)))
    nq, nk = (t + tp) // bq, (s + sp) // bk
    out = pl.pallas_call(
        _make_kernel(bq, bk, hd, scale, causal, window, s),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t + tp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :t]
