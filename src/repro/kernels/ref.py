"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *bit-exact* specification its kernel must match
(`tests/test_kernels.py` sweeps shapes/dtypes and asserts exact equality for
integer paths / allclose for float paths).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# qlstm_cell kernel oracle
# ---------------------------------------------------------------------------

def qlstm_seq_ref(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                  cfg: FixedPointConfig,
                  hs_slope_shift: int = 3, hs_bound: float = 3.0,
                  ht_min: float = -1.0, ht_max: float = 1.0,
                  h0: Array = None, c0: Array = None,
                  return_state: bool = False) -> Array:
    """Time-major quantised LSTM sequence — the paper's pipelined datapath.

    x_int:  (T, B, M) integer codes in cfg (int8 carrier ok).
    w_x:    (M, 4H) codes; w_h: (H, 4H) codes; gate order [i, f, g, o].
    b_wide: (4H,) codes at the PRODUCT precision (2a frac bits, int32).
    h0/c0:  optional (B, H) int32 initial carry (zeros when omitted — the
            accelerator's reset state); the cross-window carry of
            ``repro.serving`` stateful streaming.
    Returns (T, B, H) int32 codes of every hidden state; with
    ``return_state=True``, ``(hs, (h_last, c_last))`` so the caller can
    carry the final (h, c) into the next window.
    """
    prod = fxp.product_config(cfg, cfg)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    t_len, bsz, _ = x_int.shape
    hdim = w_h.shape[0]

    def step(carry, x_t):
        h, c = carry
        acc = (x_t.astype(jnp.int32) @ w_x.astype(jnp.int32)
               + h.astype(jnp.int32) @ w_h.astype(jnp.int32)
               + b_wide.astype(jnp.int32))
        pre = fxp.requantize(acc, prod, cfg)
        i = hard_act.hs_star_int_arithmetic(pre[:, :hdim], spec)
        f = hard_act.hs_star_int_arithmetic(pre[:, hdim:2 * hdim], spec)
        g = hard_act.hard_tanh_int(pre[:, 2 * hdim:3 * hdim], cfg, ht_min, ht_max)
        o = hard_act.hs_star_int_arithmetic(pre[:, 3 * hdim:], spec)
        wide = f * c + i * g
        c_new = fxp.requantize(wide, prod, cfg)
        tanh_c = hard_act.hard_tanh_int(c_new, cfg, ht_min, ht_max)
        h_new = fxp.requantize(o * tanh_c, prod, cfg)
        return (h_new, c_new), h_new

    h0 = jnp.zeros((bsz, hdim), jnp.int32) if h0 is None \
        else h0.astype(jnp.int32)
    c0 = jnp.zeros((bsz, hdim), jnp.int32) if c0 is None \
        else c0.astype(jnp.int32)
    (h_last, c_last), hs = jax.lax.scan(step, (h0, c0),
                                        x_int.astype(jnp.int32))
    if return_state:
        return hs, (h_last, c_last)
    return hs


# ---------------------------------------------------------------------------
# quantised GRU oracle (cells/gru.py general datapath must match bit-exact)
# ---------------------------------------------------------------------------

def qgru_seq_ref(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                 cfg: FixedPointConfig,
                 hs_slope_shift: int = 3, hs_bound: float = 3.0,
                 ht_min: float = -1.0, ht_max: float = 1.0,
                 h0: Array = None):
    """Time-major quantised GRU sequence — pipelined datapath, hard acts.

    x_int:  (T, B, M) integer codes in cfg.
    w_x:    (M, 3H) codes; w_h: (H, 3H) codes; gate order [r, z, n].
    b_wide: (3H,) codes at the PRODUCT precision (2a frac bits, int32).
    h0:     optional (B, H) int32 initial carry (zeros when omitted).

    The candidate's recurrent half ``h W_hn`` exits its own accumulator
    (one S5 rounding), is gated by ``r`` back to the wide format, added to
    the 1.0-lifted ``x W_xn + b_n`` half, and rounded once; the state mix
    ``(1-z)*n + z*h`` likewise rounds once.  Returns
    ``((T, B, H) int32 hidden codes, h_last)``.
    """
    prod = fxp.product_config(cfg, cfg)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    _, bsz, _ = x_int.shape
    hdim = w_h.shape[0]
    one = 1 << cfg.frac_bits

    def step(h, x_t):
        rz_acc = (x_t.astype(jnp.int32) @ w_x[:, :2 * hdim].astype(jnp.int32)
                  + h @ w_h[:, :2 * hdim].astype(jnp.int32)
                  + b_wide[:2 * hdim].astype(jnp.int32))
        rz = fxp.requantize(rz_acc, prod, cfg)
        r = hard_act.hs_star_int_arithmetic(rz[:, :hdim], spec)
        z = hard_act.hs_star_int_arithmetic(rz[:, hdim:], spec)
        nh = fxp.requantize(h @ w_h[:, 2 * hdim:].astype(jnp.int32),
                            prod, cfg)
        nx = fxp.requantize(
            x_t.astype(jnp.int32) @ w_x[:, 2 * hdim:].astype(jnp.int32)
            + b_wide[2 * hdim:].astype(jnp.int32), prod, cfg)
        n_pre = fxp.requantize(nx * one + r * nh, prod, cfg)
        n = hard_act.hard_tanh_int(n_pre, cfg, ht_min, ht_max)
        h_new = fxp.requantize((one - z) * n + z * h, prod, cfg)
        return h_new, h_new

    h0 = jnp.zeros((bsz, hdim), jnp.int32) if h0 is None \
        else h0.astype(jnp.int32)
    h_last, hs = jax.lax.scan(step, h0, x_int.astype(jnp.int32))
    return hs, h_last


# ---------------------------------------------------------------------------
# quantised RG-LRU oracle (cells/rglru.py general datapath, bit-exact)
# ---------------------------------------------------------------------------

def qrglru_seq_ref(x_int: Array, w_x: Array, w_a: Array, w_i: Array,
                   b_x: Array, b_a: Array, b_i: Array, lam_q: Array,
                   cfg: FixedPointConfig,
                   hs_slope_shift: int = 3, hs_bound: float = 3.0,
                   h0: Array = None):
    """Time-major quantised RG-LRU sequence — pipelined datapath, hard acts.

    x_int:        (T, B, M) integer codes in cfg.
    w_x/w_a/w_i:  (M, H) codes (value, recurrence-gate, input-gate paths).
    b_x/b_a/b_i:  (H,) codes at the PRODUCT precision (int32).
    lam_q:        (H,) codes in cfg — the pre-gated decay parameter
                  ``quantize(gate(lambda))``, baked at quantisation time.
    h0:           optional (B, H) int32 initial carry (zeros when omitted).

    The fixed-point redefinition of Griffin's recurrence (input-only
    gates, ``a = 1 - r*lambda`` decay, convex ``a*h + (1-a)*(i*x)`` mix):

        xp = S5( x W_x + b_x )
        r  = gate( S5( x W_a + b_a ) )
        i  = gate( S5( x W_i + b_i ) )
        a  = 1 - S5( r * lam_q )
        gx = S5( i * xp )
        h' = S5( a*h + (1-a)*gx )

    Returns ``((T, B, H) int32 hidden codes, h_last)``.
    """
    prod = fxp.product_config(cfg, cfg)
    spec = hard_act.HardSigmoidStarSpec(cfg, hs_slope_shift, hs_bound)
    _, bsz, _ = x_int.shape
    hdim = w_x.shape[1]
    one = 1 << cfg.frac_bits
    lam32 = lam_q.astype(jnp.int32)

    def step(h, x_t):
        x32 = x_t.astype(jnp.int32)
        xp = fxp.requantize(x32 @ w_x.astype(jnp.int32)
                            + b_x.astype(jnp.int32), prod, cfg)
        r = hard_act.hs_star_int_arithmetic(
            fxp.requantize(x32 @ w_a.astype(jnp.int32)
                           + b_a.astype(jnp.int32), prod, cfg), spec)
        i = hard_act.hs_star_int_arithmetic(
            fxp.requantize(x32 @ w_i.astype(jnp.int32)
                           + b_i.astype(jnp.int32), prod, cfg), spec)
        a = one - fxp.requantize(r * lam32, prod, cfg)
        gx = fxp.requantize(i * xp, prod, cfg)
        h_new = fxp.requantize(a * h + (one - a) * gx, prod, cfg)
        return h_new, h_new

    h0 = jnp.zeros((bsz, hdim), jnp.int32) if h0 is None \
        else h0.astype(jnp.int32)
    h_last, hs = jax.lax.scan(step, h0, x_int.astype(jnp.int32))
    return hs, h_last


# ---------------------------------------------------------------------------
# quant_matmul kernel oracle
# ---------------------------------------------------------------------------

def quant_matmul_ref(x: Array, w: Array) -> Array:
    """int8 x int8 -> int32 full-precision accumulation (late rounding)."""
    return jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def quant_matmul_requant_ref(x: Array, w: Array, cfg: FixedPointConfig) -> Array:
    """Fixed-point mode: accumulate wide, single round-half-up shift back to
    (a,b) — pipeline stage S5."""
    prod = fxp.product_config(cfg, cfg)
    return fxp.requantize(quant_matmul_ref(x, w), prod, cfg)


# ---------------------------------------------------------------------------
# hard_act kernel oracle
# ---------------------------------------------------------------------------

def hard_act_ref(x_int: Array, cfg: FixedPointConfig, method: str = "arithmetic",
                 slope_shift: int = 3, bound: float = 3.0) -> Array:
    """Integer HardSigmoid* oracle (all three methods, bit-identical)."""
    spec = hard_act.HardSigmoidStarSpec(cfg, slope_shift, bound)
    return hard_act.hs_star_int(x_int, spec, method)


def hard_tanh_ref(x_int: Array, cfg: FixedPointConfig,
                  min_val: float = -1.0, max_val: float = 1.0) -> Array:
    """Integer HardTanh oracle: clip at the quantised thresholds."""
    return hard_act.hard_tanh_int(x_int, cfg, min_val, max_val)


# ---------------------------------------------------------------------------
# flash_attention kernel oracle
# ---------------------------------------------------------------------------

def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window=None, scale=None) -> Array:
    """fp32 softmax attention.  q: (BH, T, hd), k/v: (BH, S, hd)."""
    bh, t, hd = q.shape
    s = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    sc = jnp.einsum("bqh,bsh->bqs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & ((qpos - kpos) < window)
    sc = jnp.where(mask[None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqs,bsh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# rglru_scan kernel oracle
# ---------------------------------------------------------------------------

def rglru_seq_ref(log_a: Array, b: Array) -> Array:
    """h_t = exp(log_a_t) * h_{t-1} + b_t, h_{-1} = 0.  (T, B, W) in fp32."""
    def step(h, ab):
        la, bb = ab
        h = jnp.exp(la.astype(jnp.float32)) * h + bb.astype(jnp.float32)
        return h, h

    h0 = jnp.zeros(b.shape[1:], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (log_a, b))
    return hs.astype(b.dtype)
