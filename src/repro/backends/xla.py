"""``xla`` backend — the ``lax.scan`` integer datapath
(`core/qlstm.forward_int`).

The most general engine: every Table-2 point runs here, including the
non-pipelined per-step ALU (Algorithm 1 as printed — the baseline [15]
datapath) and the 256-entry LUT Sigmoid/Tanh activations.  For pipelined
configurations with hard activations it is bit-identical to the ``ref`` and
``pallas`` engines."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.backends import Backend, register
from repro.backends.common import run_slots_via_state
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig, forward_int, forward_int_stateful

Array = jax.Array

_GATES = ("hard_sigmoid_star", "lut_sigmoid", "sigmoid")
_CELLS = ("hard_tanh", "lut_tanh", "tanh")


def supports(model: QLSTMConfig, accel: AcceleratorConfig) -> Optional[str]:
    """None when the configuration has an integer datapath here (every
    Table-2 point does), else the reason it cannot run."""
    if model.acts.gate not in _GATES:
        return f"gate activation {model.acts.gate!r} has no integer datapath"
    if model.acts.cell not in _CELLS:
        return f"cell activation {model.acts.cell!r} has no integer datapath"
    return None


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    """Whole model, batch-major: (B, T, M) codes -> (B, P) codes."""
    return forward_int(qparams, x_int, model)


def run_stateful(qparams, x_int: Array, model: QLSTMConfig,
                 accel: AcceleratorConfig, state):
    """Whole model with cross-window (h, c) carry — (y_int, new_state)."""
    return forward_int_stateful(qparams, x_int, model, state)


BACKEND = register(Backend(
    name="xla", run=run, supports=supports, run_stateful=run_stateful,
    # Device-resident state via the XLA-level gather/scatter adapter.
    run_stateful_slots=functools.partial(run_slots_via_state, run_stateful)))
