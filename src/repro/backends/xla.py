"""``xla`` backend — the ``lax.scan`` general integer datapath of
whatever cell the model names (``repro.cells``; the LSTM instance is
`core/qlstm.forward_int`).

The most general engine: every Table-2 point of every registered cell
runs here, including the non-pipelined per-step ALU (Algorithm 1 as
printed — the baseline [15] datapath) and the 256-entry LUT Sigmoid/Tanh
activations.  For pipelined configurations with hard activations it is
bit-identical to the ``ref`` oracle (and, for the LSTM, the ``pallas``
engine)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.backends import Backend, register
from repro.backends.common import run_slots_via_state
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig

Array = jax.Array


def supports(model: QLSTMConfig, accel: AcceleratorConfig) -> Optional[str]:
    """None when the cell's general integer datapath covers the
    configuration (every Table-2 point does, for every registered cell),
    else the reason it cannot run."""
    from repro import cells  # lazy: avoids the cells -> kernels -> backends cycle
    return cells.get(model.cell).supports_int(model, accel)


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    """Whole model, batch-major: (B, T, M) codes -> (B, P) codes."""
    from repro import cells
    return cells.get(model.cell).run_int(qparams, x_int, model)


def run_stateful(qparams, x_int: Array, model: QLSTMConfig,
                 accel: AcceleratorConfig, state):
    """Whole model with an explicit cross-window carry — the cell spec's
    ``run_int_stateful``; returns (y_int, new_state)."""
    from repro import cells
    return cells.get(model.cell).run_int_stateful(qparams, x_int, model,
                                                  state)


BACKEND = register(Backend(
    name="xla", run=run, supports=supports, run_stateful=run_stateful,
    # Device-resident state via the XLA-level gather/scatter adapter.
    run_stateful_slots=functools.partial(run_slots_via_state, run_stateful)))
