"""``pallas`` backend — the fused TPU kernel (`kernels/qlstm_cell.py`).

Weights fetched once into VMEM and resident across all timesteps, input DMA
double-buffered against MXU/VPU compute, int32 accumulator with the single
S5 rounding.  Runs ``interpret=True`` off-TPU (bit-exact execution of the
kernel body — the validation mode for CPU containers) and compiled on TPU.

The engine is fully STATEFUL: the kernel seeds its per-layer (h, c) VMEM
scratch from the carried state at t == 0 and returns the final state, so
``run_stateful`` serves the ``repro.serving`` cross-window streaming
contract directly — and the whole-model paths (``run`` and
``run_stateful``) execute the entire LSTM stack in ONE fused
``qlstm_seq_multilayer_pallas`` call, streaming layer-to-layer in VMEM
instead of re-launching the kernel per layer from Python.

The ``1to1`` HardSigmoid* method is a full-LUT gather — the MXU/VPU kernel
lowers it to the bit-identical ``arithmetic`` form instead (the three
methods agree by construction; `core/hard_act.py`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backends import Backend, register
from repro.backends.common import dense_head, supports_fused
from repro.core.accelerator import AcceleratorConfig, sync_accelerator
from repro.core.qlstm import QLSTMConfig, check_int_state, init_int_state
from repro.kernels.qlstm_cell import (qlstm_seq_multilayer_pallas,
                                      qlstm_seq_pallas,
                                      qlstm_seq_slot_pallas)

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _kernel_args(model: QLSTMConfig, accel: AcceleratorConfig) -> dict:
    """The static kernel configuration shared by every entry point (with
    the 1to1 -> arithmetic HardSigmoid* lowering applied)."""
    acts = model.acts
    acc = sync_accelerator(model, accel)
    hs_method = "arithmetic" if acc.hs_method == "1to1" else acc.hs_method
    return dict(cfg=model.fxp, hs_method=hs_method,
                hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
                ht_min=acts.ht_min, ht_max=acts.ht_max,
                compute_unit=acc.compute_unit, interpret=_interpret())


def layer(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
          model: QLSTMConfig, accel: AcceleratorConfig) -> Array:
    """One fused LSTM layer, time-major: (T, B, M) codes -> (T, B, H)."""
    sd = model.fxp.storage_dtype
    out = qlstm_seq_pallas(
        x_int.astype(sd), w_x.astype(sd), w_h.astype(sd), b_wide,
        **_kernel_args(model, accel))
    return out.astype(jnp.int32)


def run_stateful(qparams, x_int: Array, model: QLSTMConfig,
                 accel: AcceleratorConfig, state):
    """Whole model with cross-window (h, c) carry — (y_int, new_state).

    The entire stack runs in ONE fused kernel launch: every layer's (h, c)
    stays resident in VMEM and layer *l*'s step-t output feeds layer *l+1*
    at the same step, with no per-layer HBM round-trip."""
    check_int_state(state, qparams)
    sd = model.fxp.storage_dtype
    h_t = jnp.swapaxes(x_int, 0, 1).astype(sd)          # time-major (T, B, M)
    layers = qparams["layers"]
    out, new_state = qlstm_seq_multilayer_pallas(
        h_t,
        tuple(p["w_x"].astype(sd) for p in layers),
        tuple(p["w_h"].astype(sd) for p in layers),
        tuple(p["b"] for p in layers),
        tuple(h for h, _ in state),
        tuple(c for _, c in state),
        **_kernel_args(model, accel))
    return dense_head(out[-1].astype(jnp.int32), qparams, model), new_state


def run_stateful_slots(qparams, x_int: Array, model: QLSTMConfig,
                       accel: AcceleratorConfig, table: Array,
                       gather_slots: Array, scatter_slots: Array):
    """Whole model with DEVICE-RESIDENT stream state — (y_int, new_table).

    The fused kernel gathers each batch row's per-layer carry from the
    state table at t == 0 and scatters the final (h, c) back at t == T-1,
    all inside one ``pallas_call`` — the host ships only integer inputs
    and the two (B,) slot-id vectors (table layout:
    ``kernels/qlstm_cell.qlstm_seq_slot_pallas``)."""
    sd = model.fxp.storage_dtype
    h_t = jnp.swapaxes(x_int, 0, 1).astype(sd)          # time-major (T, B, M)
    layers = qparams["layers"]
    out, new_table = qlstm_seq_slot_pallas(
        h_t, gather_slots, scatter_slots, table,
        tuple(p["w_x"].astype(sd) for p in layers),
        tuple(p["w_h"].astype(sd) for p in layers),
        tuple(p["b"] for p in layers),
        **_kernel_args(model, accel))
    return dense_head(out[-1].astype(jnp.int32), qparams, model), new_table


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    """Whole model, batch-major — the fused multi-layer kernel started
    from the zero reset carry."""
    y, _ = run_stateful(qparams, x_int, model, accel,
                        init_int_state(model, x_int.shape[0]))
    return y


BACKEND = register(Backend(name="pallas", run=run, supports=supports_fused,
                           layer=layer, run_stateful=run_stateful,
                           run_stateful_slots=run_stateful_slots))
