"""``pallas`` backend — the fused TPU kernel (`kernels/qlstm_cell.py`).

Weights fetched once into VMEM and resident across all timesteps, input DMA
double-buffered against MXU/VPU compute, int32 accumulator with the single
S5 rounding.  Runs ``interpret=True`` off-TPU (bit-exact execution of the
kernel body — the validation mode for CPU containers) and compiled on TPU.

The ``1to1`` HardSigmoid* method is a full-LUT gather — the MXU/VPU kernel
lowers it to the bit-identical ``arithmetic`` form instead (the three
methods agree by construction; `core/hard_act.py`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.backends import Backend, register
from repro.backends.common import run_layered, supports_fused
from repro.core.accelerator import AcceleratorConfig, sync_accelerator
from repro.core.qlstm import QLSTMConfig
from repro.kernels.qlstm_cell import qlstm_seq_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def layer(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
          model: QLSTMConfig, accel: AcceleratorConfig) -> Array:
    """One fused LSTM layer, time-major: (T, B, M) codes -> (T, B, H)."""
    acts = model.acts
    acc = sync_accelerator(model, accel)
    hs_method = "arithmetic" if acc.hs_method == "1to1" else acc.hs_method
    out = qlstm_seq_pallas(
        x_int.astype(model.fxp.storage_dtype),
        w_x.astype(model.fxp.storage_dtype),
        w_h.astype(model.fxp.storage_dtype),
        b_wide,
        cfg=model.fxp,
        hs_method=hs_method,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max,
        compute_unit=acc.compute_unit,
        interpret=_interpret())
    return out.astype(jnp.int32)


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    return run_layered(layer, qparams, x_int, model, accel)


# No run_stateful: the fused kernel initialises h0 = c0 = 0 in VMEM scratch,
# so it cannot resume a stream mid-sequence.  Stateful serving
# (repro.serving) resolves to the bit-identical layered ref oracle instead
# (core.accelerator.resolve_stateful_backend).
BACKEND = register(Backend(name="pallas", run=run, supports=supports_fused,
                           layer=layer))
