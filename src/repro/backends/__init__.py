"""Backend-dispatch registry for the quantised recurrent accelerator
datapath — cell-agnostic: each engine serves whatever cell the model's
``repro.cells`` spec names (LSTM, GRU, rGLRU).

Every execution engine behind ``Accelerator.infer``/``Accelerator.serve``
lives here; nothing outside this package imports ``forward_int`` or
``qlstm_seq_pallas`` directly.  Three engines are registered:

  * ``ref``    — the bit-exact pure-jnp oracle (`kernels/ref.py`, via the
                 cell spec's ``ref_layer``): explicit matmuls per step,
                 pipelined (late-rounding) ALU with the hard activations.
                 The specification the other two must match bit-for-bit.
  * ``pallas`` — the fused TPU kernel (`kernels/qlstm_cell.py`): weights
                 VMEM-resident, double-buffered input DMA, MXU or VPU
                 compute.  Pipelined ALU + hard activations, and only for
                 cells with a fused kernel (today the LSTM; GRU/rGLRU
                 resolve to ``xla``).
  * ``xla``    — the ``lax.scan`` datapath (the cell spec's
                 ``run_int_stateful``): supports every Table-2 point of
                 every cell, including the per-step (non-pipelined,
                 baseline [15]) ALU and the 256-entry LUT activations.

Selection is plan-driven (``core/accelerator.resolve_backend``): ``auto``
picks ``pallas`` when the configuration fits the fused kernel, else
``xla``; ``AcceleratorConfig.backend`` or the ``backend=`` argument of
``Accelerator.infer`` overrides explicitly.

A backend exposes

  run(qparams, x_int, model, accel) -> y_int      # whole model, batch-major
  layer(x_int, w_x, w_h, b_wide, model, accel)    # one layer, time-major
  supports(model, accel) -> Optional[str]         # None = ok, else reason

and, when it can carry recurrent state across calls (the
``repro.serving`` stateful-streaming contract),

  run_stateful(qparams, x_int, model, accel, state) -> (y_int, new_state)

where ``state`` is the cell's carry: per layer, a tuple of
``state_arity`` int32 ``(B, H)`` code arrays (the LSTM's (h, c) is the
arity-2 instance; ``repro.cells.init_state`` builds the reset carry).
All three engines implement it — the fused ``pallas`` kernel seeds its
(h, c) VMEM scratch from the carried state and returns the final state —
so stateful selection (``select_stateful``, following the plan's
``stateful_backend``) resolves exactly like the stateless path
(docs/API.md §Backends documents the selection order).

For DEVICE-RESIDENT serving state (``plan()['state_residency']``) an
engine may additionally expose

  run_stateful_slots(qparams, x_int, model, accel,
                     table, gather_slots, scatter_slots)
      -> (y_int, new_table)

where ``table`` is the persistent ``(n_slots + 2, L, S, H)`` int32 state
table and the slot vectors are per-batch-row table-row ids (the contract
of ``kernels/qlstm_cell.qlstm_seq_slot_pallas``).  The ``pallas`` engine
gathers/scatters inside the fused kernel; ``ref`` and ``xla`` use the
XLA-level adapter (``common.run_slots_via_state`` — still device-side,
so degrading down the ladder never moves the carry back to the host).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.accelerator import (AcceleratorConfig, resolve_backend,
                                    resolve_model, resolve_stateful_backend)
from repro.core.qlstm import QLSTMConfig


class BackendUnsupported(ValueError):
    """Raised when an explicitly requested backend cannot execute the
    resolved (model, accelerator) configuration."""


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered execution engine: the callables the dispatch layer
    (``select`` / ``select_stateful``) hands to ``Accelerator``."""

    name: str
    run: Callable                       # (qparams, x_int, model, accel) -> y_int
    supports: Callable                  # (model, accel) -> Optional[str]
    layer: Optional[Callable] = None    # (x_int, wx, wh, b, model, accel) -> h_seq
    # (qparams, x_int, model, accel, state) -> (y_int, new_state); None when
    # the engine cannot start from a non-zero (h, c) carry.
    run_stateful: Optional[Callable] = None
    # (qparams, x_int, model, accel, table, gather_slots, scatter_slots)
    # -> (y_int, new_table): the device-resident state-table entry point
    # (slot gather/scatter on the device; module docstring has the table
    # layout).  None when the engine has no slot path.
    run_stateful_slots: Optional[Callable] = None


_REGISTRY: Dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    """Add an engine to the registry (last registration under a name wins)
    and return it, so modules can ``BACKEND = register(Backend(...))``."""
    _REGISTRY[backend.name] = backend
    return backend


def get(name: str) -> Backend:
    """The registered engine under ``name``; KeyError names the known
    engines when it does not exist."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def available() -> Tuple[str, ...]:
    """Names of every registered engine, sorted."""
    return tuple(sorted(_REGISTRY))


def select(model: QLSTMConfig, accel: AcceleratorConfig,
           override: Optional[str] = None) -> Backend:
    """Resolve the backend for a configuration.

    ``override`` (or a non-``auto`` ``accel.backend``) is honoured verbatim
    — raising :class:`BackendUnsupported` if the engine can't run the
    configuration.  ``auto`` asks the plan."""
    model = resolve_model(model, accel, warn=False)
    name = override if override not in (None, "auto") \
        else resolve_backend(model, accel)
    backend = get(name)
    reason = backend.supports(model, accel)
    if reason is not None:
        raise BackendUnsupported(
            f"backend {name!r} cannot run this configuration: {reason}")
    return backend


def supported_backends(model: QLSTMConfig,
                       accel: AcceleratorConfig) -> Tuple[str, ...]:
    """Names of every registered backend able to run the configuration."""
    model = resolve_model(model, accel, warn=False)
    return tuple(n for n in available()
                 if _REGISTRY[n].supports(model, accel) is None)


def _stateful_reason(backend: Backend, model: QLSTMConfig,
                     accel: AcceleratorConfig) -> Optional[str]:
    reason = backend.supports(model, accel)
    if reason is not None:
        return reason
    if backend.run_stateful is None:
        return ("no stateful entry point (the engine cannot carry state "
                "across windows)")
    return None


def select_stateful(model: QLSTMConfig, accel: AcceleratorConfig,
                    override: Optional[str] = None) -> Backend:
    """Resolve a backend able to carry recurrent state across windows.

    Same contract as :func:`select`, but ``auto`` follows the plan's
    ``stateful_backend`` — currently identical to the stateless choice,
    since every engine (including the fused pallas kernel) implements
    ``run_stateful``.  An explicit request for an engine without a
    stateful entry point raises :class:`BackendUnsupported`."""
    model = resolve_model(model, accel, warn=False)
    name = override if override not in (None, "auto") \
        else resolve_stateful_backend(model, accel)
    backend = get(name)
    reason = _stateful_reason(backend, model, accel)
    if reason is not None:
        raise BackendUnsupported(
            f"backend {name!r} cannot run this configuration statefully: "
            f"{reason}")
    return backend


def stateful_backends(model: QLSTMConfig,
                      accel: AcceleratorConfig) -> Tuple[str, ...]:
    """Names of every engine able to run the configuration with a carried
    recurrent state — the ``repro.serving`` capability surface."""
    model = resolve_model(model, accel, warn=False)
    return tuple(n for n in available()
                 if _stateful_reason(_REGISTRY[n], model, accel) is None)


# Canonical fastest-first engine order for graceful degradation: the fused
# kernel, then the general scan, then the pure-jnp oracle.  All three are
# bit-identical on the int path, so moving down the ladder changes
# latency, never results.
DEGRADATION_ORDER = ("pallas", "xla", "ref")


def degradation_ladder(model: QLSTMConfig, accel: AcceleratorConfig,
                       override: Optional[str] = None,
                       stateful: bool = True) -> Tuple[str, ...]:
    """Ordered engine names the serving tier degrades through on repeated
    backend failure: the resolved (or explicitly ``override``-requested)
    engine first, then every other engine capable of this configuration in
    :data:`DEGRADATION_ORDER` (engines registered outside the canonical
    order go last).  ``stateful`` restricts the ladder to engines with a
    cross-window state entry point — the ``repro.serving`` case."""
    first = (select_stateful if stateful else select)(
        model, accel, override=override).name
    capable = (stateful_backends if stateful else supported_backends)(
        model, accel)
    rest = [n for n in DEGRADATION_ORDER if n in capable and n != first]
    rest += [n for n in capable
             if n not in DEGRADATION_ORDER and n != first]
    return (first, *rest)


# Importing the submodules registers the engines.
from repro.backends import pallas as _pallas  # noqa: E402,F401
from repro.backends import ref as _ref        # noqa: E402,F401
from repro.backends import xla as _xla        # noqa: E402,F401
