"""Shared scaffolding for the layered (ref / pallas) backends: both engines
implement one *layer* (time-major sequence of cell steps); the whole-model
``run`` — layer stacking plus the dense head with the single late rounding —
is identical and lives here so the two cannot drift."""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig, check_int_state

Array = jax.Array


def supports_fused(model: QLSTMConfig,
                   accel: AcceleratorConfig) -> Optional[str]:
    """Can the FUSED (Pallas) datapath run this configuration?  Delegates
    to the cell spec: a cell without a fused kernel
    (``CellSpec.supports_fused is None`` — GRU, rGLRU today) is refused
    outright; a cell with one (LSTM) applies its own predicate — the
    paper's pipelined datapath with the hard activations (C2+C3).
    Anything refused here is the xla engine's job."""
    from repro import cells  # lazy: avoids the cells -> kernels -> backends cycle
    spec = cells.get(model.cell)
    if spec.supports_fused is None:
        return f"cell {model.cell!r} has no fused kernel"
    return spec.supports_fused(model, accel)


def dense_head(h_last: Array, qparams, model: QLSTMConfig) -> Array:
    """The shared dense head: final-step (B, H) hidden codes -> (B, P)
    output codes, with the single late rounding (S5).  Every layered
    engine — and the fused multi-layer pallas datapath — ends here, so the
    head cannot drift between them."""
    return fxp.fxp_matvec_late_rounding(
        h_last, qparams["dense"]["w"], qparams["dense"]["b"], model.fxp)


def run_layered(layer_fn: Callable, qparams, x_int: Array,
                model: QLSTMConfig, accel: AcceleratorConfig) -> Array:
    """Stack ``layer_fn`` over ``model.num_layers`` and apply the dense head.

    x_int: (B, T, M) integer codes in ``model.fxp`` -> (B, P) codes."""
    h_t = jnp.swapaxes(x_int, 0, 1).astype(jnp.int32)   # time-major (T, B, M)
    for p in qparams["layers"]:
        h_t = layer_fn(h_t, p["w_x"], p["w_h"], p["b"], model, accel)
        h_t = h_t.astype(jnp.int32)
    return dense_head(h_t[-1], qparams, model)


def run_slots_via_state(run_stateful: Callable, qparams, x_int: Array,
                        model: QLSTMConfig, accel: AcceleratorConfig,
                        table: Array, gather_slots: Array,
                        scatter_slots: Array):
    """Generic ``run_stateful_slots`` for engines without an in-kernel slot
    path: gather the per-layer carry batch from the state table, run the
    engine's ``run_stateful``, scatter the new carry back — all in jnp, so
    under jit the table never leaves the device even though the engine
    itself only understands dense state.  This keeps every rung of the
    serving degradation ladder device-resident: falling back from the
    fused pallas kernel to ``xla``/``ref`` changes latency, never where
    the state lives.  The carry arity is read off the table itself
    (``table.shape == (slots + 2, L, S, H)``), so the adapter serves every
    registered cell — LSTM's ``S == 2`` (h, c) and the single-array GRU /
    rGLRU carries alike.

    Same table contract as ``kernels/qlstm_cell.qlstm_seq_slot_pallas``
    (rows ``n_slots``/``n_slots + 1`` are the ZERO/TRASH slots); returns
    ``(y_int, new_table)``."""
    nl, arity = table.shape[1], table.shape[2]
    state = tuple(tuple(jnp.take(table[:, li, s, :], gather_slots, axis=0)
                        for s in range(arity))
                  for li in range(nl))
    y_int, new_state = run_stateful(qparams, x_int, model, accel, state)
    for li, layer_carry in enumerate(new_state):
        for s, arr in enumerate(layer_carry):
            table = table.at[scatter_slots, li, s, :].set(arr)
    return y_int, table


def run_layered_stateful(layer_fn: Callable, qparams, x_int: Array,
                         model: QLSTMConfig, accel: AcceleratorConfig,
                         state):
    """Stateful counterpart of :func:`run_layered` — threads the per-layer
    (h, c) carry through ``layer_fn`` and returns it alongside the output.

    ``layer_fn`` here takes the extra ``(h0, c0)`` carry and returns
    ``(h_seq, (h_last, c_last))``.  ``state`` is the per-layer carry tuple
    (``core.qlstm.IntState``); returns ``(y_int, new_state)``."""
    check_int_state(state, qparams)
    h_t = jnp.swapaxes(x_int, 0, 1).astype(jnp.int32)   # time-major (T, B, M)
    new_state = []
    for p, (h0, c0) in zip(qparams["layers"], state):
        h_t, carry = layer_fn(h_t, p["w_x"], p["w_h"], p["b"], model, accel,
                              h0, c0)
        h_t = h_t.astype(jnp.int32)
        new_state.append(carry)
    return dense_head(h_t[-1], qparams, model), tuple(new_state)
