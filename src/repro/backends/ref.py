"""``ref`` backend — the bit-exact pure-jnp oracle (`kernels/ref.py`).

Explicit int32 matmuls per cell step, single late rounding (S5), hard
activations.  This is the specification: the general (xla) datapath of
every registered cell — and, for the LSTM, the fused pallas engine — must
match it bit-for-bit (`tests/test_api.py`, `tests/test_kernels.py`,
`tests/test_cells.py`).  The whole-model run stacks the cell spec's
``ref_layer`` (time-major oracle layer) and finishes with the shared
dense head.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.backends import Backend, register
from repro.backends.common import dense_head, run_slots_via_state
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig, check_int_state
from repro.kernels import ref as _ref

Array = jax.Array


def layer(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
          model: QLSTMConfig, accel: AcceleratorConfig) -> Array:
    """One LSTM layer, time-major: (T, B, M) codes -> (T, B, H) codes.

    Kept with the historical fused-LSTM signature — ``kernels/ops.
    qlstm_seq`` dispatches single layers through ``Backend.layer``; other
    cells go through :func:`run` / ``CellSpec.ref_layer``."""
    acts = model.acts
    return _ref.qlstm_seq_ref(
        x_int, w_x, w_h, b_wide, model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max)


def layer_stateful(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                   model: QLSTMConfig, accel: AcceleratorConfig,
                   h0: Array, c0: Array):
    """One LSTM layer resumed from a carried (h0, c0): (T, B, M) codes ->
    ((T, B, H) codes, (h_last, c_last))."""
    acts = model.acts
    return _ref.qlstm_seq_ref(
        x_int, w_x, w_h, b_wide, model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max,
        h0=h0, c0=c0, return_state=True)


def supports(model: QLSTMConfig, accel: AcceleratorConfig) -> Optional[str]:
    """The oracle engine covers whatever the cell's ref oracle covers —
    for every current cell, exactly the paper's pipelined datapath with
    the hard activations."""
    from repro import cells  # lazy: avoids the cells -> kernels -> backends cycle
    return cells.get(model.cell).supports_oracle(model, accel)


def run_stateful(qparams, x_int: Array, model: QLSTMConfig,
                 accel: AcceleratorConfig, state):
    """Whole model with an explicit cross-window carry: stack the cell's
    oracle layer over the carry tuple, then the shared dense head —
    ``(y_int, new_state)``."""
    from repro import cells
    spec = cells.get(model.cell)
    check_int_state(state, qparams)
    h_t = jnp.swapaxes(x_int, 0, 1).astype(jnp.int32)   # time-major (T, B, M)
    new_state = []
    for p, carry in zip(qparams["layers"], state):
        h_t, carry = spec.ref_layer(h_t, p, model, carry)
        h_t = h_t.astype(jnp.int32)
        new_state.append(tuple(carry))
    return dense_head(h_t[-1], qparams, model), tuple(new_state)


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    """Whole model, batch-major: (B, T, M) codes -> (B, P) codes — the
    stateful oracle started from the zero reset carry."""
    from repro import cells
    y, _ = run_stateful(qparams, x_int, model, accel,
                        cells.init_state(model, x_int.shape[0]))
    return y


BACKEND = register(Backend(
    name="ref", run=run, supports=supports, layer=layer,
    run_stateful=run_stateful,
    # Device-resident state via the XLA-level gather/scatter adapter — the
    # oracle rung of the serving ladder keeps the carry on the device too.
    run_stateful_slots=functools.partial(run_slots_via_state, run_stateful)))
