"""``ref`` backend — the bit-exact pure-jnp oracle (`kernels/ref.py`).

Two explicit int32 matmuls per cell step, single late rounding (S5), hard
activations.  This is the specification: the pallas engine must match it
bit-for-bit (`tests/test_api.py`, `tests/test_kernels.py`)."""

from __future__ import annotations

import functools

import jax

from repro.backends import Backend, register
from repro.backends.common import (run_layered, run_layered_stateful,
                                   run_slots_via_state, supports_fused)
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig
from repro.kernels import ref as _ref

Array = jax.Array


def layer(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
          model: QLSTMConfig, accel: AcceleratorConfig) -> Array:
    """One LSTM layer, time-major: (T, B, M) codes -> (T, B, H) codes."""
    acts = model.acts
    return _ref.qlstm_seq_ref(
        x_int, w_x, w_h, b_wide, model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max)


def run(qparams, x_int: Array, model: QLSTMConfig,
        accel: AcceleratorConfig) -> Array:
    """Whole model, batch-major: (B, T, M) codes -> (B, P) codes."""
    return run_layered(layer, qparams, x_int, model, accel)


def layer_stateful(x_int: Array, w_x: Array, w_h: Array, b_wide: Array,
                   model: QLSTMConfig, accel: AcceleratorConfig,
                   h0: Array, c0: Array):
    """One layer resumed from a carried (h0, c0): (T, B, M) codes ->
    ((T, B, H) codes, (h_last, c_last))."""
    acts = model.acts
    return _ref.qlstm_seq_ref(
        x_int, w_x, w_h, b_wide, model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max,
        h0=h0, c0=c0, return_state=True)


def run_stateful(qparams, x_int: Array, model: QLSTMConfig,
                 accel: AcceleratorConfig, state):
    """Whole model with cross-window (h, c) carry — (y_int, new_state)."""
    return run_layered_stateful(layer_stateful, qparams, x_int, model, accel,
                                state)


BACKEND = register(Backend(
    name="ref", run=run, supports=supports_fused, layer=layer,
    run_stateful=run_stateful,
    # Device-resident state via the XLA-level gather/scatter adapter — the
    # oracle rung of the serving ladder keeps the carry on the device too.
    run_stateful_slots=functools.partial(run_slots_via_state, run_stateful)))
