"""``autotune`` — from a search space to the best deployable session.

The paper's workflow, automated: sweep the parameterised design, keep the
points that satisfy the deployment constraints (a power envelope, a
real-time samples/s floor, an accuracy budget), and return the
``Accelerator`` session for the point that maximises the objective among
the Pareto-optimal survivors.  The returned session is rebuilt and
quantised — ready for ``infer``/``serve`` — and carries the sweep evidence
in ``session.autotune_summary``.
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.api import Accelerator, build
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig
from repro.explore.measure import sweep, validate_metric_names
from repro.explore.pareto import DEFAULT_OBJECTIVES, pareto_indices
from repro.explore.space import SearchSpace, paper_space, point_from_config

# Senses for objectives/constraints whose "better" direction isn't "bigger".
_MINIMISE = ("int_float_mse", "int_float_max_abs", "total_w", "dynamic_w",
             "energy_j_per_wave", "us_per_wave", "weight_bytes")

Constraint = Union[Tuple[Optional[float], Optional[float]], Callable]


def _satisfies(metrics: Mapping, constraints: Mapping[str, Constraint]) -> bool:
    for name, c in constraints.items():
        if callable(c):
            if not c(metrics):
                return False
            continue
        lo, hi = c
        v = float(metrics[name])
        if lo is not None and v < lo:
            return False
        if hi is not None and v > hi:
            return False
    return True


def autotune(model: Optional[QLSTMConfig] = None,
             space: Optional[SearchSpace] = None, *,
             accel: Optional[AcceleratorConfig] = None,
             objective: str = "gops_per_watt",
             constraints: Optional[Mapping[str, Constraint]] = None,
             mode: str = "grid", n: Optional[int] = None, seed: int = 0,
             iters: int = 20, eval_x: Optional[np.ndarray] = None,
             payload: Optional[Dict] = None,
             log: Optional[Callable[[str], None]] = None) -> Accelerator:
    """Search ``space`` and return the best buildable session.

    ``objective`` is a sweep metric name (maximised, unless it is a
    cost-like metric — see ``_MINIMISE``).  ``constraints`` maps metric
    names to ``(min, max)`` bounds (``None`` = unbounded) or to a predicate
    over the metrics dict, e.g.::

        autotune(cfg, space,
                 objective="gops_per_watt",
                 constraints={"total_w": (None, 61.0),        # power cap
                              "samples_per_s": (30_000, None)})  # real-time

    The winner is chosen on the Pareto front *of the feasible points* (the
    front is recomputed after filtering, so a constraint that excludes the
    unconstrained front still yields the constrained optimum).  Raises
    ``ValueError`` when no evaluated point satisfies the constraints.

    ``model``/``accel`` carry the non-swept base configuration, exactly as
    they do for :func:`repro.explore.sweep`.

    ``payload`` reuses an existing sweep result (the dict from
    :func:`repro.explore.sweep`, or a loaded ``BENCH_pareto.json``) instead
    of re-measuring; the winning session is rebuilt from the recorded point
    config *with the payload's recorded init seed*, so the deployed weights
    are the ones the stored metrics (and the constraint selection) actually
    describe.  ``model``/``accel`` must then match the sweep's bases."""
    constraints = dict(constraints or {})
    validate_metric_names([objective], "objective")
    validate_metric_names([k for k, c in constraints.items()
                           if not callable(c)], "constraint")
    sense = "min" if objective in _MINIMISE else "max"
    objectives = dict(DEFAULT_OBJECTIVES)
    objectives[objective] = sense

    if payload is None:
        space = space or paper_space()
        payload = sweep(space, model, accel, mode=mode, n=n, seed=seed,
                        iters=iters, eval_x=eval_x, objectives=objectives,
                        log=log)
    ok = [r for r in payload["points"] if r["status"] == "ok"]
    feasible = [r for r in ok if _satisfies(r["metrics"], constraints)]
    if not feasible:
        raise ValueError(
            f"no feasible point: {len(ok)} evaluated, none satisfy "
            f"{constraints!r} (closest metrics: "
            f"{[r['metrics'].get(k) for r in ok[:3] for k in constraints]})")

    front_idx = pareto_indices(feasible, objectives,
                               key=lambda r: r["metrics"])
    front = [feasible[i] for i in front_idx]
    signed = ((lambda v: -v) if sense == "min" else (lambda v: v))
    best = max(front, key=lambda r: signed(float(r["metrics"][objective])))

    model_cfg, accel_cfg = point_from_config(best["config"]).configs(model,
                                                                     accel)
    # A stored payload was measured with ITS seed; rebuilding with any other
    # would deploy weights the selected metrics never described.
    session = build(model_cfg, accel_cfg,
                    seed=payload.get("seed", seed)).quantize()
    session.autotune_summary = {
        "objective": objective,
        "sense": sense,
        "constraints": {k: (repr(c) if callable(c) else list(c))
                        for k, c in constraints.items()},
        "best": best,
        "front": [r["label"] for r in front],
        "n_evaluated": len(ok),
        "n_feasible": len(feasible),
        "sweep": payload,
    }
    if log:
        log(f"[autotune] best={best['label']} "
            f"{objective}={best['metrics'][objective]:.4g} "
            f"({len(front)} on the feasible front, "
            f"{len(feasible)}/{len(ok)} feasible)")
    return session
