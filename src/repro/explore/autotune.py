"""``autotune`` — from a search space to the best deployable session.

The paper's workflow, automated: sweep the parameterised design, keep the
points that satisfy the deployment constraints (a power envelope, a
real-time samples/s floor, an accuracy budget — and, serving-aware, an
SLO like "p99 <= 5 ms" measured under a real ``ServingScenario``), and
return the ``Accelerator`` session for the point that maximises the
objective among the Pareto-optimal survivors.  The returned session is
rebuilt and quantised — ready for ``infer``/``serve`` — and carries the
sweep evidence in ``session.autotune_summary`` (for scenario searches:
the serving operating point and the full halving rung-promotion trace).
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.api import Accelerator, build
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig
from repro.explore.measure import (METRIC_KEYS, sweep, validate_metric_names)
from repro.explore.pareto import (DEFAULT_OBJECTIVES, ExploreError,
                                  pareto_indices)
from repro.explore.serving_objective import (SERVING_METRIC_KEYS,
                                             SERVING_MINIMISE,
                                             ServingScenario,
                                             parse_constraint)
from repro.explore.space import SearchSpace, paper_space, point_from_config

# Senses for objectives/constraints whose "better" direction isn't "bigger".
_MINIMISE = ("int_float_mse", "int_float_max_abs", "total_w", "dynamic_w",
             "energy_j_per_wave", "us_per_wave", "weight_bytes")

Constraint = Union[Tuple[Optional[float], Optional[float]], Callable]


def _satisfies(metrics: Mapping, constraints: Mapping[str, Constraint]) -> bool:
    for name, c in constraints.items():
        if callable(c):
            if not c(metrics):
                return False
            continue
        lo, hi = c
        v = float(metrics[name])
        if lo is not None and v < lo:
            return False
        if hi is not None and v > hi:
            return False
    return True


def autotune(model: Optional[QLSTMConfig] = None,
             space: Optional[SearchSpace] = None, *,
             accel: Optional[AcceleratorConfig] = None,
             objective: Optional[str] = None,
             constraints: Optional[Mapping[str, Constraint]] = None,
             constraint=None,
             scenario: Optional[ServingScenario] = None,
             strategy: Optional[str] = None, eta: int = 2,
             rungs: Optional[int] = None,
             mode: str = "grid", n: Optional[int] = None, seed: int = 0,
             iters: int = 20, eval_x: Optional[np.ndarray] = None,
             payload: Optional[Dict] = None,
             log: Optional[Callable[[str], None]] = None) -> Accelerator:
    """Search ``space`` and return the best buildable session.

    ``objective`` is a sweep metric name (maximised, unless it is a
    cost-like metric); the default is ``gops_per_watt`` offline and
    ``samples_per_s`` for scenario searches.  ``constraints`` maps metric
    names to ``(min, max)`` bounds (``None`` = unbounded) or to a predicate
    over the metrics dict, e.g.::

        autotune(cfg, space,
                 objective="gops_per_watt",
                 constraints={"total_w": (None, 61.0),        # power cap
                              "samples_per_s": (30_000, None)})  # real-time

    Serving-aware search adds ``scenario`` (a
    :class:`~repro.explore.serving_objective.ServingScenario` — each point
    is scored by a real short ``StreamServer``/``ClusterServer`` run at
    that operating point) and ``constraint``, an SLO string like
    ``"p99_ms<=5"`` — the constrained objective "max samples/s s.t.
    p99 <= 5 ms".  With a scenario the sweep defaults to
    ``strategy="halving"`` (seeded successive halving; ``eta``/``rungs``
    tune the schedule) and ``session.autotune_summary`` records the
    serving ``operating_point`` plus the full ``halving`` rung-promotion
    trace — deterministic given ``seed``.

    The winner is chosen on the Pareto front *of the feasible points* (the
    front is recomputed after filtering, so a constraint that excludes the
    unconstrained front still yields the constrained optimum).  Raises
    :class:`~repro.explore.pareto.ExploreError` (a ``ValueError``) naming
    the eliminating constraint when no evaluated point is feasible.

    ``model``/``accel`` carry the non-swept base configuration, exactly as
    they do for :func:`repro.explore.sweep`.

    ``payload`` reuses an existing sweep result (the dict from
    :func:`repro.explore.sweep`, or a loaded ``BENCH_pareto.json``) instead
    of re-measuring; the winning session is rebuilt from the recorded point
    config *with the payload's recorded init seed*, so the deployed weights
    are the ones the stored metrics (and the constraint selection) actually
    describe.  ``model``/``accel`` must then match the sweep's bases."""
    constraints = dict(constraints or {})
    serving = scenario is not None or (payload is not None
                                       and payload.get("scenario"))
    vocab = SERVING_METRIC_KEYS if serving else METRIC_KEYS
    if objective is None:
        objective = "samples_per_s" if serving else "gops_per_watt"
    validate_metric_names([objective], "objective", vocab)
    validate_metric_names([k for k, c in constraints.items()
                           if not callable(c)], "constraint", vocab)
    slo = parse_constraint(constraint)
    if slo is not None and not serving:
        raise ValueError("an SLO constraint needs a scenario (or a stored "
                         "scenario-sweep payload) to measure it under")
    minimise = SERVING_MINIMISE if serving else _MINIMISE
    sense = "min" if objective in minimise else "max"
    objectives = dict({} if serving else DEFAULT_OBJECTIVES)
    objectives[objective] = sense
    if serving:
        objectives.setdefault("p99_ms", "min")

    if payload is None:
        space = space or paper_space()
        strategy = strategy or ("halving" if scenario is not None
                                else "full")
        payload = sweep(space, model, accel, mode=mode, n=n, seed=seed,
                        iters=iters, eval_x=eval_x, objectives=objectives,
                        scenario=scenario, constraint=slo,
                        strategy=strategy, objective=objective, eta=eta,
                        rungs=rungs, log=log)
    if slo is None and payload.get("constraint"):
        slo = parse_constraint(payload["constraint"])

    ok = [r for r in payload["points"] if r["status"] == "ok"]
    # Scenario sweeps only compare points at their FINAL operating point:
    # earlier-rung metrics were measured on a truncated scenario and are
    # not commensurable with full-scenario ones.
    if serving:
        candidates = [r for r in ok
                      if (r.get("operating_point") or {}).get("final")]
    else:
        candidates = ok
    feasible = [r for r in candidates
                if _satisfies(r["metrics"], constraints)
                and (slo is None or slo.ok(r["metrics"]))]
    if not feasible:
        named = slo.describe() if slo is not None else repr(constraints)
        closest = ""
        if slo is not None and candidates:
            worst = min(candidates,
                        key=lambda r: slo.violation(r["metrics"]))
            closest = (f" (closest: {worst['label']} misses it by "
                       f"{slo.violation(worst['metrics']):.4g})")
        raise ExploreError(
            f"no feasible point: constraint {named} eliminated all "
            f"{len(candidates)} candidate(s) of {len(ok)} evaluated"
            f"{closest}")

    front_idx = pareto_indices(feasible, objectives,
                               key=lambda r: r["metrics"])
    front = [feasible[i] for i in front_idx]
    signed = ((lambda v: -v) if sense == "min" else (lambda v: v))
    best = max(front, key=lambda r: signed(float(r["metrics"][objective])))

    model_cfg, accel_cfg = point_from_config(best["config"]).configs(model,
                                                                     accel)
    # A stored payload was measured with ITS seed; rebuilding with any other
    # would deploy weights the selected metrics never described.
    session = build(model_cfg, accel_cfg,
                    seed=payload.get("seed", seed)).quantize()
    session.autotune_summary = {
        "objective": objective,
        "sense": sense,
        "constraints": {k: (repr(c) if callable(c) else list(c))
                        for k, c in constraints.items()},
        "constraint": slo.describe() if slo is not None else None,
        "scenario": payload.get("scenario"),
        "strategy": payload.get("strategy", "full"),
        "operating_point": best.get("operating_point"),
        "halving": payload.get("halving"),
        "best": best,
        "front": [r["label"] for r in front],
        "n_evaluated": len(ok),
        "n_feasible": len(feasible),
        "sweep": payload,
    }
    if log:
        log(f"[autotune] best={best['label']} "
            f"{objective}={best['metrics'][objective]:.4g} "
            f"({len(front)} on the feasible front, "
            f"{len(feasible)}/{len(ok)} feasible)")
    return session
