"""Pareto-dominance and front extraction over sweep metrics.

Objectives are a mapping ``{metric_name: "max" | "min"}`` — the paper's
pair is ``{"throughput_gops": "max", "gops_per_watt": "max"}``; adding
``{"int_float_mse": "min"}`` gives the 3-objective accuracy-aware front.
Points are plain mappings (metric name -> value), or arbitrary items with a
``key=`` extractor.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence

# The paper scores a configuration by throughput and energy efficiency
# (GOP/s and GOP/s/W, Table 4).
DEFAULT_OBJECTIVES: Dict[str, str] = {
    "throughput_gops": "max",
    "gops_per_watt": "max",
}

_SENSES = ("max", "min")


class ExploreError(ValueError):
    """A search step that has nothing left to offer — a front asked of 0
    measurements, a sweep whose every point was eliminated, an SLO no
    candidate satisfies.  The message names what eliminated everything, so
    ``report --pareto`` renders the reason instead of a bare header.
    Subclasses ``ValueError`` so pre-existing ``except ValueError``
    call sites keep working."""


def _signed(value: float, sense: str) -> float:
    if sense not in _SENSES:
        raise ValueError(f"objective sense must be 'max'|'min', got {sense!r}")
    return value if sense == "max" else -value


def _metric(m: Mapping, name: str) -> float:
    try:
        return float(m[name])
    except KeyError:
        raise ExploreError(
            f"point carries no metric {name!r} (has: {sorted(m)}) — "
            f"was it measured? 0-measurement rows cannot enter a front"
        ) from None


def dominates(a: Mapping, b: Mapping,
              objectives: Optional[Mapping[str, str]] = None) -> bool:
    """True iff ``a`` is at least as good as ``b`` on every objective and
    strictly better on at least one.  Identical points never dominate each
    other (both stay on the front).  A point missing an objective metric
    raises :class:`ExploreError` naming the metric."""
    objectives = objectives or DEFAULT_OBJECTIVES
    strictly_better = False
    for name, sense in objectives.items():
        av = _signed(_metric(a, name), sense)
        bv = _signed(_metric(b, name), sense)
        if av < bv:
            return False
        if av > bv:
            strictly_better = True
    return strictly_better


def _finite(m: Mapping, objectives: Mapping[str, str]) -> bool:
    return all(math.isfinite(_metric(m, name)) for name in objectives)


def pareto_indices(items: Sequence,
                   objectives: Optional[Mapping[str, str]] = None,
                   key: Optional[Callable] = None) -> List[int]:
    """Indices of the non-dominated items, in input order.

    Items with a non-finite (NaN/inf) objective value are excluded — a
    failed measurement must not survive as "incomparable, therefore
    optimal".  An EMPTY front is never returned silently: 0 items, or a
    set whose every item was excluded, raises :class:`ExploreError`
    naming what eliminated everything.  O(n^2); sweeps are hundreds of
    points, not millions."""
    objectives = objectives or DEFAULT_OBJECTIVES
    if not items:
        raise ExploreError(
            "no points to extract a Pareto front from (0 measurements — "
            "did every sweep point fail or get pruned?)")
    key = key or (lambda it: it)
    metrics = [key(it) for it in items]
    valid = [i for i, m in enumerate(metrics) if _finite(m, objectives)]
    if not valid:
        raise ExploreError(
            f"all {len(items)} points were eliminated: non-finite values "
            f"for objectives {sorted(objectives)} — every measurement "
            f"failed")
    return [i for i in valid
            if not any(dominates(metrics[j], metrics[i], objectives)
                       for j in valid if j != i)]


def pareto_front(items: Sequence,
                 objectives: Optional[Mapping[str, str]] = None,
                 key: Optional[Callable] = None) -> List:
    """The non-dominated items themselves (see :func:`pareto_indices`)."""
    return [items[i] for i in pareto_indices(items, objectives, key)]


def constrained_pareto_front(items: Sequence,
                             objectives: Optional[Mapping[str, str]] = None,
                             *, constraint=None,
                             key: Optional[Callable] = None) -> List:
    """The Pareto front restricted to constraint-feasible items.

    ``constraint`` is an SLO object (``ok(metrics)`` / ``violation`` /
    ``describe()``; see ``serving_objective.parse_constraint``) or
    ``None`` (plain front).  When the input is non-empty but the
    constraint eliminates every item, raises :class:`ExploreError` naming
    the constraint and the closest miss — a front that silently dropped
    the SLO would deploy a violating point."""
    if constraint is None:
        return pareto_front(items, objectives, key)
    key = key or (lambda it: it)
    feasible = [it for it in items if constraint.ok(key(it))]
    if items and not feasible:
        closest = min(items, key=lambda it: constraint.violation(key(it)))
        raise ExploreError(
            f"constraint {constraint.describe()!r} eliminated all "
            f"{len(items)} measured points (closest miss violates it by "
            f"{constraint.violation(key(closest)):.4g})")
    return pareto_front(feasible, objectives, key)
