"""Pareto-dominance and front extraction over sweep metrics.

Objectives are a mapping ``{metric_name: "max" | "min"}`` — the paper's
pair is ``{"throughput_gops": "max", "gops_per_watt": "max"}``; adding
``{"int_float_mse": "min"}`` gives the 3-objective accuracy-aware front.
Points are plain mappings (metric name -> value), or arbitrary items with a
``key=`` extractor.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence

# The paper scores a configuration by throughput and energy efficiency
# (GOP/s and GOP/s/W, Table 4).
DEFAULT_OBJECTIVES: Dict[str, str] = {
    "throughput_gops": "max",
    "gops_per_watt": "max",
}

_SENSES = ("max", "min")


def _signed(value: float, sense: str) -> float:
    if sense not in _SENSES:
        raise ValueError(f"objective sense must be 'max'|'min', got {sense!r}")
    return value if sense == "max" else -value


def dominates(a: Mapping, b: Mapping,
              objectives: Optional[Mapping[str, str]] = None) -> bool:
    """True iff ``a`` is at least as good as ``b`` on every objective and
    strictly better on at least one.  Identical points never dominate each
    other (both stay on the front)."""
    objectives = objectives or DEFAULT_OBJECTIVES
    strictly_better = False
    for name, sense in objectives.items():
        av = _signed(float(a[name]), sense)
        bv = _signed(float(b[name]), sense)
        if av < bv:
            return False
        if av > bv:
            strictly_better = True
    return strictly_better


def _finite(m: Mapping, objectives: Mapping[str, str]) -> bool:
    return all(math.isfinite(float(m[name])) for name in objectives)


def pareto_indices(items: Sequence,
                   objectives: Optional[Mapping[str, str]] = None,
                   key: Optional[Callable] = None) -> List[int]:
    """Indices of the non-dominated items, in input order.

    Items with a non-finite (NaN/inf) objective value are excluded — a
    failed measurement must not survive as "incomparable, therefore
    optimal".  O(n^2); sweeps are hundreds of points, not millions."""
    objectives = objectives or DEFAULT_OBJECTIVES
    key = key or (lambda it: it)
    metrics = [key(it) for it in items]
    valid = [i for i, m in enumerate(metrics) if _finite(m, objectives)]
    return [i for i in valid
            if not any(dominates(metrics[j], metrics[i], objectives)
                       for j in valid if j != i)]


def pareto_front(items: Sequence,
                 objectives: Optional[Mapping[str, str]] = None,
                 key: Optional[Callable] = None) -> List:
    """The non-dominated items themselves (see :func:`pareto_indices`)."""
    return [items[i] for i in pareto_indices(items, objectives, key)]
