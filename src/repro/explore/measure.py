"""Score one configuration point / sweep a whole space through the session
API.

Every point is evaluated exactly the way a user would deploy it:
``repro.build(model, accel).quantize()``, then the cached jitted int-path
entry (``Accelerator.compiled``) is timed — compile outside the clock — and
``Accelerator.report()`` is re-anchored at the *measured* latency so the
energy model scores the real operating point, not the paper's.  Accuracy is
the int datapath's deviation from the float reference on shared inputs (the
quantisation-fidelity axis of the trade-off).

The sweep payload (``BENCH_pareto.json``) is the artifact CI uploads and
``analysis/report.py --pareto`` renders; its schema is pinned by
``tests/test_explore.py``.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.api import build
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig
from repro.explore.pareto import DEFAULT_OBJECTIVES, pareto_indices
from repro.explore.space import Point, SearchSpace

SCHEMA_VERSION = 1

# Every metric a sweep row carries — the vocabulary objectives and
# constraints may reference.  Validated BEFORE the measurement loop, so a
# typo fails in milliseconds instead of as a KeyError after minutes of
# timed builds.
METRIC_KEYS = frozenset({
    "us_per_wave", "samples_per_s", "throughput_gops", "gops_per_watt",
    "total_w", "dynamic_w", "energy_j_per_wave", "int_float_mse",
    "int_float_max_abs", "weight_bytes", "ops_per_inference",
})


def validate_metric_names(names, what: str) -> None:
    unknown = sorted(set(names) - METRIC_KEYS)
    if unknown:
        raise ValueError(f"unknown {what} metric(s) {unknown}; "
                         f"known: {sorted(METRIC_KEYS)}")


def _eval_batch(point: Point, model: QLSTMConfig,
                eval_x: Optional[np.ndarray], seed: int) -> jax.Array:
    """A (batch, T, M) float evaluation wave: user data when given (tiled to
    the wave size), else synthetic windows in the normalised input range."""
    b, t, m = point.batch, model.seq_len, model.input_size
    if eval_x is not None:
        x = np.asarray(eval_x, np.float32)
        if x.shape[1:] != (t, m):
            raise ValueError(f"eval_x windows are {x.shape[1:]}, the swept "
                             f"model needs ({t}, {m})")
        reps = -(-b // len(x))
        return jnp.asarray(np.tile(x, (reps, 1, 1))[:b])
    return jax.random.normal(jax.random.key(seed), (b, t, m)) * 0.5


def evaluate_point(point: Point, base_model: Optional[QLSTMConfig] = None,
                   base_accel: Optional[AcceleratorConfig] = None,
                   *, eval_x: Optional[np.ndarray] = None, iters: int = 20,
                   seed: int = 0) -> Dict:
    """Build, quantise, time, and score one configuration point.

    ``base_model``/``base_accel`` carry the non-swept parameters (see
    ``Point.configs``).  Returns the sweep-row dict (``status`` is ``"ok"``
    here; ``sweep`` records unsupported points instead of raising)."""
    model_cfg, accel_cfg = point.configs(base_model, base_accel)
    sess = build(model_cfg, accel_cfg, seed=seed).quantize()
    x = _eval_batch(point, sess.model, eval_x, seed)

    fn = sess.compiled("int")
    fn(x).block_until_ready()               # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    wave_s = (time.perf_counter() - t0) / iters

    report = sess.report(latency_s=wave_s, batch=point.batch)
    energy = report["energy"]
    y_int = np.asarray(out)
    y_float = np.asarray(sess.infer(x, path="float"))
    err = y_int - y_float

    return {
        "label": point.label,
        "config": point.asdict(),
        "status": "ok",
        "plan": {
            "backend": report["backend"],
            "weight_memory": report["plan"]["weight_memory"],
            "weight_bytes": report["weight_bytes"],
            "mxu_fill_fraction": report["plan"]["mxu_fill_fraction"],
        },
        "metrics": {
            "us_per_wave": wave_s * 1e6,
            "samples_per_s": point.batch / wave_s,
            "throughput_gops": energy["throughput_gops"],
            "gops_per_watt": energy["gops_per_watt"],
            "total_w": energy["total_w"],
            "dynamic_w": energy["dynamic_w"],
            "energy_j_per_wave": energy["energy_j"],
            "int_float_mse": float(np.mean(err ** 2)),
            "int_float_max_abs": float(np.abs(err).max()),
            "weight_bytes": report["weight_bytes"],
            "ops_per_inference": report["ops_per_inference"],
        },
    }


def sweep(space: SearchSpace, base_model: Optional[QLSTMConfig] = None,
          base_accel: Optional[AcceleratorConfig] = None, *,
          mode: str = "grid", n: Optional[int] = None, seed: int = 0,
          iters: int = 20, eval_x: Optional[np.ndarray] = None,
          objectives: Optional[Mapping[str, str]] = None,
          log: Optional[Callable[[str], None]] = None) -> Dict:
    """Evaluate every point of ``space`` (``mode="grid"``) or ``n`` sampled
    points (``mode="random"``) and extract the Pareto front.

    Points whose explicit backend cannot run the configuration are recorded
    with ``status="unsupported"`` (and excluded from the front) rather than
    aborting the sweep — an infeasible corner is a sweep *finding*."""
    if mode == "grid":
        points = list(space.grid())
    elif mode == "random":
        if n is None:
            raise ValueError("mode='random' needs n=<points to sample>")
        points = list(space.sample(n, seed))
    else:
        raise ValueError(f"mode must be 'grid'|'random', got {mode!r}")
    objectives = dict(objectives or DEFAULT_OBJECTIVES)
    validate_metric_names(objectives, "objective")
    for sense in objectives.values():
        if sense not in ("max", "min"):
            raise ValueError(f"objective sense must be 'max'|'min', "
                             f"got {sense!r}")

    rows: List[Dict] = []
    for i, point in enumerate(points):
        try:
            row = evaluate_point(point, base_model, base_accel,
                                 eval_x=eval_x, iters=iters, seed=seed)
        except backends.BackendUnsupported as e:
            row = {"label": point.label, "config": point.asdict(),
                   "status": "unsupported", "reason": str(e)}
        rows.append(row)
        if log:
            m = row.get("metrics", {})
            log(f"[sweep {i + 1}/{len(points)}] {row['label']}: "
                + (f"{m['samples_per_s']:,.0f} samples/s, "
                   f"{m['gops_per_watt']:.3f} GOP/s/W"
                   if row["status"] == "ok" else row["status"]))

    ok = [r for r in rows if r["status"] == "ok"]
    front = pareto_indices(ok, objectives, key=lambda r: r["metrics"])
    on_front = {ok[i]["label"] for i in front}
    for r in rows:
        r["pareto"] = r["label"] in on_front
    return {
        "suite": "pareto",
        "schema_version": SCHEMA_VERSION,
        "mode": mode,
        # The init seed the measured sessions were built with — autotune
        # rebuilds the winner from a stored payload with THIS seed, so the
        # deployed weights are the ones the metrics describe.
        "seed": seed,
        "space": space.asdict(),
        "objectives": objectives,
        "points": rows,
        "front": [ok[i]["label"] for i in front],
    }
