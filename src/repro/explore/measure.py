"""Score one configuration point / sweep a whole space through the session
API.

Every point is evaluated exactly the way a user would deploy it.  Offline
sweeps build ``repro.build(model, accel).quantize()`` and time the cached
jitted int-path entry (``Accelerator.compiled``) — compile outside the
clock — with ``Accelerator.report()`` re-anchored at the *measured*
latency so the energy model scores the real operating point.  Serving
sweeps (``scenario=...``) instead stand up a short real
``StreamServer``/``ClusterServer`` run per point
(``repro.explore.serving_objective``) and score
``metrics_summary()``-derived objectives: p50/p95/p99, achieved
samples/s, deadline-miss rate, GOP/s/W.

Structurally infeasible points (device residency without the fused plan,
replicas > devices, a refusing explicit backend — see
``repro.explore.constraints``) are pruned BEFORE measurement and recorded
with the violated rule's reason.  ``strategy="halving"`` replaces the
full per-point scenario with seeded successive halving
(``repro.explore.halving``): rung 0 measures every survivor on a cheap
truncated scenario and each rung promotes the top ``1/eta`` on the
constrained objective.

The sweep payload (``BENCH_pareto.json``, schema v2) is the artifact CI
uploads and ``analysis/report.py --pareto`` renders; its schema is pinned
by ``tests/test_explore.py`` and checked in CI by
``tools/check_pareto_schema.py``.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends
from repro.api import build
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig
from repro.explore.pareto import (DEFAULT_OBJECTIVES, ExploreError,
                                  constrained_pareto_front, pareto_indices)
from repro.explore.serving_objective import (SERVING_METRIC_KEYS,
                                             SERVING_MINIMISE,
                                             ServingScenario,
                                             evaluate_serving_point,
                                             parse_constraint)
from repro.explore.space import Point, SearchSpace

# v2: serving-aware sweeps — points gain "infeasible" status + reasons,
# ok rows of scenario sweeps carry the serving "operating_point" (scenario,
# rung, p99, miss rate), and the payload records strategy / scenario /
# constraint / halving trace / front_reason.
SCHEMA_VERSION = 2

# Every metric an OFFLINE sweep row carries — the vocabulary objectives and
# constraints may reference.  Validated BEFORE the measurement loop, so a
# typo fails in milliseconds instead of as a KeyError after minutes of
# timed builds.  Scenario sweeps use SERVING_METRIC_KEYS instead.
METRIC_KEYS = frozenset({
    "us_per_wave", "samples_per_s", "throughput_gops", "gops_per_watt",
    "total_w", "dynamic_w", "energy_j_per_wave", "int_float_mse",
    "int_float_max_abs", "weight_bytes", "ops_per_inference",
})

# Serving-mode default front: the achieved-rate / tail-latency trade-off.
SERVING_OBJECTIVES: Dict[str, str] = {
    "samples_per_s": "max",
    "p99_ms": "min",
}


def validate_metric_names(names, what: str, vocab=None) -> None:
    vocab = METRIC_KEYS if vocab is None else vocab
    unknown = sorted(set(names) - set(vocab))
    if unknown:
        raise ValueError(f"unknown {what} metric(s) {unknown}; "
                         f"known: {sorted(vocab)}")


def _eval_batch(point: Point, model: QLSTMConfig,
                eval_x: Optional[np.ndarray], seed: int) -> jax.Array:
    """A (batch, T, M) float evaluation wave: user data when given (tiled to
    the wave size), else synthetic windows in the normalised input range."""
    b, t, m = point.batch, model.seq_len, model.input_size
    if eval_x is not None:
        x = np.asarray(eval_x, np.float32)
        if x.shape[1:] != (t, m):
            raise ValueError(f"eval_x windows are {x.shape[1:]}, the swept "
                             f"model needs ({t}, {m})")
        reps = -(-b // len(x))
        return jnp.asarray(np.tile(x, (reps, 1, 1))[:b])
    return jax.random.normal(jax.random.key(seed), (b, t, m)) * 0.5


def evaluate_point(point: Point, base_model: Optional[QLSTMConfig] = None,
                   base_accel: Optional[AcceleratorConfig] = None,
                   *, eval_x: Optional[np.ndarray] = None, iters: int = 20,
                   seed: int = 0) -> Dict:
    """Build, quantise, time, and score one configuration point.

    ``base_model``/``base_accel`` carry the non-swept parameters (see
    ``Point.configs``).  Returns the sweep-row dict (``status`` is ``"ok"``
    here; ``sweep`` records unsupported points instead of raising)."""
    model_cfg, accel_cfg = point.configs(base_model, base_accel)
    sess = build(model_cfg, accel_cfg, seed=seed).quantize()
    x = _eval_batch(point, sess.model, eval_x, seed)

    fn = sess.compiled("int")
    fn(x).block_until_ready()               # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    out.block_until_ready()
    wave_s = (time.perf_counter() - t0) / iters

    report = sess.report(latency_s=wave_s, batch=point.batch)
    energy = report["energy"]
    y_int = np.asarray(out)
    y_float = np.asarray(sess.infer(x, path="float"))
    err = y_int - y_float

    return {
        "label": point.label,
        "config": point.asdict(),
        "status": "ok",
        "plan": {
            "backend": report["backend"],
            "weight_memory": report["plan"]["weight_memory"],
            "weight_bytes": report["weight_bytes"],
            "mxu_fill_fraction": report["plan"]["mxu_fill_fraction"],
        },
        "metrics": {
            "us_per_wave": wave_s * 1e6,
            "samples_per_s": point.batch / wave_s,
            "throughput_gops": energy["throughput_gops"],
            "gops_per_watt": energy["gops_per_watt"],
            "total_w": energy["total_w"],
            "dynamic_w": energy["dynamic_w"],
            "energy_j_per_wave": energy["energy_j"],
            "int_float_mse": float(np.mean(err ** 2)),
            "int_float_max_abs": float(np.abs(err).max()),
            "weight_bytes": report["weight_bytes"],
            "ops_per_inference": report["ops_per_inference"],
        },
    }


def _enumerate(space: SearchSpace, mode: str, n: Optional[int],
               seed: int) -> List[Point]:
    if mode == "grid":
        return list(space.grid())
    if mode == "random":
        if n is None:
            raise ValueError("mode='random' needs n=<points to sample>")
        return list(space.sample(n, seed))
    raise ValueError(f"mode must be 'grid'|'random', got {mode!r}")


def _prune(space: SearchSpace, points: List[Point], base_model,
           base_accel, log) -> Tuple[List[Point], Dict[str, Dict]]:
    """Split the candidate list on the space's constraint tree.  Pruned
    points become rows up front: backend refusals keep the historical
    ``"unsupported"`` status, structural invalidity (residency, replicas)
    is ``"infeasible"`` — both carry the violated rule's reason."""
    survivors: List[Point] = []
    pruned: Dict[str, Dict] = {}
    for point in points:
        reason = space.feasible(point, base_model, base_accel)
        if reason is None:
            survivors.append(point)
            continue
        status = ("unsupported" if reason.startswith("backend_supported:")
                  else "infeasible")
        pruned[point.label] = {"label": point.label,
                               "config": point.asdict(),
                               "status": status, "reason": reason}
        if log:
            log(f"[sweep] pruned {point.label}: {reason}")
    return survivors, pruned


def sweep(space: SearchSpace, base_model: Optional[QLSTMConfig] = None,
          base_accel: Optional[AcceleratorConfig] = None, *,
          mode: str = "grid", n: Optional[int] = None, seed: int = 0,
          iters: int = 20, eval_x: Optional[np.ndarray] = None,
          objectives: Optional[Mapping[str, str]] = None,
          scenario: Optional[ServingScenario] = None,
          constraint=None, strategy: Optional[str] = None,
          objective: Optional[str] = None, eta: int = 2,
          rungs: Optional[int] = None,
          log: Optional[Callable[[str], None]] = None) -> Dict:
    """Measure a search space and extract the (constrained) Pareto front.

    Offline (``scenario=None``): every grid/sampled point is built and its
    jitted int path timed, as before.  Serving (``scenario=...``): each
    point is scored by a real short server run at the scenario's operating
    point; ``strategy="halving"`` runs seeded successive halving over the
    survivors (rung 0 on ``scenario.truncated(...)``, final rung on the
    full scenario), ranking on ``objective`` (default ``samples_per_s``)
    subject to ``constraint`` (an SLO string like ``"p99_ms<=5"``).

    Pruned/unsupported points are recorded with reasons and excluded from
    the front rather than aborting the sweep — an infeasible corner is a
    sweep *finding*.  When nothing reaches the front, ``front`` is empty
    and ``front_reason`` names what eliminated everything."""
    points = _enumerate(space, mode, n, seed)
    strategy = strategy or "full"
    if strategy not in ("full", "halving"):
        raise ValueError(f"strategy must be 'full'|'halving', "
                         f"got {strategy!r}")
    if strategy == "halving" and scenario is None:
        raise ValueError("strategy='halving' needs a ServingScenario — "
                         "rungs are scenario truncations")
    slo = parse_constraint(constraint)
    if slo is not None and scenario is None:
        raise ValueError("an SLO constraint needs a ServingScenario to "
                         "measure it under")

    vocab = SERVING_METRIC_KEYS if scenario is not None else METRIC_KEYS
    if objectives is None:
        objectives = SERVING_OBJECTIVES if scenario is not None \
            else DEFAULT_OBJECTIVES
    objectives = dict(objectives)
    if objective is None and scenario is not None:
        objective = "samples_per_s"
    if objective is not None:
        validate_metric_names([objective], "objective", vocab)
        objectives.setdefault(
            objective, "min" if objective in SERVING_MINIMISE else "max")
    validate_metric_names(objectives, "objective", vocab)
    for sense in objectives.values():
        if sense not in ("max", "min"):
            raise ValueError(f"objective sense must be 'max'|'min', "
                             f"got {sense!r}")

    survivors, pruned = _prune(space, points, base_model, base_accel, log)
    rows_by_label: Dict[str, Dict] = dict(pruned)
    halving_trace = None

    if scenario is None:
        _sweep_offline(survivors, rows_by_label, base_model, base_accel,
                       eval_x=eval_x, iters=iters, seed=seed, log=log)
        final_labels = [p.label for p in survivors
                        if rows_by_label[p.label]["status"] == "ok"]
    elif strategy == "full":
        for i, point in enumerate(survivors):
            row = evaluate_serving_point(point, scenario, base_model,
                                         base_accel, seed=seed)
            row["operating_point"] = _operating_point(
                scenario, None, 1.0, row["metrics"], slo, final=True)
            rows_by_label[point.label] = row
            if log:
                m = row["metrics"]
                log(f"[sweep {i + 1}/{len(survivors)}] {row['label']}: "
                    f"{m['samples_per_s']:,.0f} samples/s, "
                    f"p99={m['p99_ms']:.2f} ms")
        final_labels = [p.label for p in survivors]
    else:
        halving_trace, final_labels = _sweep_halving(
            survivors, rows_by_label, scenario, base_model, base_accel,
            seed=seed, objective=objective, slo=slo, eta=eta, rungs=rungs,
            log=log)

    rows = [rows_by_label[p.label] for p in points]
    front_labels, front_reason = _extract_front(
        rows_by_label, final_labels, objectives, slo)
    on_front = set(front_labels)
    for r in rows:
        r["pareto"] = r["label"] in on_front
    return {
        "suite": "pareto",
        "schema_version": SCHEMA_VERSION,
        "mode": mode,
        "strategy": strategy,
        # The init seed the measured sessions were built with — autotune
        # rebuilds the winner from a stored payload with THIS seed, so the
        # deployed weights are the ones the metrics describe.
        "seed": seed,
        "space": space.asdict(),
        "objectives": objectives,
        "objective": objective,
        "constraint": slo.describe() if slo is not None else None,
        "scenario": scenario.asdict() if scenario is not None else None,
        "halving": halving_trace,
        "points": rows,
        "front": front_labels,
        "front_reason": front_reason,
    }


def _sweep_offline(survivors, rows_by_label, base_model, base_accel, *,
                   eval_x, iters, seed, log) -> None:
    for i, point in enumerate(survivors):
        try:
            row = evaluate_point(point, base_model, base_accel,
                                 eval_x=eval_x, iters=iters, seed=seed)
        except backends.BackendUnsupported as e:
            row = {"label": point.label, "config": point.asdict(),
                   "status": "unsupported", "reason": str(e)}
        rows_by_label[point.label] = row
        if log:
            m = row.get("metrics", {})
            log(f"[sweep {i + 1}/{len(survivors)}] {row['label']}: "
                + (f"{m['samples_per_s']:,.0f} samples/s, "
                   f"{m['gops_per_watt']:.3f} GOP/s/W"
                   if row["status"] == "ok" else row["status"]))


def _sweep_halving(survivors, rows_by_label, scenario, base_model,
                   base_accel, *, seed, objective, slo, eta, rungs, log):
    """Successive halving over the pruned survivors.  Sessions are built
    once per point and reused across rungs; every survivor gets a row
    carrying the metrics of its LAST measured rung and the operating
    point it was measured at."""
    from repro.explore.halving import successive_halving
    if not survivors:
        return None, []
    sessions: Dict[str, object] = {}
    last_rung: Dict[str, int] = {}
    last_fraction: Dict[str, float] = {}
    plans: Dict[str, Dict] = {}

    def measure(point, rung, fraction):
        sc = scenario.truncated(fraction)
        sess = sessions.get(point.label)
        if sess is None:
            model_cfg, accel_cfg = point.configs(base_model, base_accel)
            sess = build(model_cfg, accel_cfg, seed=seed).quantize()
            sessions[point.label] = sess
        row = evaluate_serving_point(point, sc, base_model, base_accel,
                                     seed=seed, session=sess)
        last_rung[point.label] = rung
        last_fraction[point.label] = fraction
        plans[point.label] = row["plan"]
        return row["metrics"]

    sense = "min" if objective in SERVING_MINIMISE else "max"
    trace = successive_halving(
        survivors, measure, objective=objective, sense=sense, eta=eta,
        rungs=rungs, constraint=slo,
        labels=[p.label for p in survivors], log=log)

    n_rungs = len(trace["sizes"])
    for idx, point in enumerate(survivors):
        metrics = trace["results"].get(idx)
        if metrics is None:
            rows_by_label[point.label] = {
                "label": point.label, "config": point.asdict(),
                "status": "failed",
                "reason": "scenario measurement returned nothing"}
            continue
        rung = last_rung[point.label]
        frac = last_fraction[point.label]
        rows_by_label[point.label] = {
            "label": point.label,
            "config": point.asdict(),
            "status": "ok",
            "plan": plans[point.label],
            "metrics": metrics,
            "operating_point": _operating_point(
                scenario.truncated(frac), rung, frac, metrics, slo,
                final=rung == n_rungs - 1),
        }
    final_labels = [lab for lab in trace["rungs"][-1]["measured"]]
    payload_trace = {k: trace[k] for k in
                     ("eta", "sizes", "fractions", "rungs", "winner_label",
                      "winner_feasible", "total_measurements",
                      "budget_bound", "objective", "sense", "constraint")}
    return payload_trace, final_labels


def _operating_point(scenario, rung, fraction, metrics, slo, *,
                     final: bool) -> Dict:
    """The per-point serving operating-point record of schema v2: which
    scenario (possibly truncated) the metrics were measured under, at
    which halving rung, and how the point stands against the SLO."""
    return {
        "scenario": scenario.asdict(),
        "rung": rung,
        "fraction": fraction,
        "final": final,
        "p99_ms": metrics.get("p99_ms"),
        "deadline_miss_rate": metrics.get("deadline_miss_rate"),
        "constraint": slo.describe() if slo is not None else None,
        "feasible": slo.ok(metrics) if slo is not None else True,
    }


def _extract_front(rows_by_label, final_labels, objectives, slo):
    """The front over the final-rung ok rows, restricted to SLO-feasible
    points.  Never raises: an eliminated-everything sweep records
    ``front_reason`` instead (the ExploreError message), because an
    empty front is a sweep *finding* the report must render."""
    candidates = [rows_by_label[lab] for lab in final_labels
                  if rows_by_label.get(lab, {}).get("status") == "ok"]
    if not candidates:
        n = len(rows_by_label)
        reasons = sorted({r.get("reason", r["status"])
                          for r in rows_by_label.values()
                          if r["status"] != "ok"})
        return [], (f"0 of {n} points reached measurement"
                    + (f": {'; '.join(reasons)[:400]}" if reasons else ""))
    try:
        front = constrained_pareto_front(
            candidates, objectives, constraint=slo,
            key=lambda r: r["metrics"])
    except ExploreError as e:
        return [], str(e)
    return [r["label"] for r in front], None
