"""Serving-aware objectives: score a configuration point where it runs.

The offline sweep times a jitted closed loop — the wrong objective for a
serving system, where the winning configuration depends on the *operating
point* (arrival rate, deadline, stream count), not peak throughput.  A
:class:`ServingScenario` pins that operating point and scores a session by
standing up a short real ``StreamServer`` (or ``ClusterServer`` for
multi-replica points) run and deriving objectives from
``metrics_summary()``: achieved samples/s, p50/p95/p99 latency,
deadline-miss rate, GOP/s/W.

Constrained objectives — "max samples/s s.t. p99 <= 5 ms" — are SLO
strings parsed by :func:`parse_constraint`; the successive-halving sweep
(``repro.explore.halving``) ranks candidates on the constrained objective
and ``autotune`` refuses to deploy an SLO-violating winner.

:func:`serving_plan` is the imperative feasibility gate (raises
:class:`~repro.explore.constraints.InfeasiblePoint`); its declarative twin
is ``repro.explore.constraints.default_constraints()`` — the prune/plan
agreement property test in ``tests/test_explore.py`` holds them together.
"""

from __future__ import annotations

import dataclasses
import math
import re
import time
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.explore.constraints import InfeasiblePoint

# The metrics a scenario run yields — the vocabulary serving-mode
# objectives and SLO constraints may reference.
SERVING_METRIC_KEYS = frozenset({
    "samples_per_s", "p50_ms", "p95_ms", "p99_ms", "mean_ms",
    "deadline_miss_rate", "gops_per_watt", "wall_s", "waves",
    "mean_occupancy", "deadline_flushes",
})

# Serving metrics whose "better" direction is "smaller".
SERVING_MINIMISE = ("p50_ms", "p95_ms", "p99_ms", "mean_ms",
                    "deadline_miss_rate", "wall_s", "deadline_flushes")

_SLO_RE = re.compile(
    r"^\s*([A-Za-z_][A-Za-z_0-9]*)\s*(<=|>=|<|>)\s*"
    r"([-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?)\s*$")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One service-level bound over a scenario metric, e.g. ``p99_ms <= 5``.

    ``ok(metrics)`` is the feasibility predicate; ``violation(metrics)``
    is the magnitude by which the bound is missed (0 when satisfied,
    ``inf`` for a missing/non-finite metric) — the tie-breaking measure
    successive halving ranks infeasible candidates by."""

    metric: str
    op: str
    bound: float

    @classmethod
    def parse(cls, text: str) -> "SLO":
        """Parse ``"<metric><op><bound>"`` (ops: ``<= >= < >``)."""
        m = _SLO_RE.match(text)
        if not m:
            raise ValueError(
                f"cannot parse SLO constraint {text!r}; expected "
                f"'<metric><op><bound>' like 'p99_ms<=5'")
        metric, op, bound = m.group(1), m.group(2), float(m.group(3))
        return cls(metric, op, bound)

    def ok(self, metrics) -> bool:
        """True iff ``metrics`` carries a finite value satisfying the
        bound."""
        v = metrics.get(self.metric)
        if v is None or not math.isfinite(float(v)):
            return False
        v = float(v)
        return {"<=": v <= self.bound, "<": v < self.bound,
                ">=": v >= self.bound, ">": v > self.bound}[self.op]

    def violation(self, metrics) -> float:
        """How far past the bound the point is (0 when feasible)."""
        v = metrics.get(self.metric)
        if v is None or not math.isfinite(float(v)):
            return float("inf")
        v = float(v)
        if self.op in ("<=", "<"):
            return max(0.0, v - self.bound)
        return max(0.0, self.bound - v)

    def describe(self) -> str:
        """The canonical string form, re-parseable by :meth:`parse`."""
        return f"{self.metric}{self.op}{self.bound:g}"


@dataclasses.dataclass(frozen=True)
class SLOSet:
    """A conjunction of :class:`SLO` terms (comma-separated in string
    form); feasible iff every term is, violation = sum of the terms'."""

    terms: Tuple[SLO, ...]

    def ok(self, metrics) -> bool:
        """All terms satisfied."""
        return all(t.ok(metrics) for t in self.terms)

    def violation(self, metrics) -> float:
        """Summed per-term violation magnitude."""
        return sum(t.violation(metrics) for t in self.terms)

    def describe(self) -> str:
        """Comma-joined canonical form."""
        return ",".join(t.describe() for t in self.terms)


def parse_constraint(spec: Union[str, SLO, SLOSet, None]
                     ) -> Optional[Union[SLO, SLOSet]]:
    """Normalise an SLO spec: ``None`` passes through, strings parse
    (``","`` separates conjunctive terms), SLO/SLOSet return as-is."""
    if spec is None or isinstance(spec, (SLO, SLOSet)):
        return spec
    terms = tuple(SLO.parse(t) for t in str(spec).split(",") if t.strip())
    if not terms:
        raise ValueError(f"empty SLO constraint {spec!r}")
    for t in terms:
        if t.metric not in SERVING_METRIC_KEYS:
            raise ValueError(
                f"unknown SLO metric {t.metric!r}; known: "
                f"{sorted(SERVING_METRIC_KEYS)}")
    return terms[0] if len(terms) == 1 else SLOSet(terms)


@dataclasses.dataclass(frozen=True)
class ServingScenario:
    """A serving operating point: who arrives, how fast, and the deadline.

    ``streams`` named clients each submit ``windows_per_stream`` windows of
    ``window_len`` steps (``None`` = the model's ``seq_len``); ``arrival_hz``
    paces the per-stream window arrival rate (``None`` = closed loop, as
    fast as the server absorbs them); ``deadline_ms`` is the wave-assembly
    deadline (``ServingConfig.deadline_s``).  ``run(session)`` measures a
    session at this operating point and returns the serving objectives."""

    streams: int = 8
    windows_per_stream: int = 4
    window_len: Optional[int] = None
    arrival_hz: Optional[float] = None
    deadline_ms: float = 10.0
    batch: Optional[int] = None
    seed: int = 0
    name: str = "scenario"

    def __post_init__(self):
        if self.streams < 1 or self.windows_per_stream < 1:
            raise ValueError("a scenario needs >= 1 stream and >= 1 window "
                             f"per stream, got streams={self.streams}, "
                             f"windows_per_stream={self.windows_per_stream}")

    def truncated(self, fraction: float) -> "ServingScenario":
        """A cheaper copy for an early halving rung: the window count is
        scaled by ``fraction`` (floored at one window per stream);
        ``fraction >= 1`` returns the scenario itself."""
        if fraction >= 1.0:
            return self
        wins = max(1, int(math.ceil(self.windows_per_stream * fraction)))
        return dataclasses.replace(
            self, windows_per_stream=wins,
            name=f"{self.name}@{fraction:g}")

    @property
    def label(self) -> str:
        """Stable id, e.g. ``scenario_s8w4_d10``."""
        return (f"{self.name}_s{self.streams}w{self.windows_per_stream}"
                f"_d{self.deadline_ms:g}")

    def asdict(self) -> dict:
        """JSON form for the BENCH_pareto payload."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ServingScenario":
        """Rebuild from :meth:`asdict` (a stored payload's ``scenario``)."""
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})

    def run(self, session, *, batch: Optional[int] = None,
            replicas: int = 1, state_residency: str = "auto",
            devices=None) -> Dict[str, float]:
        """Measure ``session`` at this operating point.

        Stands up a real ``StreamServer`` (``replicas == 1``) or
        ``ClusterServer`` (via ``repro.api.build_cluster``), warms the
        datapath, takes the short-run reset (``reset_streams()`` +
        ``reset_metrics()``), drives the load, and returns the
        ``SERVING_METRIC_KEYS`` objectives derived from
        ``metrics_summary()``."""
        from repro.serving.server import ServingConfig, StreamServer

        b = batch if batch is not None else (
            self.batch if self.batch is not None else self.streams)
        t = self.window_len or session.model.seq_len
        rng = np.random.default_rng(self.seed)
        xs = (rng.standard_normal(
            (self.streams, self.windows_per_stream, t,
             session.model.input_size)) * 0.5).astype(np.float32)
        kw = dict(batch=b, deadline_s=self.deadline_ms / 1e3,
                  state_residency=state_residency,
                  max_streams=max(16, 2 * self.streams))
        if replicas > 1:
            from repro.api import build_cluster
            server = build_cluster(session, replicas, devices=devices, **kw)
        else:
            server = StreamServer(session, ServingConfig(**kw))
        try:
            warm = np.zeros((t, session.model.input_size), np.float32)
            if replicas > 1:
                server.warmup(warm)
            else:
                server.submit("__scenario_warmup__", warm)
                server.drain()
            server.reset_streams()
            server.reset_metrics()
            t0 = time.perf_counter()
            for w in range(self.windows_per_stream):
                if self.arrival_hz:
                    target = t0 + w / self.arrival_hz
                    delay = target - time.perf_counter()
                    if delay > 0:
                        time.sleep(delay)
                for s in range(self.streams):
                    server.submit(f"s{s:04d}", xs[s, w])
            server.drain()
            summary = server.metrics_summary()
        finally:
            server.close()
        return scenario_metrics(summary)


def scenario_metrics(summary: Dict) -> Dict[str, float]:
    """Flatten a ``metrics_summary()`` dict into the scenario-objective
    vocabulary (:data:`SERVING_METRIC_KEYS`)."""
    lat = summary.get("latency_ms") or {}
    faults = summary.get("faults") or {}
    nan = float("nan")
    return {
        "samples_per_s": float(summary.get("samples_per_s", 0.0)),
        "p50_ms": float(lat.get("p50", nan)),
        "p95_ms": float(lat.get("p95", nan)),
        "p99_ms": float(lat.get("p99", nan)),
        "mean_ms": float(lat.get("mean", nan)),
        "deadline_miss_rate": float(faults.get("deadline_miss_rate", 0.0)),
        "gops_per_watt": float(summary.get("gops_per_watt", nan)),
        "wall_s": float(summary.get("wall_s", nan)),
        "waves": float(summary.get("waves", 0)),
        "mean_occupancy": float(summary.get("mean_occupancy", nan)),
        "deadline_flushes": float(summary.get("deadline_flushes", 0)),
    }


def serving_plan(point, base_model=None, base_accel=None) -> Dict:
    """Resolve how a point would actually serve — or raise
    :class:`InfeasiblePoint` when it cannot.

    The checks are the imperative form of
    ``constraints.default_constraints()``: the (possibly explicit) backend
    must carry state for the configuration, pinned device residency needs
    the fused stateful plan, and ``replicas`` distinct devices must exist
    (production posture of ``launch.mesh.serving_devices``)."""
    from repro import backends
    from repro.core.accelerator import plan as _plan
    model_cfg, accel_cfg = point.configs(base_model, base_accel)
    try:
        engine = backends.select_stateful(model_cfg, accel_cfg)
    except backends.BackendUnsupported as e:
        raise InfeasiblePoint(f"backend: {e}") from e
    pl = _plan(model_cfg, accel_cfg)
    if point.state_residency == "device" \
            and pl["state_residency"] != "device":
        raise InfeasiblePoint(
            f"state_residency: device-resident carry needs the fused "
            f"stateful plan; cell={point.cell!r} on "
            f"backend={point.backend!r} resolves to "
            f"stateful_backend={pl['stateful_backend']!r}")
    if point.replicas > 1:
        from repro.launch.mesh import serving_devices
        try:
            serving_devices(point.replicas, oversubscribe=False)
        except (RuntimeError, ValueError) as e:
            raise InfeasiblePoint(f"replicas: {e}") from e
    residency = (point.state_residency if point.state_residency != "auto"
                 else pl["state_residency"])
    return {
        "backend": engine.name,
        "stateful_backend": pl["stateful_backend"],
        "state_residency": residency,
        "replicas": point.replicas,
    }


def evaluate_serving_point(point, scenario: ServingScenario,
                           base_model=None, base_accel=None, *,
                           seed: int = 0, session=None) -> Dict:
    """Build (or reuse) the point's session and measure it under
    ``scenario`` — the serving-mode analogue of
    ``measure.evaluate_point``.  Raises :class:`InfeasiblePoint` for
    points :func:`serving_plan` rejects.  Returns the sweep-row dict."""
    pl = serving_plan(point, base_model, base_accel)
    if session is None:
        from repro.api import build
        model_cfg, accel_cfg = point.configs(base_model, base_accel)
        session = build(model_cfg, accel_cfg, seed=seed).quantize()
    metrics = scenario.run(session, batch=point.batch,
                           replicas=point.replicas,
                           state_residency=point.state_residency)
    return {
        "label": point.label,
        "config": point.asdict(),
        "status": "ok",
        "plan": pl,
        "metrics": metrics,
    }
