"""Declarative, composable validity constraints over search-space points.

The serving-aware search space (cells x backends x residency x replicas)
contains points that are *structurally* infeasible — device-resident state
on a cell with no fused kernel, more replicas than devices, an explicit
backend that refuses the configuration.  Measuring them would waste a
build + scenario run each, so the space prunes them up front.

The pruning rules are composed declaratively, node-style: every rule is a
:class:`ConstraintNode`; ``&`` / ``|`` / ``~`` build composite trees out of
leaves, exactly like an expression graph — a new axis ships its validity
rule as one more leaf ANDed into :func:`default_constraints` instead of a
branch inside the sweep loop.  A node's ``check(point, ...)`` returns
``None`` for a feasible point or a human-readable reason string (prefixed
with the violated rule's name, so the sweep can attribute eliminations per
rule).

The imperative twin of this module is
:func:`repro.explore.serving_objective.serving_plan`, which *raises* on the
same points; ``tests/test_explore.py`` holds the two in agreement
(prune/plan property test).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple


class InfeasiblePoint(ValueError):
    """A search-space point that cannot be deployed as configured (the
    imperative form of a failed :class:`ConstraintNode` check)."""


@dataclasses.dataclass(frozen=True)
class ConstraintNode:
    """Base of the composable constraint tree.

    Subclasses implement :meth:`check`; composition is structural —
    ``a & b`` (both must hold), ``a | b`` (either suffices), ``~a``
    (must fail) — so a search space's validity predicate is data, not
    control flow."""

    def check(self, point, base_model=None, base_accel=None
              ) -> Optional[str]:
        """``None`` when ``point`` is feasible, else the reason."""
        raise NotImplementedError

    @property
    def name(self) -> str:
        """Short structural label used in composed reasons."""
        raise NotImplementedError

    def __and__(self, other: "ConstraintNode") -> "AllOf":
        return AllOf((self, other))

    def __or__(self, other: "ConstraintNode") -> "AnyOf":
        return AnyOf((self, other))

    def __invert__(self) -> "Not":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Rule(ConstraintNode):
    """A leaf: a named predicate over ``(point, base_model, base_accel)``
    returning ``None`` (feasible) or a reason fragment."""

    rule_name: str
    fn: Callable = dataclasses.field(compare=False)

    def check(self, point, base_model=None, base_accel=None
              ) -> Optional[str]:
        reason = self.fn(point, base_model, base_accel)
        return None if reason is None else f"{self.rule_name}: {reason}"

    @property
    def name(self) -> str:
        return self.rule_name


@dataclasses.dataclass(frozen=True)
class AllOf(ConstraintNode):
    """Conjunction: feasible iff every child is; reports the FIRST
    violated child's reason (children are checked in order, cheap rules
    first by construction)."""

    children: Tuple[ConstraintNode, ...]

    def check(self, point, base_model=None, base_accel=None
              ) -> Optional[str]:
        for child in self.children:
            reason = child.check(point, base_model, base_accel)
            if reason is not None:
                return reason
        return None

    @property
    def name(self) -> str:
        return "(" + " & ".join(c.name for c in self.children) + ")"


@dataclasses.dataclass(frozen=True)
class AnyOf(ConstraintNode):
    """Disjunction: feasible iff at least one child is; reports every
    child's reason when all fail."""

    children: Tuple[ConstraintNode, ...]

    def check(self, point, base_model=None, base_accel=None
              ) -> Optional[str]:
        reasons = []
        for child in self.children:
            reason = child.check(point, base_model, base_accel)
            if reason is None:
                return None
            reasons.append(reason)
        return " | ".join(reasons)

    @property
    def name(self) -> str:
        return "(" + " | ".join(c.name for c in self.children) + ")"


@dataclasses.dataclass(frozen=True)
class Not(ConstraintNode):
    """Negation: feasible iff the child is NOT."""

    child: ConstraintNode

    def check(self, point, base_model=None, base_accel=None
              ) -> Optional[str]:
        reason = self.child.check(point, base_model, base_accel)
        if reason is None:
            return f"~{self.child.name}: point satisfies the negated rule"
        return None

    @property
    def name(self) -> str:
        return f"~{self.child.name}"


# -- the built-in leaves ------------------------------------------------------

def _backend_supported(point, base_model, base_accel) -> Optional[str]:
    if point.backend == "auto":
        return None         # auto always resolves to something runnable
    from repro import backends
    model_cfg, accel_cfg = point.configs(base_model, base_accel)
    try:
        backends.select_stateful(model_cfg, accel_cfg)
    except backends.BackendUnsupported as e:
        return str(e)
    return None


def backend_supported() -> Rule:
    """An explicit (non-``auto``) backend must accept the configuration —
    the fused pallas engine refuses e.g. ``alu_mode='per_step'``."""
    return Rule("backend_supported", _backend_supported)


def _device_residency_fused(point, base_model, base_accel) -> Optional[str]:
    if point.state_residency != "device":
        return None
    from repro.core.accelerator import plan
    model_cfg, accel_cfg = point.configs(base_model, base_accel)
    pl = plan(model_cfg, accel_cfg)
    if pl["state_residency"] != "device":
        return (f"device-resident carry needs the fused stateful plan; "
                f"cell={point.cell!r} on backend={point.backend!r} resolves "
                f"to stateful_backend={pl['stateful_backend']!r} (host "
                f"residency)")
    return None


def device_residency_needs_fused() -> Rule:
    """``state_residency='device'`` is only a deployable operating point
    where the plan itself resolves device residency (the fused pallas
    stateful path); pinning it elsewhere measures an adapter degradation,
    not a design point."""
    return Rule("device_residency", _device_residency_fused)


def _replicas_fit(point, base_model, base_accel) -> Optional[str]:
    if point.replicas <= 1:
        return None
    from repro.launch.mesh import serving_devices
    try:
        serving_devices(point.replicas, oversubscribe=False)
    except (RuntimeError, ValueError) as e:
        return str(e)
    return None


def replicas_fit_devices() -> Rule:
    """An ``n``-replica point needs ``n`` distinct devices (the production
    posture of ``launch.mesh.serving_devices``) — a replica that silently
    shares a device is a capacity-planning bug, not a candidate."""
    return Rule("replicas_fit_devices", _replicas_fit)


def default_constraints() -> ConstraintNode:
    """The composite every :class:`~repro.explore.space.SearchSpace`
    applies unless it carries its own tree: backend feasibility AND
    fused-plan device residency AND replica/device fit."""
    return (backend_supported()
            & device_residency_needs_fused()
            & replicas_fit_devices())
