"""Design-space exploration over the parameterised accelerator.

The paper's claim is not one good configuration but a *parameterised
design*: Table-2 meta-parameters span a space of accelerators, each scored
by throughput (GOP/s), energy efficiency (GOP/s/W) and accuracy.  This
package makes that claim executable:

    from repro import explore

    space = explore.paper_space()            # Table-4 axes as a SearchSpace
    result = explore.sweep(space, iters=5)   # build+measure every point
    front = [p for p in result["points"] if p["pareto"]]

    session = explore.autotune(              # best deployable session
        objective="gops_per_watt",
        constraints={"samples_per_s": (30_000, None)})

Layout:

  * ``space``    — :class:`SearchSpace` / :class:`Point` over the Table-2
                   axes (fxp, hs_method, compute_unit, alu_mode, layer
                   width/depth, serve batch, backend).
  * ``measure``  — :func:`evaluate_point` / :func:`sweep`: build each point
                   through ``repro.build``, time the jitted int path, score
                   with the energy model and the float-reference deviation.
  * ``pareto``   — :func:`dominates` / :func:`pareto_front` /
                   :func:`pareto_indices` (any number of objectives,
                   max/min senses).
  * ``autotune`` — :func:`autotune`: constrained argmax on the feasible
                   Pareto front, returning a quantised ``Accelerator``.

``benchmarks/run.py --sweep`` drives :func:`sweep` into
``BENCH_pareto.json``; ``repro.analysis.report --pareto`` renders that
artifact as a markdown table.
"""

from repro.explore.autotune import autotune  # noqa: F401
from repro.explore.measure import (METRIC_KEYS, SCHEMA_VERSION,  # noqa: F401
                                   evaluate_point, sweep)
from repro.explore.pareto import (DEFAULT_OBJECTIVES, dominates,  # noqa: F401
                                  pareto_front, pareto_indices)
from repro.explore.space import (AXES, Point, SearchSpace,  # noqa: F401
                                 paper_space, smoke_space)

__all__ = [
    "AXES", "DEFAULT_OBJECTIVES", "METRIC_KEYS", "Point", "SCHEMA_VERSION",
    "SearchSpace", "autotune", "dominates", "evaluate_point", "paper_space",
    "pareto_front", "pareto_indices", "smoke_space", "sweep",
]
