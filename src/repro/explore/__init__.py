"""Design-space exploration over the parameterised accelerator.

The paper's claim is not one good configuration but a *parameterised
design*: Table-2 meta-parameters span a space of accelerators, each scored
by throughput (GOP/s), energy efficiency (GOP/s/W) and accuracy.  This
package makes that claim executable — offline and at a serving operating
point:

    from repro import explore

    space = explore.paper_space()            # Table-4 axes as a SearchSpace
    result = explore.sweep(space, iters=5)   # build+measure every point
    front = [p for p in result["points"] if p["pareto"]]

    session = explore.autotune(              # best deployable session
        objective="gops_per_watt",
        constraints={"samples_per_s": (30_000, None)})

    scenario = explore.ServingScenario(streams=8, deadline_ms=5.0)
    session = explore.autotune(              # serving-aware: SLO-constrained
        objective="samples_per_s",           # successive halving over real
        constraint="p99_ms<=5",              # StreamServer runs
        space=space, scenario=scenario)

Layout:

  * ``space``       — :class:`SearchSpace` / :class:`Point` over the
                      Table-2 axes (fxp, hs_method, compute_unit,
                      alu_mode, layer width/depth, serve batch, backend,
                      cell) plus the serving deployment axes (replicas,
                      state_residency).
  * ``constraints`` — declarative, composable validity rules
                      (node-composition: ``&``/``|``/``~``) pruning
                      structurally infeasible points before measurement.
  * ``measure``     — :func:`evaluate_point` / :func:`sweep`: build each
                      point through ``repro.build``; offline timed loops
                      or real ``ServingScenario`` runs per point
                      (``strategy="halving"`` for successive halving).
  * ``serving_objective`` — :class:`ServingScenario`,
                      :func:`parse_constraint` (SLO strings like
                      ``"p99_ms<=5"``), :func:`serving_plan`.
  * ``halving``     — :func:`successive_halving`: the pure seeded
                      rung-promotion algorithm.
  * ``pareto``      — :func:`dominates` / :func:`pareto_front` /
                      :func:`constrained_pareto_front` (any number of
                      objectives, max/min senses; raises
                      :class:`ExploreError` instead of returning a silent
                      empty front).
  * ``autotune``    — :func:`autotune`: constrained argmax on the feasible
                      Pareto front, returning a quantised ``Accelerator``.

``benchmarks/run.py --sweep`` drives :func:`sweep` into
``BENCH_pareto.json`` (schema v2); ``repro.analysis.report --pareto``
renders that artifact as a markdown table.
"""

from repro.explore.autotune import autotune  # noqa: F401
from repro.explore.constraints import (AllOf, AnyOf,  # noqa: F401
                                       ConstraintNode, InfeasiblePoint, Not,
                                       Rule, backend_supported,
                                       default_constraints,
                                       device_residency_needs_fused,
                                       replicas_fit_devices)
from repro.explore.halving import (rung_schedule,  # noqa: F401
                                   successive_halving)
from repro.explore.measure import (METRIC_KEYS, SCHEMA_VERSION,  # noqa: F401
                                   SERVING_OBJECTIVES, evaluate_point, sweep)
from repro.explore.pareto import (DEFAULT_OBJECTIVES,  # noqa: F401
                                  ExploreError, constrained_pareto_front,
                                  dominates, pareto_front, pareto_indices)
from repro.explore.serving_objective import (SERVING_METRIC_KEYS,  # noqa: F401
                                             SERVING_MINIMISE, SLO, SLOSet,
                                             ServingScenario,
                                             evaluate_serving_point,
                                             parse_constraint, serving_plan)
from repro.explore.space import (AXES, Point, SearchSpace,  # noqa: F401
                                 paper_space, point_from_config, smoke_space)

__all__ = [
    "AXES", "AllOf", "AnyOf", "ConstraintNode", "DEFAULT_OBJECTIVES",
    "ExploreError", "InfeasiblePoint", "METRIC_KEYS", "Not", "Point",
    "Rule", "SCHEMA_VERSION", "SERVING_METRIC_KEYS", "SERVING_MINIMISE",
    "SERVING_OBJECTIVES", "SLO", "SLOSet", "SearchSpace", "ServingScenario",
    "autotune", "backend_supported", "constrained_pareto_front",
    "default_constraints", "device_residency_needs_fused", "dominates",
    "evaluate_point", "evaluate_serving_point", "paper_space",
    "pareto_front", "pareto_indices", "parse_constraint",
    "point_from_config", "replicas_fit_devices", "rung_schedule",
    "serving_plan", "smoke_space", "successive_halving", "sweep",
]
