"""Declarative search space over the paper's Table-2 meta-parameters.

A :class:`SearchSpace` is a finite set of choices per axis; every axis is a
tuple of candidate values and a configuration *point* is one value per axis.
The axes are exactly the knobs the paper sweeps by rebuilding the bitstream
(fixed-point format, HardSigmoid* method, ALU resource type, ALU pipelining)
plus the deployment-side parameters the TPU re-expression adds (layer
width/depth, serve batch size, execution backend) and the recurrent cell
itself (``repro.cells``: lstm | gru | rglru — the scenario-diversity
axis).

``Point.configs()`` turns a point into the ``(QLSTMConfig,
AcceleratorConfig)`` pair that ``repro.build`` compiles — the search space
never bypasses the session API, so anything it scores is exactly what a user
would deploy.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core.accelerator import (ALU_MODES, BACKENDS, HS_METHODS,
                                    AcceleratorConfig)
from repro.core.fixed_point import FXP_4_8, FXP_8_16, FixedPointConfig
from repro.core.qlstm import QLSTMConfig

# Axis order is the canonical iteration order of ``grid()`` — stable across
# runs so sweep artifacts diff cleanly.
AXES = ("fxp", "hs_method", "compute_unit", "alu_mode",
        "hidden_size", "num_layers", "batch", "backend", "cell",
        "replicas", "state_residency")

STATE_RESIDENCIES = ("auto", "host", "device")


@dataclasses.dataclass(frozen=True)
class Point:
    """One configuration point: a value per axis of the search space."""

    fxp: FixedPointConfig
    hs_method: str
    compute_unit: str
    alu_mode: str
    hidden_size: int
    num_layers: int
    batch: int
    backend: str
    # The recurrent cell id (default keeps pre-cell-axis records and
    # Point(...) call sites valid).
    cell: str = "lstm"
    # Serving-side deployment axes (defaults keep pre-serving-axis records
    # and positional Point(...) call sites valid): how many cluster
    # replicas the point deploys as, and where the per-stream carry lives
    # (auto | host | device — the ServingConfig knob).
    replicas: int = 1
    state_residency: str = "auto"

    def configs(self, base_model: Optional[QLSTMConfig] = None,
                base_accel: Optional[AcceleratorConfig] = None,
                ) -> Tuple[QLSTMConfig, AcceleratorConfig]:
        """The ``(model, accelerator)`` pair this point deploys as.

        ``base_model`` carries the non-swept functional parameters
        (input_size, out_features, seq_len, activation family);
        ``base_accel`` the non-swept implementation ones (weight_memory,
        vmem_budget, ht thresholds)."""
        model = dataclasses.replace(base_model or QLSTMConfig(),
                                    hidden_size=self.hidden_size,
                                    num_layers=self.num_layers,
                                    cell=self.cell)
        accel = dataclasses.replace(base_accel or AcceleratorConfig(),
                                    fxp=self.fxp, hs_method=self.hs_method,
                                    compute_unit=self.compute_unit,
                                    alu_mode=self.alu_mode,
                                    backend=self.backend)
        return model, accel

    @property
    def label(self) -> str:
        """Stable human/machine-readable id, e.g.
        ``a4b8_step_mxu_pipelined_h20x1_b256_auto`` (non-LSTM cells get
        a ``_gru``/``_rglru`` suffix; non-default serving axes append
        ``_rN`` / ``_host``/``_device``.  Default-axis labels are
        unchanged from earlier eras so existing sweep artifacts still
        diff cleanly)."""
        base = (f"a{self.fxp.frac_bits}b{self.fxp.total_bits}_"
                f"{self.hs_method}_{self.compute_unit}_{self.alu_mode}_"
                f"h{self.hidden_size}x{self.num_layers}_b{self.batch}_"
                f"{self.backend}")
        if self.cell != "lstm":
            base += f"_{self.cell}"
        if self.replicas != 1:
            base += f"_r{self.replicas}"
        if self.state_residency != "auto":
            base += f"_{self.state_residency}"
        return base

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fxp"] = {"frac_bits": self.fxp.frac_bits,
                    "total_bits": self.fxp.total_bits}
        return d


def _as_tuple(v) -> tuple:
    if isinstance(v, (list, tuple)):
        return tuple(v)
    return (v,)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Finite choices per Table-2 axis.  Each field accepts a single value
    or a sequence; singletons pin the axis.

    ``constraints`` is the space's declarative validity tree (a
    ``repro.explore.constraints.ConstraintNode``; ``None`` = the package
    default) — infeasible points are pruned before measurement, see
    :meth:`feasible`."""

    fxp: Sequence[FixedPointConfig] = (FXP_4_8,)
    hs_method: Sequence[str] = ("step",)
    compute_unit: Sequence[str] = ("mxu",)
    alu_mode: Sequence[str] = ("pipelined",)
    hidden_size: Sequence[int] = (20,)
    num_layers: Sequence[int] = (1,)
    batch: Sequence[int] = (256,)
    backend: Sequence[str] = ("auto",)
    cell: Sequence[str] = ("lstm",)
    replicas: Sequence[int] = (1,)
    state_residency: Sequence[str] = ("auto",)
    constraints: Optional[object] = None

    def __post_init__(self):
        for axis in AXES:
            object.__setattr__(self, axis, _as_tuple(getattr(self, axis)))
            if not getattr(self, axis):
                raise ValueError(f"search axis {axis!r} has no choices")
        for v in self.fxp:
            if not isinstance(v, FixedPointConfig):
                raise ValueError(f"fxp choices must be FixedPointConfig, "
                                 f"got {v!r}")
        _check("hs_method", self.hs_method, HS_METHODS)
        _check("compute_unit", self.compute_unit, ("mxu", "vpu"))
        _check("alu_mode", self.alu_mode, ALU_MODES)
        _check("backend", self.backend, BACKENDS)
        _check("state_residency", self.state_residency, STATE_RESIDENCIES)
        from repro import cells as _cells
        _check("cell", self.cell, _cells.available())
        for axis in ("hidden_size", "num_layers", "batch", "replicas"):
            for v in getattr(self, axis):
                if not isinstance(v, int) or v < 1:
                    raise ValueError(f"{axis} choices must be positive ints, "
                                     f"got {v!r}")

    def feasible(self, point: Point, base_model=None, base_accel=None
                 ) -> Optional[str]:
        """``None`` when ``point`` passes the space's constraint tree,
        else the violated rule's reason (prefixed with its name).  The
        sweep prunes non-``None`` points before measurement and records
        them with the reason."""
        node = self.constraints
        if node is None:
            from repro.explore.constraints import default_constraints
            node = default_constraints()
        return node.check(point, base_model, base_accel)

    @property
    def size(self) -> int:
        n = 1
        for axis in AXES:
            n *= len(getattr(self, axis))
        return n

    def grid(self) -> Iterator[Point]:
        """Every point, in canonical (AXES-major) order."""
        for combo in itertools.product(*(getattr(self, a) for a in AXES)):
            yield Point(*combo)

    def sample(self, n: int, seed: int = 0) -> Tuple[Point, ...]:
        """``n`` distinct points drawn uniformly without replacement (the
        whole grid, in sampled order, if ``n >= size``)."""
        rng = np.random.default_rng(seed)
        if n >= self.size:
            pts = list(self.grid())
            rng.shuffle(pts)
            return tuple(pts)
        picked = rng.choice(self.size, size=n, replace=False)
        sizes = [len(getattr(self, a)) for a in AXES]
        out = []
        for flat in sorted(int(i) for i in picked):
            idx, combo = flat, []
            for a, k in zip(reversed(AXES), reversed(sizes)):
                idx, r = divmod(idx, k)
                combo.append(getattr(self, a)[r])
            out.append(Point(*reversed(combo)))
        return tuple(out)

    def asdict(self) -> dict:
        d = {a: list(getattr(self, a)) for a in AXES}
        d["fxp"] = [{"frac_bits": f.frac_bits, "total_bits": f.total_bits}
                    for f in self.fxp]
        return d


def point_from_config(config: dict) -> Point:
    """Rebuild a :class:`Point` from its ``asdict()`` form (the ``config``
    record of a sweep row) — lets ``autotune`` redeploy a point from a saved
    ``BENCH_pareto.json`` without re-running the sweep."""
    kw = dict(config)
    kw["fxp"] = FixedPointConfig(kw["fxp"]["frac_bits"],
                                 kw["fxp"]["total_bits"])
    # Records written before the cell / serving axes existed have no keys
    # for them — they were single-replica LSTM points with auto residency.
    kw.setdefault("cell", "lstm")
    kw.setdefault("replicas", 1)
    kw.setdefault("state_residency", "auto")
    return Point(**{a: kw[a] for a in AXES})


def _check(axis: str, choices: tuple, allowed: tuple) -> None:
    for v in choices:
        if v not in allowed:
            raise ValueError(f"{axis} choice {v!r} not in {allowed}")


def paper_space(batch: int = 256) -> SearchSpace:
    """The Table-4 comparison as a search space: both compute units, both
    ALU modes, every HardSigmoid* method, this work's (4,8) format vs the
    baseline's (8,16)."""
    return SearchSpace(fxp=(FXP_4_8, FXP_8_16),
                       hs_method=HS_METHODS,
                       compute_unit=("mxu", "vpu"),
                       alu_mode=ALU_MODES,
                       batch=(batch,))


def smoke_space(batch: int = 32, cell: Sequence[str] = ("lstm",),
                replicas: Sequence[int] = (1,),
                state_residency: Sequence[str] = ("auto",)) -> SearchSpace:
    """Four cheap CPU-safe points per cell (fixed-point format x ALU
    mode) — the deterministic sweep CI runs and tests assert on.  ``cell``
    widens the sweep across the registered cell zoo (``bench_pareto``
    passes all three); ``replicas``/``state_residency`` open the serving
    deployment axes for scenario sweeps."""
    return SearchSpace(fxp=(FXP_4_8, FXP_8_16), alu_mode=ALU_MODES,
                       batch=(batch,), cell=cell, replicas=replicas,
                       state_residency=state_residency)
