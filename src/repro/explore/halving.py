"""Seeded successive halving over a candidate set.

Pure algorithm, no server: ``measure(item, rung, fraction)`` is injected,
so the deterministic battery in ``tests/test_halving.py`` drives it with
synthetic measurement tables and the sweep drives it with real
``ServingScenario`` runs.  Rung 0 measures every candidate on the cheapest
truncated scenario; each rung promotes the top ``1/eta`` on the constrained
objective to a longer scenario; the final rung runs the full scenario
(fraction 1.0).  Every decision — ranking, tie-breaking, promotion — is a
deterministic function of the measurements, and the measurements are a
deterministic function of the caller's seed, so two identical runs produce
identical rung-promotion traces.

Ranking under a constraint: feasible candidates sort by the signed
objective, every infeasible candidate sorts BELOW every feasible one,
ordered by constraint-violation magnitude (so an all-infeasible rung still
promotes the least-violating survivors and terminates).  Ties break by
input index — stable and seed-independent.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.explore.pareto import ExploreError

__all__ = ["rung_schedule", "successive_halving"]


def rung_schedule(n: int, eta: int = 2, rungs: Optional[int] = None
                  ) -> Tuple[List[int], List[float]]:
    """The halving plan for ``n`` candidates: per-rung survivor counts and
    scenario fractions.

    Survivor counts follow ``n_{r+1} = max(1, ceil(n_r / eta))``; with
    ``rungs=None`` the schedule runs until a single survivor remains.
    Fractions are geometric, ``eta**(r - (rungs-1))``, so the final rung is
    always the full scenario (fraction 1.0).  The analytic measurement
    budget is ``sum(sizes)`` — every survivor is measured once per rung."""
    if n < 1:
        raise ExploreError("successive halving over an empty candidate set "
                           "(0 points survived pruning)")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    if rungs is None:
        rungs, size = 1, n
        while size > 1:
            size = max(1, math.ceil(size / eta))
            rungs += 1
    if rungs < 1:
        raise ValueError(f"rungs must be >= 1, got {rungs}")
    sizes = [n]
    for _ in range(1, rungs):
        sizes.append(max(1, math.ceil(sizes[-1] / eta)))
    fractions = [float(eta) ** (r - (rungs - 1)) for r in range(rungs)]
    return sizes, fractions


def successive_halving(items: Sequence, measure: Callable, *,
                       objective: str, sense: str = "max",
                       eta: int = 2, rungs: Optional[int] = None,
                       constraint=None, labels: Optional[Sequence[str]] = None,
                       log: Optional[Callable[[str], None]] = None) -> Dict:
    """Run the halving search and return the full decision trace.

    ``measure(item, rung, fraction)`` returns the item's metrics dict for
    that rung (``None`` = failed measurement, ranked as infinitely
    infeasible).  ``constraint`` is an SLO object (``ok(metrics)`` /
    ``violation(metrics)`` / ``describe()``, see
    ``serving_objective.parse_constraint``) or ``None``.

    Returns ``{"eta", "sizes", "fractions", "rungs": [{rung, fraction,
    measured, promoted}], "results": {index: last metrics}, "winner",
    "winner_label", "winner_feasible", "total_measurements",
    "budget_bound", "objective", "sense", "constraint"}`` — the trace the
    sweep payload records and the reproducibility tests compare."""
    if sense not in ("max", "min"):
        raise ValueError(f"sense must be 'max'|'min', got {sense!r}")
    sizes, fractions = rung_schedule(len(items), eta, rungs)
    labels = list(labels) if labels is not None \
        else [str(i) for i in range(len(items))]
    if len(labels) != len(items):
        raise ValueError(f"{len(labels)} labels for {len(items)} items")

    def rank_key(pair):
        idx, m = pair
        v = None if m is None else m.get(objective)
        finite = v is not None and math.isfinite(float(v))
        feasible = finite and (constraint is None or constraint.ok(m))
        if feasible:
            primary = -float(v) if sense == "max" else float(v)
            return (0, primary, idx)
        if constraint is not None and m is not None:
            return (1, constraint.violation(m), idx)
        return (1, float("inf"), idx)

    survivors = list(range(len(items)))
    results: Dict[int, Dict] = {}
    trace: List[Dict] = []
    total = 0
    ranked: List[Tuple[int, Optional[Dict]]] = []
    for r in range(len(sizes)):
        frac = fractions[r]
        scored = []
        for idx in survivors:
            m = measure(items[idx], r, frac)
            total += 1
            if m is not None:
                results[idx] = m
            scored.append((idx, m))
        ranked = sorted(scored, key=rank_key)
        rec = {"rung": r, "fraction": frac,
               "measured": [labels[i] for i, _ in scored],
               "ranking": [labels[i] for i, _ in ranked],
               "promoted": []}
        if r + 1 < len(sizes):
            survivors = [i for i, _ in ranked[:sizes[r + 1]]]
            rec["promoted"] = [labels[i] for i in survivors]
        trace.append(rec)
        if log:
            log(f"[halving r{r}] fraction={frac:g} measured={len(scored)} "
                f"promoted={len(rec['promoted'])}")

    winner_idx, winner_m = ranked[0]
    feasible = (winner_m is not None
                and winner_m.get(objective) is not None
                and math.isfinite(float(winner_m[objective]))
                and (constraint is None or constraint.ok(winner_m)))
    return {
        "eta": eta,
        "sizes": sizes,
        "fractions": fractions,
        "rungs": trace,
        "results": results,
        "winner": winner_idx,
        "winner_label": labels[winner_idx],
        "winner_feasible": feasible,
        "total_measurements": total,
        "budget_bound": sum(sizes),
        "objective": objective,
        "sense": sense,
        "constraint": constraint.describe() if constraint is not None
        else None,
    }
