"""``lstm`` cell spec — the paper's quantised LSTM, promoted unchanged.

All datapaths live in ``core.qlstm`` (the float/QAT forwards, the general
integer scan) and ``kernels/ref.py`` (the pure-jnp oracle); this module
only adapts them to the :class:`repro.cells.CellSpec` contract.  The LSTM
is the one cell with a fused Pallas kernel
(``kernels/qlstm_cell.qlstm_seq_pallas`` and friends), so it is the only
spec with a ``supports_fused`` predicate.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.cells import CellSpec, paper_datapath_reason, register
from repro.core import qlstm
from repro.core.qlstm import QLSTMConfig
from repro.kernels import ref as _ref

Array = jax.Array


def ref_layer(x_tm: Array, p, model: QLSTMConfig, carry):
    """One oracle LSTM layer, time-major: (T, B, M) codes -> ((T, B, H),
    (h_last, c_last)) resumed from ``carry = (h0, c0)``."""
    acts = model.acts
    h0, c0 = carry
    hs, new_carry = _ref.qlstm_seq_ref(
        x_tm, p["w_x"], p["w_h"], p["b"], model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max,
        h0=h0, c0=c0, return_state=True)
    return hs, new_carry


def supports_int(model: QLSTMConfig, accel) -> Optional[str]:
    """None when the general int scan covers the configuration (every
    Table-2 point does), else the reason."""
    if model.acts.gate not in ("hard_sigmoid_star", "lut_sigmoid", "sigmoid"):
        return f"gate activation {model.acts.gate!r} has no integer datapath"
    if model.acts.cell not in ("hard_tanh", "lut_tanh", "tanh"):
        return f"cell activation {model.acts.cell!r} has no integer datapath"
    return None


def weight_bytes(model: QLSTMConfig, acc) -> int:
    """Bytes of quantised LSTM weights+biases the accelerator must hold."""
    itemsize = (acc.fxp.total_bits + 7) // 8
    wide_itemsize = 2 * itemsize
    total = 0
    for li in range(model.num_layers):
        m, h = model.layer_in_dim(li), model.hidden_size
        total += (m + h) * 4 * h * itemsize + 4 * h * wide_itemsize
    total += model.hidden_size * model.out_features * itemsize
    total += model.out_features * wide_itemsize
    return total


SPEC = register(CellSpec(
    name="lstm",
    state_arity=2,
    state_names=("h", "c"),
    init_params=qlstm.init_params,
    quantize_params=qlstm.quantize_params,
    forward_float=qlstm.forward_float,
    forward_qat=qlstm.forward_qat,
    run_int_stateful=qlstm.forward_int_stateful,
    ref_layer=ref_layer,
    supports_int=supports_int,
    supports_oracle=paper_datapath_reason,
    supports_fused=paper_datapath_reason,
    ops_per_inference=qlstm.ops_per_inference,
    weight_bytes=weight_bytes,
))
