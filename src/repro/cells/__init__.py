"""The quantised recurrent cell registry — one contract, many cells.

The paper's parameterised-design claim (and ROADMAP open item 2) is that
ONE accelerator datapath serves many recurrent scenarios.  This package is
that contract: a :class:`CellSpec` describes everything a cell must bring
to run on the accelerator — parameter tree, per-layer carry shape, the
bit-exact integer datapath, a pure-jnp ref oracle, and (optionally) a
fused Pallas kernel predicate — and every downstream layer (the backend
registry, ``repro.serving``, the explorer) dispatches through the spec
instead of hardcoding LSTM.

Registered cells:

  * ``lstm``  — the paper's quantised LSTM (``core.qlstm``): per-layer
    (h, c) carry, fused Pallas kernel for the pipelined + hard-activation
    point.
  * ``gru``   — quantised GRU (``cells.gru``): per-layer (h,) carry,
    gate order [r, z, n], same S5 single-late-rounding accumulator
    contract.
  * ``rglru`` — quantised RG-LRU (``cells.rglru``): the Griffin
    recurrence (``models/rglru.py``) re-derived for the fixed-point
    datapath — input-only sigmoid gates, a ``1 - r*lambda`` decay, and a
    convex ``a*h + (1-a)*(i*x)`` mix; per-layer (h,) carry.

Every cell shares the dense K -> P head and the int-path contract pinned
by ``tests/test_cells.py``: ref <-> xla bit-exactness, and
windowed-vs-concatenated bit-exactness through ``StreamServer``.

The per-layer integer carry is a tuple of ``state_arity`` int32 arrays of
shape ``(batch, hidden)`` — the LSTM's classic ``(h, c)`` is simply the
``arity == 2`` instance — and the whole-model state is a tuple of those
over layers (:func:`init_state` / :func:`state_shape`).  Serving keys its
host store rows and its device slot table ``(slots + 2, L, S, H)`` on
:func:`state_shape`, never on a hardcoded ``(L, 2, H)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qlstm import QLSTMConfig

Array = jax.Array


def paper_datapath_reason(model: QLSTMConfig, accel) -> Optional[str]:
    """Shared predicate for the engines that implement exactly the paper's
    pipelined (late-rounding) ALU with the hard activations — the ref
    oracles and the fused kernels.  Returns ``None`` when the resolved
    configuration is that point, else the reason it is not."""
    if model.alu_mode != "pipelined":
        return (f"alu_mode={model.alu_mode!r}: only the pipelined "
                "(late-rounding) ALU is implemented")
    if model.acts.gate != "hard_sigmoid_star":
        return f"gate activation {model.acts.gate!r}: needs hard_sigmoid_star"
    if model.acts.cell != "hard_tanh":
        return f"cell activation {model.acts.cell!r}: needs hard_tanh"
    return None


@dataclasses.dataclass(frozen=True)
class CellSpec:
    """Everything one recurrent cell brings to the accelerator contract.

    The callables mirror the LSTM surface of ``core.qlstm`` exactly;
    ``tests/test_cells.py`` holds every registered cell to the same
    battery shape (bit-exact ref <-> xla parity, stateful-serving
    bit-exactness)."""

    #: Registry id (the ``QLSTMConfig.cell`` value).
    name: str
    #: Arrays per layer in the integer carry (LSTM 2: (h, c); GRU/rGLRU 1).
    state_arity: int
    #: Debug/docs names of the carry arrays, e.g. ``("h", "c")``.
    state_names: Tuple[str, ...]
    #: (model, key) -> float master params ({"layers": [...], "dense": ...}).
    init_params: Callable
    #: (params, model) -> integer codes (weights (a,b), biases wide).
    quantize_params: Callable
    #: (params, x, model) -> y — float training/eval semantics.
    forward_float: Callable
    #: (params, x, model) -> y — STE fake-quant at every rounding point.
    forward_qat: Callable
    #: (qparams, x_int, model, state) -> (y_int, new_state) — the general
    #: integer datapath (both ALU modes, LUT acts); the xla engine.
    run_int_stateful: Callable
    #: (x_tm, layer_params, model, carry) -> (h_seq, new_carry) — one
    #: layer of the pure-jnp bit-exact oracle (time-major); the ref engine.
    ref_layer: Callable
    #: (model, accel) -> Optional[str] — can the general int datapath run
    #: this configuration (None = yes, else the reason).
    supports_int: Callable
    #: (model, accel) -> Optional[str] — can the ref oracle run it.
    supports_oracle: Callable
    #: (model) -> equivalent ops per inference (the GOP/s convention).
    ops_per_inference: Callable
    #: (model, accel) -> bytes of quantised weights+biases to hold.
    weight_bytes: Callable
    #: (model, accel) -> Optional[str] for the fused Pallas kernel, or
    #: ``None`` (the attribute) when the cell has no fused kernel at all.
    supports_fused: Optional[Callable] = None

    def run_int(self, qparams, x_int: Array, model: QLSTMConfig) -> Array:
        """Stateless integer forward: the stateful datapath started from
        the zero reset carry (how ``forward_int`` relates to
        ``forward_int_stateful`` for every cell)."""
        y, _ = self.run_int_stateful(qparams, x_int, model,
                                     init_state(model, x_int.shape[0]))
        return y


_REGISTRY: Dict[str, CellSpec] = {}


def register(spec: CellSpec) -> CellSpec:
    """Add a cell to the registry (last registration under a name wins)
    and return it, so cell modules can ``SPEC = register(CellSpec(...))``."""
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> CellSpec:
    """The registered cell spec under ``name``; KeyError names the known
    cells when it does not exist."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown cell {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def available() -> Tuple[str, ...]:
    """Names of every registered cell, sorted."""
    return tuple(sorted(_REGISTRY))


def state_shape(model: QLSTMConfig) -> Tuple[int, int, int]:
    """The per-stream carry shape ``(num_layers, state_arity, hidden)``
    for the model's cell — what serving keys its state tables on
    (``plan()['state_shape']``)."""
    spec = get(model.cell)
    return (model.num_layers, spec.state_arity, model.hidden_size)


def init_state(model: QLSTMConfig, batch: int):
    """The reset integer carry for any cell: per layer, ``state_arity``
    zero ``(batch, hidden)`` int32 code arrays — exactly what the
    accelerator's state registers hold before a stream's first window.
    For ``cell='lstm'`` this is bit-for-bit ``core.qlstm.init_int_state``."""
    spec = get(model.cell)
    z = lambda: jnp.zeros((batch, model.hidden_size), jnp.int32)
    return tuple(tuple(z() for _ in range(spec.state_arity))
                 for _ in range(model.num_layers))


# Importing the cell modules registers the zoo.
from repro.cells import gru as _gru      # noqa: E402,F401
from repro.cells import lstm as _lstm    # noqa: E402,F401
from repro.cells import rglru as _rglru  # noqa: E402,F401
