"""``rglru`` cell spec — Griffin's RG-LRU re-derived for the fixed-point
datapath.

The float seed (``models/rglru.py``, ``kernels/rglru_scan.py``) computes
``a = exp(-c * r * softplus(lam))`` and a ``sqrt(1 - a^2)`` input scale —
both hostile to an integer (a,b) pipeline (exp, sqrt, and a free-running
log-space parameter).  This module is the hardware-friendly redefinition
promoted through the :class:`repro.cells.CellSpec` contract:

  * gates are INPUT-ONLY (as in Griffin): ``r = gate(x W_a + b_a)``,
    ``i = gate(x W_i + b_i)`` — one MAC each, no recurrent matmul;
  * the decay is the bilinear ``a = 1 - r * lambda`` with
    ``lambda = gate(lam)`` baked to an (a,b) code at quantisation time —
    ``r -> 0`` gives ``a -> 1`` (remember), ``r -> 1`` gives
    ``a -> 1 - lambda`` (update), monotone like the exp form but a single
    multiply;
  * the input scale is the convex complement ``(1 - a)`` instead of
    ``sqrt(1 - a^2)``: ``h' = a*h + (1-a)*(i * (x W_x + b_x))`` — a
    stable convex mix whose coefficients sum to the exact 1.0 code.

Every product sits at the wide PRODUCT precision and rounds once (the S5
contract of ``core.qlstm``); MACs switch by ALU mode through
``qlstm.int_mac``.  ``kernels/ref.qrglru_seq_ref`` is the independently
written oracle the general datapath must match bit-for-bit.  No fused
Pallas kernel — ``supports_fused`` is ``None`` so ``plan()`` resolves the
xla engine and host state residency.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cells import CellSpec, paper_datapath_reason, register
from repro.core import fixed_point as fxp
from repro.core import qlstm
from repro.core.qlstm import Params, QLSTMConfig, check_int_state
from repro.kernels import ref as _ref

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: QLSTMConfig, key: Array, dtype=jnp.float32) -> Params:
    """Float master params: per layer three input projections ``w_x/w_a/
    w_i (M, H)`` with biases, plus the raw decay parameter ``lam (H,)``
    (gated at quantisation time), plus the shared dense head."""
    layers = []
    for li in range(cfg.num_layers):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        s = 1.0 / jnp.sqrt(max(m, 1))
        layers.append({
            "w_x": jax.random.uniform(k1, (m, h), dtype, -s, s),
            "w_a": jax.random.uniform(k2, (m, h), dtype, -s, s),
            "w_i": jax.random.uniform(k3, (m, h), dtype, -s, s),
            "b_x": jnp.zeros((h,), dtype),
            "b_a": jnp.zeros((h,), dtype),
            "b_i": jnp.zeros((h,), dtype),
            # lam in ~[0.4, 2.6]: gate(lam) spans slow-to-fast decays.
            "lam": jax.random.uniform(k4, (h,), dtype, 0.4, 2.6),
        })
    key, kd = jax.random.split(key)
    s = 1.0 / jnp.sqrt(cfg.hidden_size)
    dense = {
        "w": jax.random.uniform(kd, (cfg.hidden_size, cfg.out_features),
                                dtype, -s, s),
        "b": jnp.zeros((cfg.out_features,), dtype),
    }
    return {"layers": layers, "dense": dense}


def quantize_params(params: Params, cfg: QLSTMConfig) -> Params:
    """Float masters -> integer codes.  Weights in (a,b), biases at the
    wide PRODUCT format, and the decay is BAKED: ``lam_q =
    quantize(gate(lam))`` — the gate nonlinearity on the static parameter
    runs once here, not per step on the accelerator."""
    c = cfg.fxp
    wide = fxp.product_config(c, c)
    gate = qlstm._float_gate_act(cfg.acts, c)

    def q_layer(p):
        return {
            "w_x": fxp.quantize(p["w_x"], c),
            "w_a": fxp.quantize(p["w_a"], c),
            "w_i": fxp.quantize(p["w_i"], c),
            "b_x": fxp.quantize(p["b_x"], wide),
            "b_a": fxp.quantize(p["b_a"], wide),
            "b_i": fxp.quantize(p["b_i"], wide),
            "lam_q": fxp.quantize(gate(p["lam"]), c),
        }

    return {
        "layers": [q_layer(p) for p in params["layers"]],
        "dense": {"w": fxp.quantize(params["dense"]["w"], c),
                  "b": fxp.quantize(params["dense"]["b"], wide)},
    }


# ---------------------------------------------------------------------------
# Float / QAT forward
# ---------------------------------------------------------------------------

def _step_float(p, x_t, h, cfg: QLSTMConfig, fq: bool):
    fp = cfg.fxp
    q = (lambda t: fxp.fake_quant(t, fp)) if fq else (lambda t: t)
    gate = qlstm._float_gate_act(cfg.acts, fp, fq=fq)
    lam = gate(p["lam"])
    if fq:
        lam = q(lam)
    xp = q(x_t @ q(p["w_x"]) + p["b_x"])
    r = gate(x_t @ q(p["w_a"]) + p["b_a"])
    i = gate(x_t @ q(p["w_i"]) + p["b_i"])
    if fq:
        r, i = q(r), q(i)
    a = 1.0 - q(r * lam)
    gx = q(i * xp)
    return q(a * h + (1.0 - a) * gx)


def _forward(params: Params, x: Array, cfg: QLSTMConfig, fq: bool) -> Array:
    b = x.shape[0]
    h_t = x
    h_last = None
    for p in params["layers"]:
        h0 = jnp.zeros((b, cfg.hidden_size), x.dtype)

        def step(h, x_t, p=p):
            h = _step_float(p, x_t, h, cfg, fq)
            return h, h

        h_last, hs = jax.lax.scan(step, h0, jnp.swapaxes(h_t, 0, 1))
        h_t = jnp.swapaxes(hs, 0, 1)
    q = (lambda t: fxp.fake_quant(t, cfg.fxp)) if fq else (lambda t: t)
    return q(h_last @ q(params["dense"]["w"]) + params["dense"]["b"])


def forward_float(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    """Float RG-LRU stack + dense head: (B, T, M) -> (B, P)."""
    return _forward(params, x, cfg, fq=False)


def forward_qat(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    """QAT graph: the float forward with STE fake-quant at every hardware
    rounding point (including the baked ``gate(lam)`` code)."""
    return _forward(params, x, cfg, fq=True)


# ---------------------------------------------------------------------------
# Integer forward — the general (xla-engine) datapath
# ---------------------------------------------------------------------------

def _step_int(p, x_t, h, cfg: QLSTMConfig):
    fp = cfg.fxp
    prod = fxp.product_config(fp, fp)
    one = 1 << fp.frac_bits            # the exact (a,b) code of 1.0
    xp = qlstm.int_mac(x_t, p["w_x"], p["b_x"], cfg)
    r = qlstm.int_gate_act(qlstm.int_mac(x_t, p["w_a"], p["b_a"], cfg), cfg)
    i = qlstm.int_gate_act(qlstm.int_mac(x_t, p["w_i"], p["b_i"], cfg), cfg)
    a = one - qlstm.elem_mul_round(r, p["lam_q"].astype(jnp.int32), cfg)
    gx = qlstm.elem_mul_round(i, xp, cfg)
    # Convex mix a*h + (1-a)*gx: both products wide, add, round ONCE (S5).
    wide = a.astype(jnp.int32) * h.astype(jnp.int32) \
        + (one - a.astype(jnp.int32)) * gx.astype(jnp.int32)
    return fxp.requantize(wide, prod, fp)


def run_int_stateful(qparams: Params, x_int: Array, cfg: QLSTMConfig,
                     state) -> Tuple[Array, tuple]:
    """Bit-exact integer RG-LRU stack with an explicit cross-window carry
    (per layer ``(h,)``) — windowed feeding is bit-identical to one call
    on the concatenated sequence."""
    check_int_state(state, qparams)
    h_t = x_int.astype(jnp.int32)
    new_state = []
    h_last = None
    for p, (h0,) in zip(qparams["layers"], state):

        def step(h, x_t, p=p):
            h = _step_int(p, x_t, h, cfg)
            return h, h

        h_last, hs = jax.lax.scan(step, h0.astype(jnp.int32),
                                  jnp.swapaxes(h_t, 0, 1))
        new_state.append((h_last,))
        h_t = jnp.swapaxes(hs, 0, 1)
    y = qlstm.int_mac(h_last, qparams["dense"]["w"], qparams["dense"]["b"],
                      cfg)
    return y, tuple(new_state)


def ref_layer(x_tm: Array, p, model: QLSTMConfig, carry):
    """One oracle RG-LRU layer, time-major — ``kernels/ref.qrglru_seq_ref``
    resumed from ``carry = (h0,)``."""
    acts = model.acts
    (h0,) = carry
    hs, h_last = _ref.qrglru_seq_ref(
        x_tm, p["w_x"], p["w_a"], p["w_i"],
        p["b_x"], p["b_a"], p["b_i"], p["lam_q"], model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound, h0=h0)
    return hs, (h_last,)


def supports_int(model: QLSTMConfig, accel) -> Optional[str]:
    """None when the general int datapath covers the configuration.  The
    RG-LRU uses no cell activation — only the gate nonlinearity must have
    an integer form."""
    if model.acts.gate not in ("hard_sigmoid_star", "lut_sigmoid", "sigmoid"):
        return f"gate activation {model.acts.gate!r} has no integer datapath"
    return None


def ops_per_inference(cfg: QLSTMConfig) -> int:
    """Equivalent ops per inference (MAC = 2 ops) for the RG-LRU stack +
    dense head — the GOP/s accounting convention of ``core.qlstm``."""
    total = 0
    for li in range(cfg.num_layers):
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        per_step = 2 * 3 * h * m        # three input-projection MACs
        per_step += 3 * h               # + bias adds
        per_step += 4 * h + 2 * h      # r*lam, i*xp, a*h, (1-a)*gx + mixes
        per_step += 2 * h               # gate activations
        total += cfg.seq_len * per_step
    total += 2 * cfg.hidden_size * cfg.out_features + cfg.out_features
    return total


def weight_bytes(model: QLSTMConfig, acc) -> int:
    """Bytes of quantised RG-LRU weights+biases (including the baked
    ``lam_q`` codes) the accelerator must hold."""
    itemsize = (acc.fxp.total_bits + 7) // 8
    wide_itemsize = 2 * itemsize
    total = 0
    for li in range(model.num_layers):
        m, h = model.layer_in_dim(li), model.hidden_size
        total += 3 * m * h * itemsize + 3 * h * wide_itemsize
        total += h * itemsize           # lam_q
    total += model.hidden_size * model.out_features * itemsize
    total += model.out_features * wide_itemsize
    return total


SPEC = register(CellSpec(
    name="rglru",
    state_arity=1,
    state_names=("h",),
    init_params=init_params,
    quantize_params=quantize_params,
    forward_float=forward_float,
    forward_qat=forward_qat,
    run_int_stateful=run_int_stateful,
    ref_layer=ref_layer,
    supports_int=supports_int,
    supports_oracle=paper_datapath_reason,
    supports_fused=None,    # no fused Pallas kernel (yet): auto -> xla
    ops_per_inference=ops_per_inference,
    weight_bytes=weight_bytes,
))
