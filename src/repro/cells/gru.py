"""``gru`` cell spec — quantised GRU through the paper's datapath.

Same fixed-point contract as the LSTM (``core.qlstm``): weights in (a,b),
biases at the wide PRODUCT precision (2a frac bits), MACs by ALU mode
(pipelined = accumulate wide + ONE late S5 rounding; per_step = Algorithm
1's per-product rounding with saturating adds), gates through the integer
HardSigmoid*/LUT activations, elementwise state updates at wide precision
with a single rounding.

Gate order is [r, z, n] over a fused ``(in, 3H)`` weight layout (the
LSTM's ``[i, f, g, o]`` convention, one gate shorter):

    r = gate(x W_xr + h W_hr + b_r)            (reset)
    z = gate(x W_xz + h W_hz + b_z)            (update)
    n = cellact( (x W_xn + b_n)*1 + r * (h W_hn) )   (candidate, v3 form:
                                                r gates the RECURRENT half
                                                before the activation)
    h' = (1 - z) * n + z * h

The candidate combine and the state mix are both S5-style: every product
at the wide precision, add, round once.  The recurrent half ``h W_hn`` is
rounded at its own accumulator exit (a second MAC port in hardware), then
the ``r``-gating product restores the wide format — so ``x W_xn + b_n``
is lifted to wide by the exact ``1.0`` code and the sum rounds once.

``kernels/ref.qgru_seq_ref`` is the independently written oracle this
module's general datapath must match bit-for-bit
(``tests/test_cells.py``).  No fused Pallas kernel yet — the spec's
``supports_fused`` is ``None``, so ``plan()`` resolves the xla engine and
serving keeps host (or adapter-driven device) state residency.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cells import CellSpec, paper_datapath_reason, register
from repro.core import fixed_point as fxp
from repro.core import qlstm
from repro.core.qlstm import Params, QLSTMConfig, check_int_state
from repro.kernels import ref as _ref

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: QLSTMConfig, key: Array, dtype=jnp.float32) -> Params:
    """Float master params: per layer ``w_x (M, 3H)``, ``w_h (H, 3H)``,
    ``b (3H,)`` in gate order [r, z, n], plus the shared dense head."""
    layers = []
    for li in range(cfg.num_layers):
        key, k1, k2 = jax.random.split(key, 3)
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        s = 1.0 / jnp.sqrt(h)
        layers.append({
            "w_x": jax.random.uniform(k1, (m, 3 * h), dtype, -s, s),
            "w_h": jax.random.uniform(k2, (h, 3 * h), dtype, -s, s),
            "b": jnp.zeros((3 * h,), dtype),
        })
    key, kd = jax.random.split(key)
    s = 1.0 / jnp.sqrt(cfg.hidden_size)
    dense = {
        "w": jax.random.uniform(kd, (cfg.hidden_size, cfg.out_features),
                                dtype, -s, s),
        "b": jnp.zeros((cfg.out_features,), dtype),
    }
    return {"layers": layers, "dense": dense}


def quantize_params(params: Params, cfg: QLSTMConfig) -> Params:
    """Float masters -> integer codes: weights in (a,b), biases at the
    wide PRODUCT format — the LSTM quantisation rule, 3 gates wide."""
    c = cfg.fxp
    wide = fxp.product_config(c, c)
    q_layer = lambda p: {"w_x": fxp.quantize(p["w_x"], c),
                         "w_h": fxp.quantize(p["w_h"], c),
                         "b": fxp.quantize(p["b"], wide)}
    return {
        "layers": [q_layer(p) for p in params["layers"]],
        "dense": {"w": fxp.quantize(params["dense"]["w"], c),
                  "b": fxp.quantize(params["dense"]["b"], wide)},
    }


# ---------------------------------------------------------------------------
# Float / QAT forward
# ---------------------------------------------------------------------------

def _step_float(p, x_t, h, cfg: QLSTMConfig, fq: bool):
    fp = cfg.fxp
    q = (lambda t: fxp.fake_quant(t, fp)) if fq else (lambda t: t)
    gate = qlstm._float_gate_act(cfg.acts, fp, fq=fq)
    cellact = qlstm._float_cell_act(cfg.acts)
    hdim = cfg.hidden_size
    w_x, w_h = q(p["w_x"]), q(p["w_h"])
    rz = q(x_t @ w_x[:, :2 * hdim] + h @ w_h[:, :2 * hdim]
           + p["b"][:2 * hdim])
    r, z = gate(rz[:, :hdim]), gate(rz[:, hdim:])
    if fq:
        r, z = q(r), q(z)
    nh = q(h @ w_h[:, 2 * hdim:])
    n = cellact(q(x_t @ w_x[:, 2 * hdim:] + p["b"][2 * hdim:] + r * nh))
    if fq:
        n = q(n)
    return q((1.0 - z) * n + z * h)


def _forward(params: Params, x: Array, cfg: QLSTMConfig, fq: bool) -> Array:
    b = x.shape[0]
    h_t = x
    h_last = None
    for p in params["layers"]:
        h0 = jnp.zeros((b, cfg.hidden_size), x.dtype)

        def step(h, x_t, p=p):
            h = _step_float(p, x_t, h, cfg, fq)
            return h, h

        h_last, hs = jax.lax.scan(step, h0, jnp.swapaxes(h_t, 0, 1))
        h_t = jnp.swapaxes(hs, 0, 1)
    q = (lambda t: fxp.fake_quant(t, cfg.fxp)) if fq else (lambda t: t)
    return q(h_last @ q(params["dense"]["w"]) + params["dense"]["b"])


def forward_float(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    """Float GRU stack + dense head: (B, T, M) -> (B, P)."""
    return _forward(params, x, cfg, fq=False)


def forward_qat(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    """QAT graph: the float forward with STE fake-quant at every hardware
    rounding point."""
    return _forward(params, x, cfg, fq=True)


# ---------------------------------------------------------------------------
# Integer forward — the general (xla-engine) datapath
# ---------------------------------------------------------------------------

def _step_int(p, x_t, h, cfg: QLSTMConfig):
    fp = cfg.fxp
    prod = fxp.product_config(fp, fp)
    hdim = cfg.hidden_size
    one = 1 << fp.frac_bits            # the exact (a,b) code of 1.0
    w_x, w_h = p["w_x"], p["w_h"]
    rz = qlstm.int_mac(jnp.concatenate([x_t, h], axis=-1),
                       jnp.concatenate([w_x[:, :2 * hdim],
                                        w_h[:, :2 * hdim]], axis=-2),
                       p["b"][:2 * hdim], cfg)
    r = qlstm.int_gate_act(rz[:, :hdim], cfg)
    z = qlstm.int_gate_act(rz[:, hdim:], cfg)
    # Candidate: both halves MAC'd by ALU mode to (a,b); the combine is
    # S5 — lift nx by the 1.0 code, gate nh by r (both wide), round once.
    nh = qlstm.int_mac(h, w_h[:, 2 * hdim:],
                       jnp.zeros((hdim,), jnp.int32), cfg)
    nx = qlstm.int_mac(x_t, w_x[:, 2 * hdim:], p["b"][2 * hdim:], cfg)
    n_pre = fxp.requantize(nx.astype(jnp.int32) * one
                           + r.astype(jnp.int32) * nh.astype(jnp.int32),
                           prod, fp)
    n = qlstm.int_cell_act(n_pre, cfg)
    # h' = (1-z)*n + z*h : both products wide, add, round ONCE (S5).
    wide = (one - z.astype(jnp.int32)) * n.astype(jnp.int32) \
        + z.astype(jnp.int32) * h.astype(jnp.int32)
    return fxp.requantize(wide, prod, fp)


def run_int_stateful(qparams: Params, x_int: Array, cfg: QLSTMConfig,
                     state) -> Tuple[Array, tuple]:
    """Bit-exact integer GRU stack with an explicit cross-window carry
    (per layer ``(h,)``).  Window-by-window feeding is bit-identical to
    one call on the concatenated sequence — the serving contract."""
    check_int_state(state, qparams)
    h_t = x_int.astype(jnp.int32)
    new_state = []
    h_last = None
    for p, (h0,) in zip(qparams["layers"], state):

        def step(h, x_t, p=p):
            h = _step_int(p, x_t, h, cfg)
            return h, h

        h_last, hs = jax.lax.scan(step, h0.astype(jnp.int32),
                                  jnp.swapaxes(h_t, 0, 1))
        new_state.append((h_last,))
        h_t = jnp.swapaxes(hs, 0, 1)
    y = qlstm.int_mac(h_last, qparams["dense"]["w"], qparams["dense"]["b"],
                      cfg)
    return y, tuple(new_state)


def ref_layer(x_tm: Array, p, model: QLSTMConfig, carry):
    """One oracle GRU layer, time-major — ``kernels/ref.qgru_seq_ref``
    resumed from ``carry = (h0,)``."""
    acts = model.acts
    (h0,) = carry
    hs, h_last = _ref.qgru_seq_ref(
        x_tm, p["w_x"], p["w_h"], p["b"], model.fxp,
        hs_slope_shift=acts.hs_slope_shift, hs_bound=acts.hs_bound,
        ht_min=acts.ht_min, ht_max=acts.ht_max, h0=h0)
    return hs, (h_last,)


def supports_int(model: QLSTMConfig, accel) -> Optional[str]:
    """None when the general int datapath covers the configuration (both
    ALU modes, hard or LUT activations), else the reason."""
    if model.acts.gate not in ("hard_sigmoid_star", "lut_sigmoid", "sigmoid"):
        return f"gate activation {model.acts.gate!r} has no integer datapath"
    if model.acts.cell not in ("hard_tanh", "lut_tanh", "tanh"):
        return f"cell activation {model.acts.cell!r} has no integer datapath"
    return None


def ops_per_inference(cfg: QLSTMConfig) -> int:
    """Equivalent ops per inference (MAC = 2 ops) for the GRU stack +
    dense head — the GOP/s accounting convention of ``core.qlstm``."""
    total = 0
    for li in range(cfg.num_layers):
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        per_step = 2 * 3 * h * (m + h)   # gate/candidate MACs
        per_step += 3 * h                # + bias adds
        per_step += 2 * 3 * h + h       # r*nh, (1-z)*n, z*h muls + combine
        per_step += 3 * h                # activations (1 op each)
        total += cfg.seq_len * per_step
    total += 2 * cfg.hidden_size * cfg.out_features + cfg.out_features
    return total


def weight_bytes(model: QLSTMConfig, acc) -> int:
    """Bytes of quantised GRU weights+biases the accelerator must hold."""
    itemsize = (acc.fxp.total_bits + 7) // 8
    wide_itemsize = 2 * itemsize
    total = 0
    for li in range(model.num_layers):
        m, h = model.layer_in_dim(li), model.hidden_size
        total += (m + h) * 3 * h * itemsize + 3 * h * wide_itemsize
    total += model.hidden_size * model.out_features * itemsize
    total += model.out_features * wide_itemsize
    return total


SPEC = register(CellSpec(
    name="gru",
    state_arity=1,
    state_names=("h",),
    init_params=init_params,
    quantize_params=quantize_params,
    forward_float=forward_float,
    forward_qat=forward_qat,
    run_int_stateful=run_int_stateful,
    ref_layer=ref_layer,
    supports_int=supports_int,
    supports_oracle=paper_datapath_reason,
    supports_fused=None,    # no fused Pallas kernel (yet): auto -> xla
    ops_per_inference=ops_per_inference,
    weight_bytes=weight_bytes,
))
