"""The unified accelerator session API — ``repro.build``.

One configuration, compiled once, deployed everywhere (the paper's thesis:
a single *parameterised* design covers many deployment situations):

    import repro
    from repro.core.qlstm import QLSTMConfig
    from repro.core.accelerator import AcceleratorConfig

    acc = repro.build(QLSTMConfig(), AcceleratorConfig())
    acc.train_qat(data, steps=400)          # QAT (§6.1)
    acc.quantize()                          # float master -> integer codes
    y = acc.infer(x, path="int")            # bit-exact accelerator datapath
    for pred in acc.serve(stream, batch=256):
        ...                                 # batched real-time serving (§6)
    acc.report()                            # Table-2 plan + Table-4 energy

The session owns the float master params, the quantised params, and the
resolved ``plan()``; ``infer``/``serve`` dispatch through the backend
registry (`repro/backends/`: ``ref`` oracle | fused ``pallas`` kernel |
``xla`` scan) selected by the plan, with explicit override.  Jitted
entry points are cached per (path, backend) so repeated calls — the
serving hot path — never retrace.

``serve`` is the stateless compat wrapper; the production streaming layer
(named client streams, cross-window (h, c) carry, deadline-bounded waves,
serving metrics) is ``repro.serving.StreamServer``, built on
``compiled_stateful``/``init_state`` below (docs/SERVING.md).

See docs/API.md for the full lifecycle and the Table-2 parameter mapping.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Iterator, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import backends, cells
from repro.core import fixed_point as fxp
from repro.core.accelerator import (AcceleratorConfig, plan as resolve_plan,
                                    resolve_model, sync_accelerator)
from repro.core.energy import power_report
from repro.core.qlstm import QLSTMConfig

Array = jax.Array
Params = Dict[str, Any]

PATHS = ("float", "qat", "int")

# The paper's measured operating point (§6: 28.07 us/inference on the
# XC7S15) — the default latency anchor for report().
PAPER_LATENCY_S = 28.07e-6


def build(model: Optional[QLSTMConfig] = None,
          accel: Optional[AcceleratorConfig] = None, *,
          params: Optional[Params] = None, seed: int = 0) -> "Accelerator":
    """Compile a (model, accelerator) configuration into a session.

    This is the single entry point of the pipeline: Table-2 meta-parameters
    in, a deployable object out.  ``params`` seeds the session with
    existing float master weights; otherwise they are initialised from
    ``seed``."""
    return Accelerator(model or QLSTMConfig(), accel or AcceleratorConfig(),
                       params=params, seed=seed)


def build_cluster(session, n: int, *, devices=None, names=None, config=None,
                  **overrides):
    """A ready multi-replica serving cluster from one quantised session:
    ``session.replicate(n)`` (per-device pinned copies) behind a
    ``repro.serving.ClusterServer`` consistent-hash front door.

    ``devices`` pins explicit placement (``launch.mesh.serving_devices``
    semantics); ``names`` labels the replicas on the ring; ``config`` /
    keyword overrides set ``ClusterConfig`` and fall through to the
    per-replica ``ServingConfig`` (``batch=``, ``deadline_s=``, ...).
    docs/SERVING.md §Scaling out is the deployment guide."""
    # Lazy: the serving package (threaded scheduler) only loads when a
    # cluster is actually built, same posture as the other serving entry
    # points.
    from repro.serving.cluster import ClusterServer

    replicas = session.replicate(n, devices=devices)
    return ClusterServer(replicas, config=config, names=names, **overrides)


class Accelerator:
    """A built accelerator: params + resolved plan + dispatchable datapaths.

    Lifecycle: ``build`` -> ``train_qat`` -> ``quantize`` -> ``infer`` /
    ``serve`` / ``report``.  Stage methods return ``self`` for chaining."""

    def __init__(self, model: QLSTMConfig, accel: AcceleratorConfig, *,
                 params: Optional[Params] = None, seed: int = 0):
        # Canonicalise both directions once: AcceleratorConfig is the source
        # of truth; legacy model-side knobs are honoured with a warning.
        self.model = resolve_model(model, accel)
        self.accel = sync_accelerator(self.model, accel)
        # The cell spec owns every datapath and the param/state trees;
        # KeyError here (unknown cell id) fails the build immediately.
        self.cell = cells.get(self.model.cell)
        self.plan = resolve_plan(self.model, self.accel)
        if self.accel.backend != "auto":
            # Fail at build, not first infer: an explicit engine that cannot
            # run this configuration would otherwise be reported by plan()/
            # report() as if it could.
            backends.select(self.model, self.accel)
        self.params: Params = (params if params is not None
                               else self.cell.init_params(
                                   self.model, jax.random.key(seed)))
        self.qparams: Optional[Params] = None
        self.train_summary: Optional[Dict[str, Any]] = None
        self._jitted: Dict[Tuple[str, str], Any] = {}
        # Set by replicate(): the jax.Device this session's params are
        # committed to (None = uncommitted, jax's default placement).
        self.device = None

    # -- training -----------------------------------------------------------

    def train_qat(self, data, steps: int = 200, *, batch: int = 64,
                  lr: float = 3e-3, seed: int = 0,
                  ckpt_dir: Optional[str] = None, log_every: int = 50,
                  log=print) -> "Accelerator":
        """Quantisation-aware training (§6.1): MSE regression with STE
        fake-quant at every hardware rounding point.

        ``data``: either the dict from ``data.timeseries.pems_like_dataset``
        (its ``"train"`` split is used) or an ``(x, y)`` tuple with
        x (N, T, M) float and y (N, P).  Fault tolerance comes from the
        shared ``Trainer`` (checkpoint/resume in ``ckpt_dir``,
        SIGTERM/SIGINT checkpoint-and-exit)."""
        from repro.training.optimizer import (OptConfig, apply_updates,
                                              init_opt_state)
        from repro.training.train_loop import LoopConfig, Trainer

        xtr, ytr = data["train"] if isinstance(data, dict) else data
        cfg = self.model
        opt_cfg = OptConfig(name="adamw", lr=lr, weight_decay=0.0,
                            warmup_steps=min(20, max(1, steps // 10)),
                            total_steps=steps)
        state = {"params": self.params,
                 "opt": init_opt_state(self.params, opt_cfg),
                 "step": jnp.zeros((), jnp.int32)}

        forward_qat = self.cell.forward_qat

        @jax.jit
        def step_fn(state, batch_d):
            def loss(p):
                y = forward_qat(p, batch_d["x"], cfg)
                mse = jnp.mean(jnp.square(y - batch_d["y"]))
                return mse, {"mse": mse}

            (l, m), g = jax.value_and_grad(loss, has_aux=True)(state["params"])
            p, o, om = apply_updates(state["params"], g, state["opt"], opt_cfg)
            return ({"params": p, "opt": o, "step": state["step"] + 1},
                    {"loss": l, **m, **om})

        def batch_fn(step):
            rng = np.random.default_rng((seed, step))
            idx = rng.integers(0, len(xtr), batch)
            return {"x": jnp.asarray(xtr[idx]), "y": jnp.asarray(ytr[idx])}

        trainer = Trainer(step_fn, state, batch_fn,
                          LoopConfig(total_steps=steps, ckpt_dir=ckpt_dir,
                                     ckpt_every=100, log_every=log_every),
                          log=log)
        trainer.maybe_resume()
        self.train_summary = trainer.run()
        self.params = trainer.state["params"]
        # Params changed: stale quantisation and jit closures must go.
        self.qparams = None
        self._jitted.clear()
        return self

    # -- quantisation -------------------------------------------------------

    def quantize(self) -> "Accelerator":
        """Float master weights -> integer codes for the hardware datapath
        (weights in (a,b); biases at the wide accumulator precision)."""
        self.qparams = self.cell.quantize_params(self.params, self.model)
        # Cached int-path closures (stateless AND stateful) captured the
        # previous codes; drop them.
        self._jitted = {k: fn for k, fn in self._jitted.items()
                        if not k[0].startswith("int")}
        return self

    # -- inference ----------------------------------------------------------

    def infer(self, x: Union[Array, np.ndarray], path: str = "float",
              backend: Optional[str] = None) -> Array:
        """x: (B, T, M) float -> (B, P) float.

        ``path``: ``float`` (training semantics), ``qat`` (fake-quant
        graph), ``int`` (bit-exact integer datapath — dequantised at the
        boundary).  ``backend`` overrides the plan's engine for the int
        path (``ref`` | ``pallas`` | ``xla``)."""
        return self._fn(path, backend)(jnp.asarray(x))

    def infer_int(self, x_int: Union[Array, np.ndarray],
                  backend: Optional[str] = None) -> Array:
        """Integer codes in, integer codes out — the raw accelerator
        boundary, for bit-exactness checks and benchmarks."""
        self._require_quantized()
        bk = backends.select(self.model, self.accel, override=backend)
        return bk.run(self.qparams, jnp.asarray(x_int), self.model, self.accel)

    def compiled(self, path: str = "int", backend: Optional[str] = None):
        """The cached jitted entry point for (path, backend): a callable
        ``(B, T, M) float -> (B, P) float``.  Useful for benchmarking the
        datapath without per-call dispatch overhead."""
        return self._fn(path, backend)

    def init_state(self, batch: int):
        """The reset cross-window carry for ``compiled_stateful``: per
        layer, the cell spec's ``state_arity`` zero int32 code arrays of
        shape (batch, hidden) — what the accelerator's state registers
        hold before a stream's first window (for ``cell='lstm'`` this is
        the classic per-layer (h, c) pair)."""
        return cells.init_state(self.model, batch)

    def compiled_stateful(self, backend: Optional[str] = None):
        """The cached jitted STATEFUL int-path entry point: a callable
        ``((B, T, M) float, state) -> ((B, P) float, new_state)`` where
        ``state`` is the per-layer (h, c) carry (``init_state`` for a fresh
        stream).  This is the datapath behind ``repro.serving`` — feeding a
        stream window-by-window with the carried state is bit-identical to
        one call on the concatenated sequence.  Every engine is
        stateful-capable (``ref`` | ``pallas`` | ``xla``): the fused
        pallas kernel seeds its (h, c) VMEM scratch from the carry, so
        ``auto`` (the plan's ``stateful_backend``) resolves exactly like
        the stateless path — docs/API.md §Backends has the selection
        order."""
        self._require_quantized()
        bk = backends.select_stateful(self.model, self.accel,
                                      override=backend)
        key = ("int_stateful", bk.name)
        if key in self._jitted:
            return self._jitted[key]
        qparams, model, accel = self.qparams, self.model, self.accel

        def stateful_path(x, state):
            x_int = fxp.quantize(x, model.fxp)
            y_int, new_state = bk.run_stateful(qparams, x_int, model, accel,
                                               state)
            return fxp.dequantize(y_int, model.fxp), new_state

        fn = jax.jit(stateful_path)
        self._jitted[key] = fn
        return fn

    def init_state_table(self, max_slots: int) -> Array:
        """The reset DEVICE-RESIDENT state table for
        ``compiled_stateful_slots``: a zero ``(max_slots + 2, L, S, H)``
        int32 array where ``(L, S, H)`` is the cell's
        ``plan()['state_shape']`` (axis 2 is the carry arity — (h, c) for
        the LSTM, a single h row for GRU/rGLRU), committed to this
        session's device when the session is pinned (``replicate``).
        Rows ``max_slots`` and ``max_slots + 1`` are the conventions of
        the slot kernel: the always-zero RESET row fresh/evicted streams
        gather from, and the write-only TRASH row retired/padding rows
        scatter to (``kernels/qlstm_cell.qlstm_seq_slot_pallas``)."""
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        tbl = jnp.zeros((max_slots + 2, *self.plan["state_shape"]), jnp.int32)
        return jax.device_put(tbl, self.device) if self.device is not None \
            else tbl

    def compiled_stateful_slots(self, backend: Optional[str] = None):
        """The cached jitted DEVICE-RESIDENT-state entry point: a callable
        ``((B, T, M) float, table, gather_slots, scatter_slots) ->
        ((B, P) float, new_table)`` where ``table`` is the persistent
        per-stream carry table (``init_state_table``) and the slot vectors
        are (B,) int32 table-row ids.  Per wave the host ships only the
        float window batch and the two slot vectors — no (h, c) arrays
        cross the host/device boundary, which is what
        ``plan()['state_residency'] == 'device'`` buys the serving tier.
        The fused pallas engine gathers/scatters inside the kernel;
        ``ref``/``xla`` run the XLA-level adapter, so every rung of the
        degradation ladder accepts the same arguments.  Bit-identical to
        ``compiled_stateful`` fed the host-gathered carries."""
        self._require_quantized()
        bk = backends.select_stateful(self.model, self.accel,
                                      override=backend)
        key = ("int_stateful_slots", bk.name)
        if key in self._jitted:
            return self._jitted[key]
        impl = bk.run_stateful_slots
        if impl is None:
            from repro.backends.common import run_slots_via_state
            impl = lambda *a: run_slots_via_state(bk.run_stateful, *a)
        qparams, model, accel = self.qparams, self.model, self.accel

        def slot_path(x, table, gather_slots, scatter_slots):
            x_int = fxp.quantize(x, model.fxp)
            y_int, new_table = impl(qparams, x_int, model, accel, table,
                                    gather_slots, scatter_slots)
            return fxp.dequantize(y_int, model.fxp), new_table

        fn = jax.jit(slot_path)
        self._jitted[key] = fn
        return fn

    def degradation_ladder(self, backend: Optional[str] = None,
                           stateful: bool = True) -> Tuple[str, ...]:
        """Ordered engine names the serving tier falls back through on
        repeated backend failure (fastest first; all bit-identical on the
        int path, so degrading changes latency, never results).  ``backend``
        pins the preferred head of the ladder; ``stateful`` restricts it to
        engines able to carry (h, c) across windows — see
        ``backends.degradation_ladder`` and docs/SERVING.md §Reliability."""
        return backends.degradation_ladder(self.model, self.accel,
                                           override=backend,
                                           stateful=stateful)

    def replicate(self, n: int, devices=None) -> "list[Accelerator]":
        """``n`` device-pinned replica sessions of this (quantised)
        accelerator — the per-replica substrate of the serving cluster
        (docs/SERVING.md §Scaling out).

        Each replica shares this session's configuration and weights, with
        its params AND integer codes committed to its own device
        (``sharding.partition.pin_to_device``), so jit executes each
        replica's datapath on that device and a stream's (h, c) carry
        stays replica-local under ``ClusterServer`` routing.  Devices come
        from ``launch.mesh.serving_devices``: round-robin over
        ``jax.devices()`` by default (oversubscribing when there are fewer
        than ``n`` — the CPU-test posture), or an explicit ``devices``
        list for controlled placement.  The codes are pinned, NOT
        re-quantised, so every replica is bit-identical to this session."""
        from repro.launch.mesh import serving_devices
        from repro.sharding.partition import pin_to_device

        self._require_quantized()
        out = []
        for d in serving_devices(n, devices):
            rep = Accelerator(self.model, self.accel,
                              params=pin_to_device(self.params, d))
            rep.qparams = pin_to_device(self.qparams, d)
            rep.device = d
            out.append(rep)
        return out

    def _require_quantized(self):
        if self.qparams is None:
            raise RuntimeError(
                "the session is not quantised: call .quantize() before the "
                "int path (build -> train_qat -> quantize -> infer/serve)")

    def _fn(self, path: str, backend: Optional[str]):
        """Cached jitted entry point for (path, backend)."""
        if path not in PATHS:
            raise ValueError(f"path must be one of {PATHS}, got {path!r}")
        if backend is not None and path != "int":
            raise ValueError(
                f"backend={backend!r} only applies to path='int'; the "
                f"{path!r} path runs the float graph")
        model = self.model
        if path == "int":
            self._require_quantized()
            # Key on the RESOLVED engine: plan-auto and an explicit request
            # for the same engine share one compiled closure.
            bk = backends.select(model, self.accel, override=backend)
            key = (path, bk.name)
        else:
            key = (path, "plan")
        if key in self._jitted:
            return self._jitted[key]

        if path == "float":
            params, fwd = self.params, self.cell.forward_float
            fn = jax.jit(lambda x: fwd(params, x, model))
        elif path == "qat":
            params, fwd = self.params, self.cell.forward_qat
            fn = jax.jit(lambda x: fwd(params, x, model))
        else:
            qparams, accel = self.qparams, self.accel

            def int_path(x):
                x_int = fxp.quantize(x, model.fxp)
                y_int = bk.run(qparams, x_int, model, accel)
                return fxp.dequantize(y_int, model.fxp)

            fn = jax.jit(int_path)
        self._jitted[key] = fn
        return fn

    # -- serving ------------------------------------------------------------

    def serve(self, stream: Iterable[Union[Array, np.ndarray]],
              batch: int = 256, path: str = "int",
              backend: Optional[str] = None) -> Iterator[np.ndarray]:
        """Batched streaming inference — the paper's deployment scenario
        (§6: real-time samples/s).  Thin compat wrapper over
        ``repro.serving.serve_windows`` (stateless; for cross-window state
        carry and multi-client multiplexing use
        ``repro.serving.StreamServer``).

        ``stream`` yields windows of shape (T, M); predictions of shape
        (P,) are yielded in submission order.  Windows are assembled into
        fixed-size waves of ``batch`` so the jitted datapath sees one
        static shape.  **Final-partial-wave padding semantics**: when the
        stream ends mid-wave, the wave is padded to ``batch`` by repeating
        the last real window; the padded rows are computed and DROPPED —
        exactly one prediction per input window is yielded, never the
        padding's (pinned by ``tests/test_serving.py``)."""
        # Validate NOW, not at first iteration: serve() itself is a plain
        # function so a bad path/backend or an unquantised session fails at
        # the call site, not deep inside whatever consumes the generator.
        from repro.serving import serve_windows
        return serve_windows(self, stream, batch=batch, path=path,
                             backend=backend)

    def measure_scenario(self, scenario, *, batch: Optional[int] = None,
                         replicas: int = 1,
                         state_residency: str = "auto") -> Dict[str, Any]:
        """Measure THIS session at a serving operating point.

        ``scenario`` is a ``repro.explore.ServingScenario``; a short real
        ``StreamServer`` (or ``ClusterServer`` when ``replicas > 1``) run
        is stood up and the ``metrics_summary()``-derived objectives
        returned (samples/s, p50/p95/p99 ms, deadline-miss rate,
        GOP/s/W).  This is the re-measurement hook for an autotuned
        operating point: after ``explore.autotune(..., scenario=...)``,
        ``session.measure_scenario(scenario)`` verifies the deployed
        session still meets the SLO it was selected under."""
        return scenario.run(self, batch=batch, replicas=replicas,
                            state_residency=state_residency)

    # -- reporting ----------------------------------------------------------

    def report(self, latency_s: float = PAPER_LATENCY_S,
               batch: int = 1) -> Dict[str, Any]:
        """Resolved plan + op/footprint accounting + the Table-4-style
        energy report at the given operating point."""
        ops = self.cell.ops_per_inference(self.model)
        energy = power_report(
            flops=ops * batch, hbm_bytes=self.plan["weight_bytes"],
            ici_bytes=0, latency_s=latency_s,
            unit=self.plan["compute_unit"],
            dtype="int8" if self.accel.fxp.total_bits <= 8 else "bf16")
        return {
            "model": dataclasses.asdict(self.model),
            # JSON-friendly: the plan's FixedPointConfig becomes a dict too.
            "plan": {**self.plan,
                     "fxp": dataclasses.asdict(self.plan["fxp"])},
            "backend": self.plan["backend"],
            "backends_supported": backends.supported_backends(self.model,
                                                              self.accel),
            # Engines able to carry (h, c) across windows — the
            # repro.serving capability surface for this configuration.
            "stateful_backends": backends.stateful_backends(self.model,
                                                            self.accel),
            "ops_per_inference": ops,
            "weight_bytes": self.plan["weight_bytes"],
            "quantized": self.qparams is not None,
            "energy": energy,
        }

    def __repr__(self) -> str:
        return (f"Accelerator(fxp={self.model.fxp}, "
                f"unit={self.plan['compute_unit']}, "
                f"wmem={self.plan['weight_memory']}, "
                f"alu={self.plan['alu_mode']}, "
                f"hs={self.plan['hs_method']}, "
                f"backend={self.plan['backend']}, "
                f"quantized={self.qparams is not None})")
