"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU.

This is where the paper's technique lands hardest outside the LSTM itself
(DESIGN.md §4): the RG-LRU's input/recurrence gates are SIGMOIDS — with
``cfg.hard_acts`` they become the paper's HardSigmoid — and the linear
recurrence is computed with an ASSOCIATIVE SCAN for train/prefill
(log-depth, MXU-friendly) while decode keeps the O(1) recurrent state that
makes long_500k tractable.

Block structure (Griffin):
  y = W_out( GeLU(W_gate x)  *  RGLRU(conv1d(W_x x)) )
RG-LRU:
  r_t = sigma(W_a x_t + b_a)              (recurrence gate)
  i_t = sigma(W_i x_t + b_i)              (input gate)
  log a_t = -c * r_t * softplus(Lambda)   (data-dependent decay, c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hard_act import hard_sigmoid_star
from repro.models.layers import act_fn, linear
from repro.models.modules import Boxed, param, split_keys
from repro.sharding.partition import constrain

Array = jax.Array


def _gate_sigmoid(x: Array, cfg: ModelConfig) -> Array:
    if cfg.hard_acts:  # C2: the paper's HardSigmoid* in float form
        return hard_sigmoid_star(x, slope=0.125, bound=3.0)
    return jax.nn.sigmoid(x)


def init_rglru_block(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Dict[str, Boxed]:
    d, w = cfg.d_model, cfg.recurrent.lru_width
    cw = cfg.recurrent.conv_width
    ks = split_keys(key, 6)
    la = ("layers",) * len(stack)
    return {
        "w_x": param(ks[0], stack + (d, w), la + ("embed", "lru")),
        "w_gate": param(ks[1], stack + (d, w), la + ("embed", "lru")),
        "w_out": param(ks[2], stack + (w, d), la + ("lru", "embed")),
        "conv_w": param(ks[3], stack + (cw, w), la + (None, "lru"), scale=cw ** -0.5),
        "conv_b": param(None, stack + (w,), la + ("lru",), init="zeros"),
        "w_a": param(ks[4], stack + (w, w), la + ("lru", None), scale=w ** -0.5),
        "b_a": param(None, stack + (w,), la + ("lru",), init="zeros"),
        "w_i": param(ks[5], stack + (w, w), la + ("lru", None), scale=w ** -0.5),
        "b_i": param(None, stack + (w,), la + ("lru",), init="zeros"),
        # Lambda init so a^c spans ~(0.9, 0.999) — Griffin's stable band
        "lam": param(None, stack + (w,), la + ("lru",), init="ones"),
    }


def _decay(p, gx: Array, cfg: ModelConfig):
    """log a_t (negative) and the input-normaliser sqrt(1-a_t^2)."""
    c = cfg.recurrent.c_exponent
    r = _gate_sigmoid(linear(gx, p["w_a"], cfg.quant) + p["b_a"], cfg)
    i = _gate_sigmoid(linear(gx, p["w_i"], cfg.quant) + p["b_i"], cfg)
    log_a = -c * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult, i


def rglru_scan(p, x: Array, cfg: ModelConfig) -> Array:
    """Associative-scan linear recurrence over the full sequence.

    x: (B, T, W) — returns h: (B, T, W)."""
    a, mult, i = _decay(p, x, cfg)
    b = mult * (i * x)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s, h = jax.lax.associative_scan(combine, (a.astype(jnp.float32),
                                                b.astype(jnp.float32)), axis=1)
    return h.astype(x.dtype)


def rglru_step(p, x_t: Array, h_prev: Array, cfg: ModelConfig) -> Array:
    """O(1) decode step. x_t: (B, 1, W); h_prev: (B, W)."""
    a, mult, i = _decay(p, x_t, cfg)
    h = a[:, 0] * h_prev + (mult * (i * x_t))[:, 0]
    return h


def _causal_conv(p, x: Array, cfg: ModelConfig) -> Array:
    """Depthwise causal conv1d, width cfg.recurrent.conv_width."""
    cw = cfg.recurrent.conv_width
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    y = sum(xp[:, k:k + x.shape[1], :] * p["conv_w"][k] for k in range(cw))
    return y + p["conv_b"]


def rec_block_apply(p, x: Array, cfg: ModelConfig, mode: str = "train",
                    state: Dict[str, Array] = None):
    """Full Griffin recurrent block.

    train/prefill: returns y (B, T, d).
    decode: x is (B, 1, d); state {"h": (B,W), "conv": (B, cw-1, W)};
    returns (y, new_state)."""
    gate = act_fn("gelu", cfg)(linear(x, p["w_gate"], cfg.quant, mode))
    gx = linear(x, p["w_x"], cfg.quant, mode)
    gx = constrain(gx, "batch", None, "lru")
    if mode == "decode":
        cw = cfg.recurrent.conv_width
        conv_st = state["conv"]  # (B, cw-1, W) previous inputs
        window = jnp.concatenate([conv_st, gx], axis=1)  # (B, cw, W)
        cx = jnp.einsum("bkw,kw->bw", window, p["conv_w"])[:, None, :] + p["conv_b"]
        h = rglru_step(p, cx, state["h"], cfg)
        y = linear(gate * h[:, None, :], p["w_out"], cfg.quant, mode)
        return y, {"h": h, "conv": window[:, 1:, :]}
    cx = _causal_conv(p, gx, cfg)
    h = rglru_scan(p, cx, cfg)
    return linear(gate * h, p["w_out"], cfg.quant, mode)
