"""Mixture-of-Experts layer: top-k routing with LOCAL capacity dispatch.

Dispatch strategy (DESIGN.md §5): tokens stay on their (pod, data) shard and
are scattered into per-shard expert capacity buffers — no cross-data-shard
collectives from routing itself.  Expert FFN weights are TP-sharded on the
expert-ff dim ("expert_mlp" -> model) by default; with
``MoEConfig.expert_parallel`` the expert dim itself shards over the model
axis (true EP — phi3.5's 16 experts / 16-way TP), letting GSPMD insert the
all-to-alls.

The router softmax stays in fp32 and is NEVER quantised or hardened —
accuracy-critical, the same judgement the paper applies when it keeps g_t's
range exact (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, linear
from repro.models.modules import Boxed, param, split_keys
from repro.sharding.partition import constrain

Array = jax.Array


def init_moe(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Dict[str, Boxed]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff, m.num_experts
    ks = split_keys(key, 4)
    la = ("layers",) * len(stack)
    return {
        "router": param(ks[0], stack + (d, e), la + ("embed", None)),
        "w_gate": param(ks[1], stack + (e, d, f), la + ("experts", "embed", "expert_mlp"),
                        scale=d ** -0.5),
        "w_up": param(ks[2], stack + (e, d, f), la + ("experts", "embed", "expert_mlp"),
                      scale=d ** -0.5),
        "w_down": param(ks[3], stack + (e, f, d), la + ("experts", "expert_mlp", "embed"),
                        scale=f ** -0.5),
    }


def moe_apply(p: Dict[str, Any], x: Array, cfg: ModelConfig,
              mode: str = "train") -> Tuple[Array, Array]:
    """x: (B, T, d) -> (y, aux_loss).

    GROUPED capacity dispatch: each batch row is a dispatch group, so the
    slot-assignment cumsum and the scatter/gather stay LOCAL to the (pod,
    data) shard that owns the row — routing itself adds no cross-shard
    collectives (index-based scatter, not one-hot einsum — a one-hot
    dispatch tensor at LM scale is O(tokens*E*C) and OOMs).
    Returns the Switch-style load-balancing auxiliary loss.
    """
    m = cfg.moe
    b, t, d = x.shape
    # capacity per expert per group; short sequences (tests / decode warm-up)
    # get dropless capacity so prefill == sequential decode exactly.
    cap = int(max(1, t * m.top_k * m.capacity_factor / m.num_experts,
                  min(t, 16)))

    logits = linear(x, p["router"], cfg.quant, mode).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)                      # fp32, exact
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)   # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # mixtral renorm

    # Load-balance aux loss: E * sum_e(frac_tokens_e * mean_prob_e)
    one_hot = jax.nn.one_hot(expert_idx[..., 0], m.num_experts,
                             dtype=jnp.float32)
    aux = m.num_experts * jnp.sum(one_hot.mean((0, 1)) * probs.mean((0, 1)))

    # Per-group slot assignment (cumsum over the group's own tokens only).
    flat_e = expert_idx.reshape(b, t * m.top_k)             # (B, T*k)
    eo = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    slot = (jnp.cumsum(eo, 1) - 1) * eo
    slot = jnp.take_along_axis(
        slot, flat_e[..., None], axis=2)[..., 0]            # (B, T*k)
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, m.num_experts * cap)

    xk = jnp.repeat(x[:, :, None, :], m.top_k, 2).reshape(b, t * m.top_k, d)
    buf = jnp.zeros((b, m.num_experts * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, de, xx: bf.at[de].set(xx, mode="drop"))(
        buf, dest, xk)
    eb = buf[:, :-1].reshape(b, m.num_experts, cap, d)
    ep_axis = "experts" if m.expert_parallel else None
    eb = constrain(eb, "batch", ep_axis, None, None)

    # Expert FFN (batched over [group, expert]; ff dim TP-sharded)
    f = act_fn(cfg.act, cfg)
    if mode == "train" and cfg.quant.enabled:
        h = f(jnp.einsum("becd,edf->becf", eb, _fq(p["w_gate"], cfg))) * \
            jnp.einsum("becd,edf->becf", eb, _fq(p["w_up"], cfg))
        out = jnp.einsum("becf,efd->becd", h, _fq(p["w_down"], cfg))
    else:
        wg, wu, wd = (_deq(p["w_gate"], x.dtype), _deq(p["w_up"], x.dtype),
                      _deq(p["w_down"], x.dtype))
        h = f(jnp.einsum("becd,edf->becf", eb, wg)) * \
            jnp.einsum("becd,edf->becf", eb, wu)
        h = constrain(h, "batch", ep_axis, None,
                      "expert_mlp" if not m.expert_parallel else None)
        out = jnp.einsum("becf,efd->becd", h, wd)
    out = constrain(out, "batch", ep_axis, None, None)

    # Combine: gather each token's surviving claims, weight by gates.
    flat_out = jnp.concatenate(
        [out.reshape(b, -1, d), jnp.zeros((b, 1, d), out.dtype)], 1)
    y = jnp.take_along_axis(flat_out, dest[..., None], axis=1)
    y = y.reshape(b, t, m.top_k, d)
    y = jnp.sum(y * gate_vals.astype(y.dtype)[..., None], 2)
    return y, aux


def _fq(w, cfg: ModelConfig):
    from repro.core.quant import fake_quant_tensor
    return fake_quant_tensor(w, axis=tuple(range(w.ndim - 1)),
                             p2=cfg.quant.p2_scale)


def _deq(w, dtype):
    if isinstance(w, dict):
        return w["q"].astype(dtype) * w["s"].astype(dtype)
    return w.astype(dtype)
