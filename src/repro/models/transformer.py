"""Generic decoder-only LM covering every assigned architecture.

Composition rules (from ModelConfig):
  * family attn/moe/vlm/audio/dense — homogeneous block stack, scanned with
    stacked params; per-layer attention WINDOWS are scan data so gemma2's
    local/global alternation and mixtral's SWA share one compiled body.
  * family ssm (rwkv6)   — rwkv time-mix mixer + channel-mix "MLP".
  * family hybrid (recurrentgemma) — (rec, rec, attn) pattern grouped into
    scanned full periods + an unscanned tail (DESIGN.md §5).

Entry points:
  init_model      -> (params, axes)
  forward_train   -> per-microbatch CE loss (+ MoE aux)
  init_cache      -> decode cache pytree (+ logical axes)
  forward_decode  -> one-token serve step against the cache
  forward_prefill -> full-sequence logits (inference-prefill shape)
  quantize_model_params -> W8/W8A8 serve weights (C1 at LM scale)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quant import quantize_tensor
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.modules import (Boxed, is_boxed, param, scan_,
                                  split_keys, unbox)
from repro.sharding.partition import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, stack: Tuple[int, ...]):
    """One block kind's params, stacked over `stack` layers."""
    k1, k2 = jax.random.split(key)
    la = ("layers",) * len(stack)
    blk: Dict[str, Any] = {
        "ln1": L.init_norm(cfg, stack),
        "ln2": L.init_norm(cfg, stack),
    }
    if cfg.post_norms:
        blk["ln1_post"] = L.init_norm(cfg, stack)
        blk["ln2_post"] = L.init_norm(cfg, stack)
    if kind == "attn":
        blk["mixer"] = L.init_attn(k1, cfg, stack)
    elif kind == "rec":
        blk["mixer"] = RG.init_rglru_block(k1, cfg, stack)
    elif kind == "rwkv":
        rw = RW.init_rwkv_block(k1, cfg, stack)
        blk["mixer"] = {k: v for k, v in rw.items() if not k.startswith("cm_")}
        blk["mlp"] = {k: v for k, v in rw.items() if k.startswith("cm_")}
        return blk
    if cfg.moe is not None:
        blk["mlp"] = MOE.init_moe(k2, cfg, stack)
    else:
        blk["mlp"] = L.init_mlp(k2, cfg, stack)
    return blk


def init_model(cfg: ModelConfig, key) -> Tuple[Any, Any]:
    """Returns (params, logical_axes) twin pytrees."""
    ks = split_keys(key, 4)
    tree: Dict[str, Any] = {}
    tree["embed"] = param(ks[0], (cfg.vocab_size, cfg.d_model),
                          ("vocab", "embed"), scale=1.0)
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern
        full = cfg.n_layers // len(pat)
        tail = cfg.n_layers - full * len(pat)
        gkeys = split_keys(ks[1], len(pat) + 1)
        tree["groups"] = [
            _init_block(gkeys[j], cfg, pat[j], (full,)) for j in range(len(pat))
        ]
        tree["tail"] = ([_init_block(gkeys[-1], cfg, pat[0], (tail,))]
                        if tail else [])
        assert all(k == pat[0] for k in pat[:tail]), "tail must be homogeneous"
    else:
        tree["blocks"] = _init_block(ks[1], cfg, kinds[0], (cfg.n_layers,))
    tree["final_norm"] = L.init_norm(cfg)
    if not cfg.tie_embeddings:
        tree["lm_head"] = param(ks[2], (cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"), scale=cfg.d_model ** -0.5)
    return unbox(tree)


def num_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_model(cfg, k)[0], jax.random.key(0))
    return sum(int(x.size) for x in jax.tree.leaves(shapes))


def num_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k experts count)."""
    n = num_params(cfg)
    if cfg.moe is None:
        return n
    m = cfg.moe
    per_layer_expert = 3 * cfg.d_model * m.d_ff
    inactive = cfg.n_layers * (m.num_experts - m.top_k) * per_layer_expert
    return n - inactive


# ---------------------------------------------------------------------------
# block body
# ---------------------------------------------------------------------------

def _block_apply(p, x: Array, kind: str, cfg: ModelConfig, *,
                 positions, window=None, mode: str = "train",
                 state=None, cache_pos=None, ring_window=None):
    """Residual block: norm -> mixer -> (+), norm -> mlp -> (+).

    Returns (x, aux, new_state)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_apply(p["ln1"], x, cfg)
    new_state = None
    if kind == "attn":
        if mode == "decode":
            h, new_state = L.attn_apply(p["mixer"], h, positions, cfg=cfg,
                                        window=window, mode=mode,
                                        cache=state, cache_pos=cache_pos,
                                        ring_window=ring_window)
        else:
            h = L.attn_apply(p["mixer"], h, positions, cfg=cfg,
                             window=window, mode=mode)
    elif kind == "rec":
        if mode == "decode":
            h, new_state = RG.rec_block_apply(p["mixer"], h, cfg, mode, state)
        else:
            h = RG.rec_block_apply(p["mixer"], h, cfg, mode)
    elif kind == "rwkv":
        if mode == "decode":
            h, tm_state = RW.time_mix_apply(p["mixer"], h, cfg, mode,
                                            {"tm_shift": state["tm_shift"],
                                             "wkv": state["wkv"]})
            new_state = dict(tm_state)
        else:
            h = RW.time_mix_apply(p["mixer"], h, cfg, mode)
    if cfg.post_norms:
        h = L.norm_apply(p["ln1_post"], h, cfg)
    x = x + h.astype(x.dtype)

    h = L.norm_apply(p["ln2"], x, cfg)
    if kind == "rwkv":
        if mode == "decode":
            h, cm_state = RW.channel_mix_apply(p["mlp"], h, cfg, mode,
                                               {"cm_shift": state["cm_shift"]})
            new_state.update(cm_state)
        else:
            h = RW.channel_mix_apply(p["mlp"], h, cfg, mode)
    elif cfg.moe is not None:
        h, aux = MOE.moe_apply(p["mlp"], h, cfg, mode)
    else:
        h = L.mlp_apply(p["mlp"], h, cfg, mode)
    if cfg.post_norms:
        h = L.norm_apply(p["ln2_post"], h, cfg)
    x = x + h.astype(x.dtype)
    x = constrain(x, "batch", None, None)
    return x, aux, new_state


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(params, batch: Dict[str, Array], cfg: ModelConfig,
           positions) -> Array:
    if "inputs_embeds" in batch:
        h = batch["inputs_embeds"].astype(cfg.dtype)
    else:
        emb = params["embed"]
        if isinstance(emb, dict):  # quantised embedding
            h = (emb["q"][batch["tokens"]].astype(cfg.dtype)
                 * emb["s"].astype(cfg.dtype))
        else:
            h = emb[batch["tokens"]].astype(cfg.dtype)
    if cfg.norm == "gemma_rmsnorm":  # gemma scales embeddings by sqrt(d)
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.attn and cfg.attn.sinusoidal:
        h = h + L.sinusoidal_embedding(positions, cfg.d_model).astype(h.dtype)
    return constrain(h, "batch", None, None)


def _logits(params, h: Array, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        emb = params["embed"]
        w = (emb["q"].astype(h.dtype) * emb["s"].astype(h.dtype)).T \
            if isinstance(emb, dict) else emb.astype(h.dtype).T
        logits = h @ w
    else:
        logits = L.linear(h, params["lm_head"], cfg.quant,
                          "serve" if isinstance(params.get("lm_head"), dict)
                          else "train")
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        cap = cfg.final_softcap
        logits = jnp.clip(logits, -cap, cap) if cfg.hard_acts \
            else cap * jnp.tanh(logits / cap)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# forward: train
# ---------------------------------------------------------------------------

def _positions_for(batch, b, s, offset=0):
    if "position_ids" in batch:
        return batch["position_ids"]
    return jnp.broadcast_to(jnp.arange(s) + offset, (b, s))


def _run_blocks(params, h, cfg: ModelConfig, positions, mode: str):
    """Scan the layer stack(s) over a full sequence (train/prefill)."""
    seq = h.shape[1]
    aux_total = jnp.zeros((), jnp.float32)

    def make_body(kind, static_win="traced"):
        def body(x, xs):
            p, window = xs
            if static_win != "traced":
                window = static_win  # python int or None: enables the
                #                      causal-triangle static kv bounds
            x, aux, _ = _block_apply(p, x, kind, cfg, positions=positions,
                                     window=window, mode=mode)
            return x, aux
        if cfg.remat == "full":
            return jax.checkpoint(body)
        return body

    if cfg.family == "hybrid":
        # Scan over FULL PERIODS of the block pattern: the body applies one
        # (rec, rec, attn) triple, preserving the true interleaving.
        pat = cfg.recurrent.block_pattern
        attn_win = min((w for w in cfg.layer_windows(seq)), default=seq)
        attn_win = None if attn_win >= seq else int(attn_win)  # static

        def period_body(x, xs):
            aux = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(pat):
                x, a, _ = _block_apply(xs[j], x, kind, cfg,
                                       positions=positions,
                                       window=attn_win if kind == "attn"
                                       else None,
                                       mode=mode)
                aux += a
            return x, aux

        body = jax.checkpoint(period_body) if cfg.remat == "full" else period_body
        h, auxs = scan_(body, h, tuple(params["groups"]))
        aux_total += auxs.sum()
        for p in params["tail"]:
            n_tail = jax.tree.leaves(p)[0].shape[0]
            w = jnp.zeros((n_tail,), jnp.int32)
            h, auxs = scan_(make_body(pat[0]), h, (p, w))
            aux_total += auxs.sum()
    else:
        kind = cfg.layer_kinds()[0]
        static_win = "traced"
        windows = jnp.zeros((cfg.n_layers,), jnp.int32)
        if kind == "attn":
            wins = cfg.layer_windows(seq)
            if len(set(wins)) == 1:  # uniform: static (triangle + SWA skip)
                static_win = None if wins[0] >= seq else int(wins[0])
            else:                    # gemma2 local/global alternation
                windows = jnp.asarray(wins, jnp.int32)
        h, auxs = scan_(make_body(kind, static_win), h,
                        (params["blocks"], windows))
        aux_total += auxs.sum()
    return h, aux_total


def forward_train(params, batch: Dict[str, Array], cfg: ModelConfig
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Next-token CE loss over one (micro)batch."""
    tokens_or_embeds = batch.get("tokens", batch.get("inputs_embeds"))
    b, s = tokens_or_embeds.shape[:2]
    positions = _positions_for(batch, b, s)
    h = _embed(params, batch, cfg, positions)
    h, aux = _run_blocks(params, h, cfg, positions, "train")
    h = L.norm_apply(params["final_norm"], h, cfg)
    logits = _logits(params, h, cfg)                    # (B, S, V) fp32
    labels = batch["labels"]
    lw = (labels[:, 1:] >= 0).astype(jnp.float32)       # -1 = padding
    lse = jax.nn.logsumexp(logits[:, :-1], axis=-1)
    tgt = jnp.take_along_axis(logits[:, :-1],
                              jnp.maximum(labels[:, 1:], 0)[..., None],
                              axis=-1)[..., 0]
    ce = jnp.sum((lse - tgt) * lw) / jnp.maximum(lw.sum(), 1.0)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# forward: prefill / decode
# ---------------------------------------------------------------------------

def forward_prefill(params, batch: Dict[str, Array], cfg: ModelConfig) -> Array:
    tokens_or_embeds = batch.get("tokens", batch.get("inputs_embeds"))
    b, s = tokens_or_embeds.shape[:2]
    positions = _positions_for(batch, b, s)
    h = _embed(params, batch, cfg, positions)
    h, _ = _run_blocks(params, h, cfg, positions, "prefill")
    h = L.norm_apply(params["final_norm"], h, cfg)
    return _logits(params, h[:, -1:], cfg)


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int
               ) -> Dict[str, Tuple[Tuple[int, ...], Any, Tuple]]:
    """{name: (shape, dtype, logical_axes)} for the decode cache.

    Attention KV is bounded by the layer's window when EVERY attn layer is
    windowed (ring-buffer decode) — this is what makes mixtral's long_500k
    cell runnable (DESIGN.md §4). int8 KV when cfg.quant.quantize_kv."""
    kinds = cfg.layer_kinds()
    n_attn = sum(k == "attn" for k in kinds)
    specs = {}
    kv_dtype = jnp.int8 if cfg.quant.quantize_kv else jnp.bfloat16
    if n_attn:
        windows = cfg.layer_windows(seq_len)
        s_cache = max(windows)  # uniform (ragged caches break stacking)
        kv_shape = (n_attn, batch, s_cache, cfg.n_kv_heads, cfg.head_dim)
        # TP placement of the cache: shard KV heads when they divide the
        # production TP width, otherwise shard the SEQUENCE dim (sequence-
        # parallel decode attention).  Without this, GSPMD all-gathers the
        # entire cache every step for head-replicated archs (§Perf iter 1).
        from repro.sharding.partition import PRODUCTION_TP
        heads_ok = cfg.n_kv_heads % PRODUCTION_TP == 0
        seq_ax = None if heads_ok else "kv_seq"
        axes = ("layers", "batch", seq_ax, "kv_heads", None)
        specs["k"] = (kv_shape, kv_dtype, axes)
        specs["v"] = (kv_shape, kv_dtype, axes)
        if cfg.quant.quantize_kv:
            sc_axes = ("layers", "batch", seq_ax, "kv_heads")
            specs["k_scale"] = ((n_attn, batch, s_cache, cfg.n_kv_heads),
                                jnp.float32, sc_axes)
            specs["v_scale"] = ((n_attn, batch, s_cache, cfg.n_kv_heads),
                                jnp.float32, sc_axes)
    n_rec = sum(k == "rec" for k in kinds)
    if n_rec:
        w, cw = cfg.recurrent.lru_width, cfg.recurrent.conv_width
        specs["rec_h"] = ((n_rec, batch, w), jnp.float32,
                          ("layers", "batch", "lru"))
        specs["rec_conv"] = ((n_rec, batch, cw - 1, w), jnp.bfloat16,
                             ("layers", "batch", None, "lru"))
    n_rwkv = sum(k == "rwkv" for k in kinds)
    if n_rwkv:
        hd = cfg.rwkv.head_dim
        nh = cfg.d_model // hd
        specs["wkv"] = ((n_rwkv, batch, nh, hd, hd), jnp.float32,
                        ("layers", "batch", "act_heads", None, None))
        specs["tm_shift"] = ((n_rwkv, batch, cfg.d_model), jnp.bfloat16,
                             ("layers", "batch", None))
        specs["cm_shift"] = ((n_rwkv, batch, cfg.d_model), jnp.bfloat16,
                             ("layers", "batch", None))
    return specs


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return {k: jnp.zeros(sh, dt) for k, (sh, dt, _) in
            cache_spec(cfg, batch, seq_len).items()}


_STATE_KEYS = {
    "attn": (("k", "k"), ("v", "v"), ("k_scale", "k_scale"),
             ("v_scale", "v_scale")),
    "rec": (("h", "rec_h"), ("conv", "rec_conv")),
    "rwkv": (("wkv", "wkv"), ("tm_shift", "tm_shift"), ("cm_shift", "cm_shift")),
}


def _state_slice(cache, kind, lo, hi, cfg):
    return {sk: cache[ck][lo:hi] for sk, ck in _STATE_KEYS[kind]
            if ck in cache}


def _state_write(new_cache, kind, lo, hi, ns_stacked):
    for sk, ck in _STATE_KEYS[kind]:
        if ck in new_cache and sk in ns_stacked:
            if lo == 0 and hi == new_cache[ck].shape[0]:
                new_cache[ck] = ns_stacked[sk]   # full range: no copy
            else:
                new_cache[ck] = new_cache[ck].at[lo:hi].set(ns_stacked[sk])


def forward_decode(params, cache: Dict[str, Array], batch: Dict[str, Array],
                   cfg: ModelConfig) -> Tuple[Array, Dict[str, Array]]:
    """One serve step: one new token per sequence against the cache.

    Cache layout — homogeneous: states stacked (L, ...).  Hybrid: the attn
    cache is (periods, ...); rec states are ordered (position-group, period)
    then tail.  init_cache/cache_spec sizes match by construction."""
    cache_pos = batch["cache_pos"]
    tokens_or_embeds = batch.get("tokens", batch.get("inputs_embeds"))
    b = tokens_or_embeds.shape[0]
    positions = (batch["position_ids"] if "position_ids" in batch
                 else jnp.full((b, 1), cache_pos, jnp.int32))
    h = _embed(params, batch, cfg, positions)
    new_cache = dict(cache)
    seq_budget = cache["k"].shape[2] if "k" in cache else None
    ring = (seq_budget if (cfg.uniform_window and
                           seq_budget == cfg.uniform_window) else None)

    if cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern
        full = cfg.n_layers // len(pat)
        win = jnp.asarray(cfg.attn.window or ((1 << 31) - 1), jnp.int32)
        n_rec_pos = sum(k == "rec" for k in pat)

        # xs: per pattern position, (params, state-slice across periods)
        xs, rj, aj = [], 0, 0
        for j, kind in enumerate(pat):
            if kind == "rec":
                st = _state_slice(cache, "rec", rj * full, (rj + 1) * full, cfg)
                rj += 1
            else:
                st = _state_slice(cache, "attn", aj * full, (aj + 1) * full, cfg)
                aj += 1
            xs.append((params["groups"][j], st))

        def period_body(x, xs_t):
            ys = []
            for j, kind in enumerate(pat):
                p_j, st_j = xs_t[j]
                x, _, ns = _block_apply(
                    p_j, x, kind, cfg, positions=positions,
                    window=win if kind == "attn" else None, mode="decode",
                    state=st_j, cache_pos=cache_pos, ring_window=ring)
                ys.append(ns)
            return x, tuple(ys)

        h, ys = scan_(period_body, h, tuple(xs))
        rj = aj = 0
        for j, kind in enumerate(pat):
            if kind == "rec":
                _state_write(new_cache, "rec", rj * full, (rj + 1) * full, ys[j])
                rj += 1
            else:
                _state_write(new_cache, "attn", aj * full, (aj + 1) * full, ys[j])
                aj += 1
        # tail (homogeneous rec layers after the last full period)
        for p in params["tail"]:
            n = jax.tree.leaves(p)[0].shape[0]
            lo = n_rec_pos * full
            st = _state_slice(cache, "rec", lo, lo + n, cfg)

            def tail_body(x, xs_t):
                p_l, st_l = xs_t
                x, _, ns = _block_apply(p_l, x, "rec", cfg,
                                        positions=positions, mode="decode",
                                        state=st_l, cache_pos=cache_pos)
                return x, ns

            h, ns = scan_(tail_body, h, (p, st))
            _state_write(new_cache, "rec", lo, lo + n, ns)
    else:
        kind = cfg.layer_kinds()[0]
        n = cfg.n_layers
        if kind == "attn":
            win = jnp.asarray([min(w, (1 << 31) - 1) for w in
                               cfg.layer_windows(1 << 60)], jnp.int32)
        else:
            win = jnp.zeros((n,), jnp.int32)
        st = _state_slice(cache, kind, 0, n, cfg)

        def body(x, xs_t):
            p_l, w_l, st_l = xs_t
            x, _, ns = _block_apply(p_l, x, kind, cfg, positions=positions,
                                    window=w_l, mode="decode", state=st_l,
                                    cache_pos=cache_pos, ring_window=ring)
            return x, ns

        h, ns = scan_(body, h, (params["blocks"], win, st))
        _state_write(new_cache, kind, 0, n, ns)

    h = L.norm_apply(params["final_norm"], h, cfg)
    return _logits(params, h, cfg), new_cache


# ---------------------------------------------------------------------------
# serve-time quantisation (C1 at LM scale)
# ---------------------------------------------------------------------------

# leaves kept in full precision: norms, biases, gates'/decays' small tensors,
# ddlerp/LoRA params, the MoE router (accuracy-critical — the same judgement
# the paper applies keeping g_t's tanh range exact), depthwise conv.
_QUANT_EXCLUDE_EXACT = frozenset(
    {"u", "w0", "lam", "mu", "mu_x", "cm_mu_r", "cm_mu_k", "conv_w", "conv_b",
     "ln_x", "router", "b", "b_a", "b_i"})
_QUANT_EXCLUDE_PREFIX = ("ln", "b_", "bq", "bk", "bv", "lora", "wl_", "bias",
                         "final_norm")


def _quantizable(path: str, x) -> bool:
    if not hasattr(x, "ndim") or x.ndim < 2:
        return False
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return False
    leaf = path.split("/")[-1]
    if leaf in _QUANT_EXCLUDE_EXACT:
        return False
    return not any(leaf.startswith(e) for e in _QUANT_EXCLUDE_PREFIX)


def quantize_model_params(params, axes, cfg: ModelConfig):
    """Replace weight leaves with {"q": int8, "s": scale} (per-out-channel,
    power-of-two scales — the paper's shift-requant, C1).  Returns (params,
    axes) twin trees for serving."""

    def walk(p, a, path=""):
        if isinstance(p, dict):
            pairs = {k: walk(p[k], a[k], f"{path}/{k}") for k in p}
            return ({k: v[0] for k, v in pairs.items()},
                    {k: v[1] for k, v in pairs.items()})
        if isinstance(p, list):
            pairs = [walk(x, y, f"{path}/{i}") for i, (x, y) in enumerate(zip(p, a))]
            return [x for x, _ in pairs], [y for _, y in pairs]
        if _quantizable(path, p):
            # reduce over the CONTRACTION dim only: the first dim after any
            # leading layer-stack/expert dims (linear() contracts w's first
            # non-stacked dim).  Keeps per-layer / per-expert / per-output-
            # channel scales — e.g. (L, d, H, hd) -> scale (L, 1, H, hd).
            c = 0
            while c < p.ndim - 1 and a[c] in ("layers", "experts"):
                c += 1
            red = (c,)
            qt = quantize_tensor(p, axis=red, p2=cfg.quant.p2_scale)
            s_axes = tuple(a[i] if i not in red else None
                           for i in range(p.ndim))
            return ({"q": qt.values, "s": qt.scale}, {"q": a, "s": s_axes})
        return p, a

    return walk(params, axes)
