# Model substrate: generic decoder LM + recurrent blocks + the paper's LSTM.
