"""The paper's own model (LSTM + dense head) exposed through the same
framework interface as the LM architectures: (params, axes) init, train
forward (MSE regression — single-step-ahead time-series prediction on
PeMS-4W-like data), QAT forward, and the integer serve path that matches
the accelerator bit-for-bit.

The deployment surface moved to the session API: ``repro.build(model,
accel)`` owns quantisation and backend dispatch (see docs/API.md);
``serve_int`` below remains as a one-release deprecation shim.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import QLSTMConfig, forward_float, forward_qat, init_params

Array = jax.Array


def init_lstm_model(cfg: QLSTMConfig, key) -> Tuple[Any, Any]:
    params = init_params(cfg, key)
    # Logical axes: the LSTM is tiny — replicate weights, shard the batch.
    axes = jax.tree.map(lambda x: tuple(None for _ in x.shape), params)
    return params, axes


def forward(params, x: Array, cfg: QLSTMConfig, mode: str = "qat") -> Array:
    """x: (B, T, M) float -> (B, P).  mode: float | qat."""
    return forward_qat(params, x, cfg) if mode == "qat" \
        else forward_float(params, x, cfg)


def loss_fn(params, batch: Dict[str, Array], cfg: QLSTMConfig,
            mode: str = "qat") -> Tuple[Array, Dict[str, Array]]:
    y = forward(params, batch["x"], cfg, mode)
    mse = jnp.mean(jnp.square(y - batch["y"]))
    return mse, {"mse": mse}


def serve_int(params, x: Array, cfg: QLSTMConfig,
              accel: AcceleratorConfig = None, use_kernel: bool = True) -> Array:
    """Deployment path: float inputs -> integer codes -> accelerator
    datapath -> float outputs.

    .. deprecated:: 0.2
       Use the session API instead — it caches the quantised params and the
       jitted datapath across calls::

           sess = repro.build(cfg, accel, params=params).quantize()
           y = sess.infer(x, path="int")

    ``use_kernel=False`` forces the ``xla`` (lax.scan oracle) backend, as
    before."""
    warnings.warn("lstm_model.serve_int is deprecated; use "
                  "repro.build(cfg, accel, params=params).quantize()"
                  ".infer(x, path='int')", DeprecationWarning, stacklevel=2)
    from repro import api
    sess = api.build(cfg, accel or AcceleratorConfig(), params=params).quantize()
    return sess.infer(x, path="int", backend=None if use_kernel else "xla")
