"""The paper's own model (LSTM + dense head) exposed through the same
framework interface as the LM architectures: (params, axes) init, train
forward (MSE regression — single-step-ahead time-series prediction on
PeMS-4W-like data), QAT forward, and the integer serve path that matches
the accelerator bit-for-bit.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.accelerator import AcceleratorConfig
from repro.core.qlstm import (QLSTMConfig, forward_float, forward_int,
                              forward_qat, init_params, quantize_params)
from repro.kernels import ops

Array = jax.Array


def init_lstm_model(cfg: QLSTMConfig, key) -> Tuple[Any, Any]:
    params = init_params(cfg, key)
    # Logical axes: the LSTM is tiny — replicate weights, shard the batch.
    axes = jax.tree.map(lambda x: tuple(None for _ in x.shape), params)
    return params, axes


def forward(params, x: Array, cfg: QLSTMConfig, mode: str = "qat") -> Array:
    """x: (B, T, M) float -> (B, P).  mode: float | qat."""
    return forward_qat(params, x, cfg) if mode == "qat" \
        else forward_float(params, x, cfg)


def loss_fn(params, batch: Dict[str, Array], cfg: QLSTMConfig,
            mode: str = "qat") -> Tuple[Array, Dict[str, Array]]:
    y = forward(params, batch["x"], cfg, mode)
    mse = jnp.mean(jnp.square(y - batch["y"]))
    return mse, {"mse": mse}


def serve_int(params, x: Array, cfg: QLSTMConfig,
              accel: AcceleratorConfig = None, use_kernel: bool = True) -> Array:
    """Deployment path: float inputs -> integer codes -> fused Pallas kernel
    (or bit-exact oracle) -> float outputs."""
    accel = accel or AcceleratorConfig()
    qp = quantize_params(params, cfg)
    x_int = fxp.quantize(x, cfg.fxp)
    if use_kernel and cfg.num_layers == 1 and cfg.alu_mode == "pipelined":
        h_seq = ops.qlstm_seq(
            jnp.swapaxes(x_int, 0, 1).astype(cfg.fxp.storage_dtype),
            qp["layers"][0]["w_x"].astype(cfg.fxp.storage_dtype),
            qp["layers"][0]["w_h"].astype(cfg.fxp.storage_dtype),
            qp["layers"][0]["b"], cfg, accel)
        h_last = h_seq[-1].astype(jnp.int32)
        y_int = fxp.fxp_matvec_late_rounding(
            h_last, qp["dense"]["w"], qp["dense"]["b"], cfg.fxp)
    else:
        y_int = forward_int(qp, x_int, cfg)
    return fxp.dequantize(y_int, cfg.fxp)
