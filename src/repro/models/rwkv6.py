"""RWKV-6 "Finch" block (attention-free, data-dependent decay).

Two sequence-mixing formulations, selected by ``RWKVConfig.chunk``:

  * ``wkv_sequential`` — the literal per-token recurrence (state
    S_t = diag(d_t) S_{t-1} + k_t v_t^T).  O(1) state; used for decode and
    as the correctness oracle.
  * ``wkv_chunked``    — block-parallel form (flash-linear-attention style):
    within a chunk of C tokens the outputs are computed with (C x C)
    MXU matmuls and pairwise decay factors exp(L_{t-1} - L_s) (all <= 1 —
    numerically safe); chunks are chained by a short scan.  This is the
    paper's C3 (pipelined MAC) philosophy applied to an SSM: restructure a
    serial recurrence so the multiplier array stays busy.

The token-shift gates are sigmoids -> hard-activation capable (C2).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hard_act import hard_sigmoid_star
from repro.models.layers import linear, norm_apply
from repro.models.modules import Boxed, param, scan_, split_keys
from repro.sharding.partition import constrain

Array = jax.Array


def _sigmoid(x: Array, cfg: ModelConfig) -> Array:
    if cfg.hard_acts:
        return hard_sigmoid_star(x, slope=0.125, bound=3.0)
    return jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_rwkv_block(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Dict[str, Boxed]:
    d = cfg.d_model
    r = cfg.rwkv.lora_r
    rw = cfg.rwkv.lora_w
    f = cfg.d_ff
    ks = split_keys(key, 16)
    la = ("layers",) * len(stack)
    P = lambda i, shape, axes, **kw: param(ks[i], stack + shape, la + axes, **kw)
    zeros = lambda shape, axes: param(None, stack + shape, la + axes, init="zeros")
    return {
        # --- time mix ---
        "mu_x": zeros((d,), (None,)),             # base lerp for the ddlerp input
        "mu": zeros((5, d), (None, None)),        # per-channel mu for r,k,v,w,g
        "lora_a": P(0, (5, d, r), (None, "embed", None), scale=d ** -0.5),
        "lora_b": zeros((5, r, d), (None, None, None)),
        "w_r": P(1, (d, d), ("embed", "heads_d")),
        "w_k": P(2, (d, d), ("embed", "heads_d")),
        "w_v": P(3, (d, d), ("embed", "heads_d")),
        "w_g": P(4, (d, d), ("embed", "heads_d")),
        "w_o": P(5, (d, d), ("heads_d", "embed")),
        "w0": zeros((d,), (None,)),               # decay base
        "wl_a": P(6, (d, rw), ("embed", None), scale=d ** -0.5),
        "wl_b": zeros((rw, d), (None, None)),
        "u": zeros((d,), (None,)),                # per-channel bonus
        "ln_x": param(None, stack + (d,), la + (None,), init="ones"),
        # --- channel mix ---
        "cm_mu_r": zeros((d,), (None,)),
        "cm_mu_k": zeros((d,), (None,)),
        "cm_r": P(7, (d, d), ("embed", "mlp2")),
        "cm_k": P(8, (d, f), ("embed", "mlp")),
        "cm_v": P(9, (f, d), ("mlp", "embed")),
    }


# ---------------------------------------------------------------------------
# wkv core
# ---------------------------------------------------------------------------

def wkv_sequential(r, k, v, w, u, state=None):
    """Literal recurrence.  r,k,v: (B, T, H, N); w: (B, T, H, N) decay logits
    (d_t = exp(-exp(w))); u: (H, N).  state: (B, H, N, N) or None.
    Returns (y (B,T,H,N), final_state)."""
    b, t, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw          # (B, H, N)
        d = jnp.exp(-jnp.exp(wt.astype(jnp.float32)))
        kv = kt[..., :, None] * vt[..., None, :]        # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None] [..., None] * kv)
        s = d[..., None] * s + kv
        return s, y

    rr, kk, vv, ww = (jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = scan_(step, state, (rr, kk, vv, ww))
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state=None, chunk: int = 128):
    """Block-parallel WKV.  Same signature/semantics as wkv_sequential.

    Derivation: with per-channel decays d_t on the k-dim and L_t = cumsum
    (log d) within a chunk,
      y_t = r_t . (S_chunk_in * exp(L_{t-1}))            [inter-chunk]
          + sum_{s<t} (r_t exp(L_{t-1}-L_s) . k_s) v_s   [intra, strictly lower]
          + (r_t . u k_t) v_t                            [current-token bonus]
    exp(L_{t-1}-L_s) <= 1 for s < t, so everything stays in fp32 safely.
    """
    b, t, h, n = r.shape
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        # pad decay logits with -inf => d = exp(-exp(-inf)) = 1 (no decay),
        # so the chunk-final state stays valid for prefill->decode handoff.
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=-1e30)
    nc = (t + pad) // c
    f32 = lambda a: a.astype(jnp.float32)
    rc = f32(r).reshape(b, nc, c, h, n)
    kc = f32(k).reshape(b, nc, c, h, n)
    vc = f32(v).reshape(b, nc, c, h, n)
    logd = -jnp.exp(f32(w)).reshape(b, nc, c, h, n)     # log d_t  (<= 0)
    L = jnp.cumsum(logd, axis=2)                        # L_t within chunk

    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def chunk_step(s, inp):
        rb, kb, vb, Lb, ldb = inp   # (B, C, H, N), L: cumsum, ld: log d
        Lprev = Lb - ldb            # L_{t-1} (L before this token)
        r_in = rb * jnp.exp(Lprev)                     # decay from chunk start
        y_inter = jnp.einsum("bchn,bhnm->bchm", r_in, s)
        # intra-chunk: scores[t, s] = sum_n r_t[n] k_s[n] exp(L_{t-1}-L_s)[n]
        # computed per-n-pair via masked matmul over n with decay folded into
        # both sides: a_t = r_t * exp(L_{t-1}), b_s = k_s * exp(-L_s).
        # exp(-L_s) can overflow for strongly-decayed channels; clamp since
        # those channels contribute exp(L_{t-1}-L_s) ~ 0 anyway via a_t.
        k_out = kb * jnp.exp(jnp.maximum(-Lb, -60.0))   # == exp(-L_s), clamped
        scores = jnp.einsum("bchn,bshn->bhcs", r_in, k_out)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)    # strictly lower
        scores = jnp.where(tri[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhcs,bshn->bchn", scores, vb)
        bonus = jnp.einsum("bchn,bchn->bch", rb, u[None, None] * kb)
        y_bonus = bonus[..., None] * vb
        # state to next chunk: S' = diag(exp(L_C)) S + sum_s exp(L_C - L_s) k_s v_s
        LC = Lb[:, -1:, :, :]                           # (B,1,H,N)
        k_fold = kb * jnp.exp(LC - Lb)
        s_new = jnp.exp(LC[:, 0])[..., None] * s + \
            jnp.einsum("bshn,bshm->bhnm", k_fold, vb)
        return s_new, y_inter + y_intra + y_bonus

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(L, 1, 0),
          jnp.moveaxis(logd, 1, 0))
    state, ys = scan_(chunk_step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * c, h, n)[:, :t]
    return y, state


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _shift(x: Array, last: Array = None) -> Array:
    """Token shift: x_{t-1} (zeros / `last` state at t=0)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], 1) if x.shape[1] > 1 \
        else last[:, None, :]


def _ddlerp(p, x, xx, which: int):
    """Data-dependent lerp (the Finch token-shift innovation)."""
    mu_x = p["mu_x"]
    base = x + (xx - x) * mu_x
    lora = jnp.tanh(base @ p["lora_a"][which]) @ p["lora_b"][which]
    mu = p["mu"][which] + lora
    return x + (xx - x) * mu


def time_mix_apply(p, x: Array, cfg: ModelConfig, mode: str = "train",
                   state: Dict[str, Array] = None):
    b, t, d = x.shape
    h = d // cfg.rwkv.head_dim
    n = cfg.rwkv.head_dim
    xx = _shift(x, state["tm_shift"] if state else None)
    xr = _ddlerp(p, x, xx, 0)
    xk = _ddlerp(p, x, xx, 1)
    xv = _ddlerp(p, x, xx, 2)
    xw = _ddlerp(p, x, xx, 3)
    xg = _ddlerp(p, x, xx, 4)
    r = linear(xr, p["w_r"], cfg.quant, mode).reshape(b, t, h, n)
    k = linear(xk, p["w_k"], cfg.quant, mode).reshape(b, t, h, n)
    v = linear(xv, p["w_v"], cfg.quant, mode).reshape(b, t, h, n)
    g = linear(xg, p["w_g"], cfg.quant, mode)
    g = g * _sigmoid(g, cfg)  # silu/hard-silu gate
    w = (p["w0"] + jnp.tanh(xw @ p["wl_a"]) @ p["wl_b"]).reshape(b, t, h, n)
    u = p["u"].reshape(h, n)
    r = constrain(r, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)

    wkv_state = state["wkv"] if state else None
    if mode == "decode" or t == 1:
        y, s_new = wkv_sequential(r, k, v, w, u, wkv_state)
    else:
        y, s_new = wkv_chunked(r, k, v, w, u, wkv_state, cfg.rwkv.chunk)
    y = y.reshape(b, t, d).astype(x.dtype)
    # per-head groupnorm (ln_x approximates RWKV's GroupNorm over heads)
    yh = y.reshape(b, t, h, n).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(jnp.square(yh), -1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, t, d) * p["ln_x"]).astype(x.dtype)
    out = linear(y * g, p["w_o"], cfg.quant, mode)
    if state is not None or mode == "decode":
        return out, {"tm_shift": x[:, -1], "wkv": s_new}
    return out


def channel_mix_apply(p, x: Array, cfg: ModelConfig, mode: str = "train",
                      state: Dict[str, Array] = None):
    xx = _shift(x, state["cm_shift"] if state else None)
    xr = x + (xx - x) * p["cm_mu_r"]
    xk = x + (xx - x) * p["cm_mu_k"]
    r = _sigmoid(linear(xr, p["cm_r"], cfg.quant, mode), cfg)
    k = jnp.square(jax.nn.relu(linear(xk, p["cm_k"], cfg.quant, mode)))
    k = constrain(k, "batch", None, "mlp")
    y = r * linear(k, p["cm_v"], cfg.quant, mode)
    if state is not None or mode == "decode":
        return y, {"cm_shift": x[:, -1]}
    return y
