"""--arch registry: name -> (ModelConfig | QLSTMConfig)."""
from __future__ import annotations

from typing import Dict, Union

from repro.configs import ARCH_CONFIGS
from repro.configs.base import ModelConfig
from repro.core.qlstm import QLSTMConfig


def get_config(name: str):
    if name not in ARCH_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCH_CONFIGS)}")
    return ARCH_CONFIGS[name]


def list_archs():
    return sorted(ARCH_CONFIGS)
