"""Transformer substrate: norms, RoPE / M-RoPE, GQA attention (windowed /
softcapped / chunked-online-softmax), GLU MLPs — all quantisation-aware
(C1) and hard-activation-capable (C2).

Attention is chunked flash-style (online softmax over KV blocks inside a
sequential map over Q blocks) so 32k-token prefill never materialises a
(T, S) score matrix.  The masked-rectangle formulation costs ~2x the causal
FLOPs; this is accounted in the roofline's useful-ratio and is a hillclimb
lever (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hard_act import get_float_act, HARD_VARIANT
from repro.core.quant import QuantConfig, fake_quant_tensor, fq_matmul
from repro.models.modules import Boxed, map_, param, scan_, split_keys
from repro.sharding.partition import constrain

Array = jax.Array


def act_fn(name: str, cfg: ModelConfig):
    """Resolve an activation, honouring the hard_acts flag (C2)."""
    if cfg.hard_acts:
        name = HARD_VARIANT.get(name, name)
    return get_float_act(name)


# ---------------------------------------------------------------------------
# Quantisation-aware linear
# ---------------------------------------------------------------------------

def linear(x: Array, w, quant: QuantConfig, mode: str = "train") -> Array:
    """x @ w where w is a float array (train/QAT) or a {"q","s"} int8 dict
    (serve).  Contraction is over x's last dim and w's first dim; w may have
    extra trailing dims (e.g. (d, H, hd)) — they are flattened."""
    if isinstance(w, dict):  # quantised serve weights
        wq, ws = w["q"], w["s"]
        shp = wq.shape
        w2 = wq.reshape(shp[0], -1)
        if quant.mode == "w8a8":
            # dynamic per-tensor activation quant, int8 x int8 -> int32
            s_x = jax.lax.stop_gradient(
                jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0)
            s_x = jnp.exp2(jnp.ceil(jnp.log2(s_x))) if quant.p2_scale else s_x
            xq = jnp.clip(jnp.floor(x / s_x + 0.5), -128, 127).astype(jnp.int8)
            acc = jax.lax.dot_general(
                xq, w2, (((x.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            y = acc.astype(jnp.float32) * s_x * ws.reshape(1, -1)
            y = y.astype(x.dtype)
        else:  # w8: dequantise weights into the matmul
            y = jax.lax.dot_general(
                x, (w2.astype(x.dtype) * ws.reshape(1, -1).astype(x.dtype)),
                (((x.ndim - 1,), (0,)), ((), ())))
        return y.reshape(x.shape[:-1] + shp[1:])
    shp = w.shape
    w2 = w.reshape(shp[0], -1)
    if mode == "train" and quant.enabled:
        y = fq_matmul(x, w2.astype(x.dtype), quant)
    else:
        y = jnp.dot(x, w2.astype(x.dtype))
    return y.reshape(x.shape[:-1] + shp[1:])


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Boxed:
    axes = ("layers",) * len(stack) + (None,)
    init = "zeros" if cfg.norm == "gemma_rmsnorm" else "ones"
    return param(None, stack + (cfg.d_model,), axes, init=init)


def norm_apply(w: Array, x: Array, cfg: ModelConfig, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * w
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        y = y * (1.0 + w) if cfg.norm == "gemma_rmsnorm" else y * w
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions: Array, dim: int, theta: float) -> Tuple[Array, Array]:
    """positions (...,) -> cos/sin (..., dim/2)."""
    freq = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, positions: Array, theta: float,
               mrope_sections: Optional[Tuple[int, ...]] = None) -> Array:
    """x: (B, T, H, hd).  positions: (B, T) or — M-RoPE — (3, B, T).

    M-RoPE (Qwen2-VL): the head_dim's frequency slots are partitioned into
    sections, each rotated by its own positional stream (temporal / height /
    width)."""
    hd = x.shape[-1]
    if mrope_sections is not None:
        cos3, sin3 = _rope_angles(positions, hd, theta)  # (3, B, T, hd/2)
        parts_c, parts_s = [], []
        off = 0
        for i, sec in enumerate(mrope_sections):
            parts_c.append(cos3[i, ..., off:off + sec])
            parts_s.append(sin3[i, ..., off:off + sec])
            off += sec
        cos = jnp.concatenate(parts_c, -1)
        sin = jnp.concatenate(parts_s, -1)
    else:
        cos, sin = _rope_angles(positions, hd, theta)    # (B, T, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: Array, dim: int) -> Array:
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Dict[str, Boxed]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    la = ("layers",) * len(stack)
    p = {
        "wq": param(ks[0], stack + (d, h, hd), la + ("embed", "heads", "head_dim"),
                    scale=d ** -0.5),
        "wk": param(ks[1], stack + (d, kv, hd), la + ("embed", "kv_heads", "head_dim"),
                    scale=d ** -0.5),
        "wv": param(ks[2], stack + (d, kv, hd), la + ("embed", "kv_heads", "head_dim"),
                    scale=d ** -0.5),
        "wo": param(ks[3], stack + (h * hd, d), la + ("heads", "embed"),
                    scale=(h * hd) ** -0.5),
    }
    if cfg.attn and cfg.attn.qkv_bias:
        p["bq"] = param(None, stack + (h, hd), la + ("heads", "head_dim"), init="zeros")
        p["bk"] = param(None, stack + (kv, hd), la + ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = param(None, stack + (kv, hd), la + ("kv_heads", "head_dim"), init="zeros")
    return p


def _softcap(scores: Array, cap: Optional[float], hard: bool) -> Array:
    if cap is None:
        return scores
    if hard:  # C2 beyond-paper: softcap's tanh hardened to a clip
        return jnp.clip(scores, -cap, cap)
    return cap * jnp.tanh(scores / cap)


def _attn_q_chunk(qb: Array, qi: int, j_lo: int, kg: Array, vg: Array, *,
                  qc: int, kc: int, scale, softcap, hard_softcap, causal,
                  window, s_valid, q_offset) -> Array:
    """Online-softmax attention of ONE q chunk against kv blocks
    [j_lo, j_lo + kg.shape[1]).  qb: (B, qc, KV, g, hd); kg/vg:
    (B, nj, kc, KV, hd).  Returns (B, qc, KV, g, hd) in fp32."""
    b, _, kvh, g, hd = qb.shape
    nj = kg.shape[1]
    qpos = q_offset + qi * qc + jnp.arange(qc)
    qf = qb.astype(jnp.float32)

    def kv_step(carry, xs):
        m, l, acc = carry
        jj, kb, vb = xs
        kpos = (j_lo + jj) * kc + jnp.arange(kc)
        sc = jnp.einsum("bqkgh,bskh->bkgqs", qf,
                        kb.astype(jnp.float32)) * scale
        sc = _softcap(sc, softcap, hard_softcap)
        mask = kpos[None, :] < s_valid
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc * corr[..., None] + pv), None

    m0 = jnp.full((b, kvh, g, qc), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
    (m, l, acc), _ = scan_(kv_step, (m0, l0, a0),
                           (jnp.arange(nj), jnp.moveaxis(kg, 1, 0),
                            jnp.moveaxis(vg, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bkgqh->bqkgh", out)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[Array] = None,
                    softcap: Optional[float] = None, hard_softcap: bool = False,
                    scale: float = 1.0, q_offset: Array = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    kv_valid_len: Optional[Array] = None,
                    k_scale: Optional[Array] = None,
                    v_scale: Optional[Array] = None) -> Array:
    """Chunked online-softmax attention.

    q: (B, T, H, hd); k, v: (B, S, KV, hd); GQA via head grouping.
    window: traced scalar — qpos-kpos must be < window (SWA / gemma2
    alternation as scan-compatible data, DESIGN.md §5).
    kv_valid_len: decode masking (cache slots >= this are invalid).
    k_scale/v_scale (B, S, KV): int8-KV dequantisation scales (C1 applied to
    the cache) — k's folds into the scores, v's folds into the softmax
    weights, so the cache is only ever READ as int8.
    Returns (B, T, H, hd).
    """
    b, t, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qc = min(q_chunk, t)
    kc = min(kv_chunk, s)
    tp, sp = -t % qc, -s % kc
    if tp:
        q = jnp.pad(q, ((0, 0), (0, tp), (0, 0), (0, 0)))
    if sp:
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, sp), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, sp), (0, 0)))
    nq, nk = (t + tp) // qc, (s + sp) // kc
    qg = q.reshape(b, nq, qc, kvh, g, hd)
    kg = k.reshape(b, nk, kc, kvh, hd)
    vg = v.reshape(b, nk, kc, kvh, hd)
    scales = ()
    if k_scale is not None:
        scales = (jnp.moveaxis(k_scale.reshape(b, nk, kc, kvh), 1, 0),
                  jnp.moveaxis(v_scale.reshape(b, nk, kc, kvh), 1, 0))
    s_valid = jnp.asarray(s if kv_valid_len is None else kv_valid_len, jnp.int32)

    # CAUSAL-TRIANGLE path (train/prefill: t == s, no offset): per-q-chunk
    # STATIC kv bounds skip the strictly-future blocks the masked-rectangle
    # formulation still computes (~2x attention FLOPs), and a *static*
    # sliding window additionally skips fully-expired past blocks (SWA cost
    # becomes window-linear).  §Perf iteration 2.
    static_window = window if isinstance(window, int) else None
    if (causal and t == s and tp == 0 and sp == 0
            and isinstance(q_offset, int) and q_offset == 0
            and kv_valid_len is None and not scales):
        outs = []
        for qi in range(nq):
            j_hi = ((qi + 1) * qc + kc - 1) // kc          # blocks <= diag
            j_lo = 0
            if static_window is not None:
                j_lo = max(0, (qi * qc - static_window + 1) // kc)
            outs.append(_attn_q_chunk(
                qg[:, qi], qi, j_lo, kg[:, j_lo:j_hi], vg[:, j_lo:j_hi],
                qc=qc, kc=kc, scale=scale, softcap=softcap,
                hard_softcap=hard_softcap, causal=True, window=window,
                s_valid=s_valid, q_offset=0))
        out = jnp.stack(outs, 1).reshape(b, t, h, hd)
        return out.astype(q.dtype)

    def q_block(qi_and_chunk):
        qi, qb = qi_and_chunk  # qb: (B, qc, KV, g, hd)
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, kb, vb = xs[0], xs[1], xs[2]
            kpos = kj * kc + jnp.arange(kc)
            sc = jnp.einsum("bqkgh,bskh->bkgqs", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * scale
            if scales:
                ks = xs[3]  # (B, kc, KV)
                sc = sc * jnp.transpose(ks, (0, 2, 1))[:, :, None, None, :]
            sc = _softcap(sc, softcap, hard_softcap)
            mask = kpos[None, :] < s_valid
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            if scales:
                vs = xs[4]
                p_v = p * jnp.transpose(vs, (0, 2, 1))[:, :, None, None, :]
            else:
                p_v = p
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p_v, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
        (m, l, acc), _ = scan_(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0))
            + scales)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.einsum("bkgqh->bqkgh", out)

    outs = map_(q_block, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t + tp, h, hd)
    return out[:, :t].astype(q.dtype)


def attn_apply(p: Dict[str, Any], x: Array, positions: Array, *,
               cfg: ModelConfig, window=None, mode: str = "train",
               cache: Optional[Tuple[Array, Array]] = None,
               cache_pos: Optional[Array] = None,
               ring_window: Optional[int] = None):
    """GQA attention block body.

    train/prefill: full-sequence causal (chunked).  decode: x is (B, 1, d);
    cache (k, v) each (B, Smax, KV, hd) is updated at cache_pos (ring-buffer
    indexed when ring_window is set — bounded-KV SWA decode)."""
    a = cfg.attn
    scale = (a.query_scale or cfg.head_dim ** -0.5) if a else cfg.head_dim ** -0.5
    q = linear(x, p["wq"], cfg.quant, mode)
    k = linear(x, p["wk"], cfg.quant, mode)
    v = linear(x, p["wv"], cfg.quant, mode)
    if a and a.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if not (a and a.sinusoidal):
        q = apply_rope(q, positions, a.rope_theta, a.mrope_sections)
        k = apply_rope(k, positions, a.rope_theta, a.mrope_sections)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)

    if mode == "decode":
        st = dict(cache)
        slot = cache_pos % ring_window if ring_window else cache_pos
        quant_kv = st["k"].dtype == jnp.int8
        if quant_kv:
            # C1 on the cache: per-(token, head) symmetric int8
            def q8(t):  # t: (B, 1, KV, hd)
                s_ = jnp.maximum(jnp.max(jnp.abs(t), -1), 1e-6) / 127.0
                tq = jnp.clip(jnp.floor(t / s_[..., None] + 0.5),
                              -128, 127).astype(jnp.int8)
                return tq, s_.astype(jnp.float32)
            kq, ks_new = q8(k.astype(jnp.float32))
            vq, vs_new = q8(v.astype(jnp.float32))
            st["k"] = jax.lax.dynamic_update_slice(st["k"], kq, (0, slot, 0, 0))
            st["v"] = jax.lax.dynamic_update_slice(st["v"], vq, (0, slot, 0, 0))
            st["k_scale"] = jax.lax.dynamic_update_slice(
                st["k_scale"], ks_new, (0, slot, 0))
            st["v_scale"] = jax.lax.dynamic_update_slice(
                st["v_scale"], vs_new, (0, slot, 0))
            kcache, vcache = st["k"], st["v"]
            kscale, vscale = st["k_scale"], st["v_scale"]
        else:
            st["k"] = jax.lax.dynamic_update_slice(
                st["k"], k.astype(st["k"].dtype), (0, slot, 0, 0))
            st["v"] = jax.lax.dynamic_update_slice(
                st["v"], v.astype(st["v"].dtype), (0, slot, 0, 0))
            kcache, vcache = st["k"], st["v"]
            kscale = vscale = None
        kv_valid = jnp.minimum(cache_pos + 1, st["k"].shape[1])
        out = flash_attention(
            q, kcache, vcache, causal=False,
            window=None if ring_window else window,
            softcap=a.attn_softcap if a else None, hard_softcap=cfg.hard_acts,
            scale=scale, q_offset=cache_pos, kv_valid_len=kv_valid,
            q_chunk=1, kv_chunk=min(4096, st["k"].shape[1]),
            k_scale=kscale, v_scale=vscale)
        y = out.reshape(*x.shape[:2], -1)
        y = linear(y, p["wo"], cfg.quant, mode)
        return y, st

    out = flash_attention(
        q, k, v, causal=True, window=window,
        softcap=a.attn_softcap if a else None, hard_softcap=cfg.hard_acts,
        scale=scale)
    y = out.reshape(*x.shape[:2], -1)
    return linear(y, p["wo"], cfg.quant, mode)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> Dict[str, Boxed]:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    la = ("layers",) * len(stack)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": param(ks[0], stack + (d, f), la + ("embed", "mlp")),
            "w_up": param(ks[1], stack + (d, f), la + ("embed", "mlp")),
            "w_down": param(ks[2], stack + (f, d), la + ("mlp", "embed")),
        }
    return {
        "w_up": param(ks[0], stack + (d, f), la + ("embed", "mlp")),
        "w_down": param(ks[1], stack + (f, d), la + ("mlp", "embed")),
    }


def mlp_apply(p: Dict[str, Any], x: Array, cfg: ModelConfig,
              mode: str = "train") -> Array:
    f = act_fn(cfg.act, cfg)
    if cfg.mlp_type in ("swiglu", "geglu"):
        h = f(linear(x, p["w_gate"], cfg.quant, mode)) * \
            linear(x, p["w_up"], cfg.quant, mode)
    else:
        h = f(linear(x, p["w_up"], cfg.quant, mode))
    h = constrain(h, "batch", None, "mlp")
    return linear(h, p["w_down"], cfg.quant, mode)
