"""Minimal module system: params are nested dicts of arrays; every leaf is
created together with its LOGICAL AXES tuple so the sharding rules in
`sharding/partition.py` can map leaves to PartitionSpecs without a parallel
hand-maintained tree.

``init`` functions build trees of ``Boxed(value, axes)``; ``unbox`` splits
them into (params, axes) with identical structure — one code path, no drift.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# --- cost-exact (unrolled) tracing mode -------------------------------------
# XLA's HloCostAnalysis counts a while-loop body ONCE, so the roofline FLOP
# accounting lowers the step with every model loop unrolled (scan unroll=True)
# and reads cost_analysis() from the *lowered* (uncompiled) module.  The
# compile-proof dry-run keeps the scanned form (fast compiles).
_UNROLL: contextvars.ContextVar = contextvars.ContextVar("unroll", default=False)


@contextlib.contextmanager
def unroll_mode():
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def unrolling() -> bool:
    return _UNROLL.get()


def scan_(body, init, xs, length=None):
    """lax.scan that fully unrolls under unroll_mode() (cost-exact HLO)."""
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if _UNROLL.get() else 1)


def map_(f, xs):
    """lax.map that unrolls under unroll_mode()."""
    if _UNROLL.get():
        def body(_, x):
            return (), f(x)
        _, ys = jax.lax.scan(body, (), xs, unroll=True)
        return ys
    return jax.lax.map(f, xs)


@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Boxed tree -> (params, axes) twin trees."""
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return params, axes


def param(key, shape, axes, scale: float = None, dtype=jnp.float32,
          init: str = "normal") -> Boxed:
    """Create one parameter leaf with logical axes metadata."""
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            # fan-in scaling on the contracting dim (first non-stacked dim)
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5
        v = jax.random.normal(key, shape, dtype) * scale
    return Boxed(v, axes)


def split_keys(key, n):
    return list(jax.random.split(key, n))


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
