"""Optimized-HLO parsing: collective operand/result bytes per op kind.

cost_analysis() does not report collective traffic, so the §Roofline
collective term is derived by parsing the compiled module's text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(including async -start forms, excluding -done echoes) we sum the RESULT
buffer sizes.  SPMD modules are per-device, so these are per-device bytes —
consistent with the per-device compute/memory terms.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result types: everything between '=' and the op name, e.g.
#   %ag = bf16[4,128]{1,0} all-gather(...)
#   %ar = (f32[8]{0}, f32[8]{0}) all-reduce-start(...)
_LINE_RE = re.compile(
    r"=\s*(?P<types>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(types: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(types):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes by collective kind (+ 'total' and 'count')."""
    out: Dict[str, float] = defaultdict(float)
    count = 0
    for m in _LINE_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # the -start already carries the payload
        b = _shape_bytes(m.group("types"))
        # async -start results are (input, output[, context]) tuples; the
        # payload moved is ~ the output. Halve the tuple double-count.
        if m.group("suffix") == "-start":
            b = b / 2
        out[m.group("op")] += b
        count += 1
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    out["count"] = count
    return dict(out)
