"""Render sweep JSON artifacts into EXPERIMENTS.md-ready markdown tables.

Dry-run sweeps (§Dry-run / §Roofline, plus §Perf deltas vs a baseline):

  PYTHONPATH=src python -m repro.analysis.report results/dryrun.json \
      [--baseline results/dryrun_baseline.json]

Design-space sweeps (the ``BENCH_pareto.json`` written by
``benchmarks/run.py --sweep``; Pareto-front rows are bolded):

  PYTHONPATH=src python -m repro.analysis.report --pareto BENCH_pareto.json

Serving runs (the ``BENCH_serving.json`` written by
``benchmarks/bench_serving.py``; one row per scenario, scored against the
paper's §6 headline):

  PYTHONPATH=src python -m repro.analysis.report --serving BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.2f} {unit}"
        b /= 1024
    return f"{b:.2f} PiB"


def _ms(s: float) -> str:
    return f"{s * 1e3:.2f}"


def dryrun_table(rs: List[Dict], mesh: str) -> str:
    rows = [r for r in rs if r.get("mesh") == mesh]
    out = [f"| arch | shape | status | compile s | params | peak GB/dev | "
           f"coll MB/dev | microbatches |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                       f"({r.get('reason', '')[:60]}...) | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r.get('compile_s', '')} | "
            f"{r.get('params', 0) / 1e9:.2f}B | "
            f"{r['memory'].get('peak_gb', 0):.2f} | "
            f"{r['collectives'].get('total', 0) / 2**20:.1f} | "
            f"{r.get('microbatches', '-')} |")
    return "\n".join(out)


def roofline_table(rs: List[Dict]) -> str:
    rows = [r for r in rs if r.get("mesh") == "16x16" and r["status"] == "ok"]
    out = ["| arch | shape | compute ms | memory ms | collective ms | bound "
           "| step ms | MODEL_FLOPS/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        note = _bottleneck_note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {_ms(t['compute_s'])} | "
            f"{_ms(t['memory_s'])} | {_ms(t['collective_s'])} | "
            f"**{t['bound']}** | {_ms(t['step_s'])} | "
            f"{(r.get('useful_flops_ratio') or 0):.2f} | {note} |")
    return "\n".join(out)


def _bottleneck_note(r: Dict) -> str:
    b = r["roofline"]["bound"]
    if b == "compute":
        u = r.get("useful_flops_ratio") or 0
        if u < 0.6:
            return ("cut remat/masked-rectangle waste (causal-aware "
                    "chunking, remat policy)")
        return "raise MXU util (larger microbatch, fused kernels)"
    if b == "memory":
        if r["kind"] == "decode":
            return "int8 weights + int8 KV (C1) halve/quarter traffic"
        return "fewer weight re-reads (fewer microbatches) / bf16 master"
    return "reshard to kill the dominant gather (see §Perf)"


def perf_delta_table(rs: List[Dict], base: List[Dict]) -> str:
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    bmap = {key(r): r for r in base if r.get("status") == "ok"}
    out = ["| cell | mesh | step ms before | after | coll MB before | after "
           "| peak GB before | after |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rs, key=key):
        if r.get("status") != "ok":
            continue
        b = bmap.get(key(r))
        if not b:
            continue
        t, tb = r["roofline"], b["roofline"]
        if abs(t["step_s"] - tb["step_s"]) / max(tb["step_s"], 1e-12) < 0.02 \
           and abs(r["memory"]["peak_gb"] - b["memory"]["peak_gb"]) < 0.5:
            continue  # only show meaningful deltas
        out.append(
            f"| {r['arch']} {r['shape']} | {r['mesh']} | {_ms(tb['step_s'])} "
            f"| **{_ms(t['step_s'])}** | "
            f"{b['collectives'].get('total', 0) / 2**20:.0f} | "
            f"**{r['collectives'].get('total', 0) / 2**20:.0f}** | "
            f"{b['memory'].get('peak_gb', 0):.1f} | "
            f"**{r['memory'].get('peak_gb', 0):.1f}** |")
    return "\n".join(out)


def pareto_table(payload: Dict) -> str:
    """The §Design-space table: one row per swept point, front rows bold.

    ``payload`` is the ``BENCH_pareto.json`` schema from
    ``repro.explore.sweep`` (see tests/test_explore.py).  Serving-aware
    payloads (schema v2 with a ``scenario``) get SLO columns — tail
    latency, deadline-miss rate, halving rung — instead of the offline
    energy/accuracy ones; an eliminated-everything sweep renders its
    ``front_reason`` instead of a silently empty front."""
    objectives = ", ".join(f"{k} ({v})"
                           for k, v in payload["objectives"].items())
    head = (f"Objectives: {objectives}.  Front: "
            f"{len(payload['front'])}/{len(payload['points'])} points.")
    if payload.get("constraint"):
        head += f"  SLO: {payload['constraint']}."
    if payload.get("scenario"):
        sc = payload["scenario"]
        head += (f"  Scenario: {sc.get('name', 'scenario')} "
                 f"({sc.get('streams')} streams x "
                 f"{sc.get('windows_per_stream')} windows, "
                 f"deadline {sc.get('deadline_ms')} ms, "
                 f"strategy={payload.get('strategy', 'full')}).")
    out = [head]
    if not payload["front"] and payload.get("front_reason"):
        out.append(f"Empty front: {payload['front_reason']}")
    out.append("")
    if payload.get("scenario"):
        return "\n".join(out + _serving_pareto_rows(payload))
    out += ["| config | backend | samples/s | GOP/s | GOP/s/W | total W | "
            "int-vs-float MSE | weights | front |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in payload["points"]:
        if r["status"] != "ok":
            out.append(f"| {r['label']} | — | {r['status']}: "
                       f"{r.get('reason', '')[:60]} | | | | | | |")
            continue
        m = r["metrics"]
        b = "**" if r["pareto"] else ""
        out.append(
            f"| {b}{r['label']}{b} | {r['plan']['backend']} | "
            f"{m['samples_per_s']:,.0f} | {m['throughput_gops']:.3f} | "
            f"{m['gops_per_watt']:.4f} | {m['total_w']:.1f} | "
            f"{m['int_float_mse']:.2e} | {_fmt_bytes(m['weight_bytes'])} | "
            f"{'yes' if r['pareto'] else ''} |")
    return "\n".join(out)


def _serving_pareto_rows(payload: Dict) -> list:
    """The serving-mode rows of :func:`pareto_table`: achieved rate and
    tail latency against the SLO, plus which halving rung each point was
    last measured at (non-final rungs ran a truncated scenario)."""
    out = ["| config | backend | replicas | samples/s | p50 ms | p95 ms | "
           "p99 ms | miss rate | GOP/s/W | rung | front |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in payload["points"]:
        if r["status"] != "ok":
            out.append(f"| {r['label']} | — | {r['status']}: "
                       f"{r.get('reason', '')[:60]} | | | | | | | | |")
            continue
        m = r["metrics"]
        op = r.get("operating_point") or {}
        rung = op.get("rung")
        rung_s = "full" if op.get("final") else (
            f"r{rung}@{op.get('fraction', 0):g}" if rung is not None else "—")
        gpw = m.get("gops_per_watt")
        b = "**" if r["pareto"] else ""
        out.append(
            f"| {b}{r['label']}{b} | {r['plan']['backend']} | "
            f"{r['plan'].get('replicas', 1)} | {m['samples_per_s']:,.0f} | "
            f"{m['p50_ms']:.2f} | {m['p95_ms']:.2f} | {m['p99_ms']:.2f} | "
            f"{m['deadline_miss_rate']:.3f} | "
            + (f"{gpw:.4f}" if gpw is not None and gpw == gpw else "—")
            + f" | {rung_s} | {'yes' if r['pareto'] else ''} |")
    return out


def serving_table(payload: Dict) -> str:
    """The §Serving table: one row per scenario from ``BENCH_serving.json``
    (see ``benchmarks/bench_serving.py`` for the schema), scored against
    the paper's §6 reference point."""
    paper = payload["paper"]
    out = [f"Paper reference (XC7S15 @ 204 MHz): "
           f"{paper['samples_per_s']:,.0f} samples/s, "
           f"{paper['gops_per_watt']:.2f} GOP/s/W.", "",
           "| scenario | backend | samples/s | vs paper | p50 ms | p95 ms | "
           "p99 ms | waves | occupancy | deadline flushes | evictions | "
           "GOP/s/W |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for name, s in payload["scenarios"].items():
        lat = s["latency_ms"]
        ev = (s.get("state") or {}).get("evictions", "—")
        out.append(
            f"| {name} | {s.get('backend', '—')} | "
            f"{s['samples_per_s']:,.0f} | "
            f"{s['vs_paper_samples_per_s']:.2f}x | {lat['p50']:.2f} | "
            f"{lat['p95']:.2f} | {lat['p99']:.2f} | {s['waves']} | "
            f"{s['mean_occupancy']:.1f}/{s['batch']} | "
            f"{s['deadline_flushes']} | {ev} | "
            f"{s['gops_per_watt']:.4f} |")
    fault_rows = _serving_fault_rows(payload)
    if fault_rows:
        out += ["", "Reliability (schema >= 3: the PR-6 guarded-execution "
                "layer; `injected` is the seeded chaos schedule that was "
                "absorbed):", "",
                "| scenario | served on | health | retries | wave failures |"
                " sheds | rejections | degradations | promotions | "
                "state resets | stream errors | injected faults |",
                "|---|---|---|---|---|---|---|---|---|---|---|---|"]
        out += fault_rows
    replica_rows = _serving_replica_rows(payload)
    if replica_rows:
        out += ["", "Cluster breakdown (schema >= 4: one row per replica "
                "of each `cluster[rN]` scenario; `aggregate samples/s` is "
                "the cluster's merged rate over the common wall, per-"
                "replica rates are each server's own):", "",
                "| scenario | replica | samples/s | p50 ms | p99 ms | "
                "waves | occupancy | streams |",
                "|---|---|---|---|---|---|---|---|"]
        out += replica_rows
    return "\n".join(out)


def _serving_fault_rows(payload: Dict) -> list:
    """§Serving reliability rows — one per scenario carrying a ``faults``
    block (empty for pre-PR-6 artifacts, keeping old JSONs renderable)."""
    rows = []
    for name, s in payload["scenarios"].items():
        f = s.get("faults")
        if f is None:
            continue
        inj = f.get("injected") or {}
        n_inj = sum(v for k, v in inj.items() if k != "attempts")
        health = (s.get("health") or {}).get("status", "—")
        rows.append(
            f"| {name} | {f['backend']}"
            f"{' (degraded)' if f['degraded'] else ''} | {health} | "
            f"{f['retries']} | {f['wave_failures']} | {f['sheds']} | "
            f"{f['rejections']} | {f['degradations']} | {f['promotions']} | "
            f"{f['state_resets']} | {f['stream_errors']} | {n_inj} |")
    return rows


def _serving_replica_rows(payload: Dict) -> list:
    """§Serving cluster rows — one per replica of each scenario carrying a
    ``replicas`` breakdown (the ClusterServer scenarios of schema >= 4;
    empty for single-server artifacts, keeping old JSONs renderable)."""
    rows = []
    for name, s in payload["scenarios"].items():
        per = s.get("replicas")
        if not per:
            continue
        for rname in sorted(per):
            p = per[rname]
            lat = p.get("latency_ms") or {}
            live = (p.get("state") or {}).get("live_streams", "—")
            occ = (f"{p['mean_occupancy']:.1f}/{p['batch']}"
                   if p.get("waves") else "—")
            rows.append(
                f"| {name} | {rname} | {p['samples_per_s']:,.0f} | "
                f"{lat.get('p50', 0):.2f} | {lat.get('p99', 0):.2f} | "
                f"{p['waves']} | {occ} | {live} |")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--pareto", action="store_true",
                    help="results is a BENCH_pareto.json design-space sweep")
    ap.add_argument("--serving", action="store_true",
                    help="results is a BENCH_serving.json serving run")
    args = ap.parse_args()
    rs = json.load(open(args.results))
    if args.pareto:
        print("## §Design-space — measured sweep + Pareto front\n")
        print(pareto_table(rs))
        return
    if args.serving:
        print("## §Serving — streaming subsystem vs the paper's §6 "
              "deployment\n")
        print(serving_table(rs))
        return
    print("## §Dry-run — single-pod 16x16 (256 chips)\n")
    print(dryrun_table(rs, "16x16"))
    print("\n## §Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(rs, "2x16x16"))
    print("\n## §Roofline — single-pod, per-device terms\n")
    print(roofline_table(rs))
    if args.baseline:
        base = json.load(open(args.baseline))
        print("\n## §Perf — deltas vs baseline sweep\n")
        print(perf_delta_table(rs, base))


if __name__ == "__main__":
    main()
