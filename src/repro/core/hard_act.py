"""Hard activation functions — the paper's contribution C2.

Float domain (for training / QAT) and integer domain (bit-exact hardware
semantics) implementations of:

  * HardTanh        — clip(x, min_val, max_val); 5 LUTs on the FPGA, a pair
                      of VPU selects on TPU.
  * HardSigmoid*    — the paper's customised HardSigmoid: slope 2**-k
                      (bit-shiftable; k=3 -> slope 0.125 for the (4,8)
                      standard config), saturation bounds ±3 (inherited from
                      the PyTorch HardSigmoid), THREE interchangeable
                      implementations:
        - ``arithmetic``: shift + add                      (2 sequential ops)
        - ``1to1``      : full lookup table                (gather)
        - ``step``      : merged step-function thresholds  (nested selects)
    All three are bit-identical by construction (the tables are derived from
    the arithmetic definition); which is *fastest* depends on the fixed-point
    configuration — the paper's Table 1, reproduced by
    ``benchmarks/bench_activations.py``.

  * LUT Sigmoid/Tanh — the 256-entry lookup-table activations of the baseline
    [15], implemented for the baseline comparison.

Paper-faithfulness notes:
  * The slope division uses a *truncating* arithmetic shift.  Together with
    the linear region ``[-3, 3)`` this reproduces the paper's reported table
    sizes for (4,8): 96 one-to-one entries and 14 step entries.
  * ``hard_silu`` / ``hard_gelu`` extend C2 beyond the paper to the GLU
    activations of the assigned LM architectures (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixed_point import (
    FixedPointConfig,
    quantize,
    saturate,
    trunc_shift_right,
)

Array = jax.Array

HARDSIGMOID_METHODS = ("arithmetic", "1to1", "step")


# ---------------------------------------------------------------------------
# Float domain
# ---------------------------------------------------------------------------

def hard_tanh(x: Array, min_val: float = -1.0, max_val: float = 1.0) -> Array:
    return jnp.clip(x, min_val, max_val)


def hard_sigmoid(x: Array) -> Array:
    """PyTorch HardSigmoid: relu6(x + 3) / 6 == clip(x/6 + 1/2, 0, 1)."""
    return jnp.clip(x / 6.0 + 0.5, 0.0, 1.0)


def hard_sigmoid_star(x: Array, slope: float = 0.125, bound: float = 3.0) -> Array:
    """The paper's HardSigmoid*: configurable slope, saturation at ±bound.

    Linear region is ``[-bound, bound)`` (half-open; see module docstring).
    Note the (intentional, paper-faithful) small jumps at the bounds when
    slope != 1/(2*bound).
    """
    lin = x * slope + 0.5
    return jnp.where(x < -bound, 0.0, jnp.where(x >= bound, 1.0, lin))


def hard_silu(x: Array) -> Array:
    """HardSwish: x * HardSigmoid(x) — drop-in hard replacement for SiLU."""
    return x * hard_sigmoid(x)


def hard_gelu(x: Array) -> Array:
    """Hard approximation of GELU: x * HardSigmoid(1.702 * x).

    (The sigmoid-form GELU approximation with the sigmoid hardened.)"""
    return x * hard_sigmoid(1.702 * x)


def get_float_act(name: str):
    return {
        "sigmoid": jax.nn.sigmoid,
        "tanh": jnp.tanh,
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": functools.partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "hard_tanh": hard_tanh,
        "hard_sigmoid": hard_sigmoid,
        "hard_sigmoid_star": hard_sigmoid_star,
        "hard_silu": hard_silu,
        "hard_gelu": hard_gelu,
    }[name]


HARD_VARIANT = {  # soft activation -> its hard replacement (C2 beyond-paper)
    "sigmoid": "hard_sigmoid_star",
    "tanh": "hard_tanh",
    "silu": "hard_silu",
    "gelu": "hard_gelu",
    "gelu_tanh": "hard_gelu",
    "relu": "relu",
    "relu2": "relu2",
}


# ---------------------------------------------------------------------------
# Integer domain — HardSigmoid* (three methods, bit-identical)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HardSigmoidStarSpec:
    """Fixed-point HardSigmoid* specification.

    slope = 2**-slope_shift (the bit-shiftable slope of §4.2; slope_shift=3
    gives the paper's 0.125).  bound = saturation threshold (paper: 3.0).
    """

    cfg: FixedPointConfig
    slope_shift: int = 3
    bound: float = 3.0

    @property
    def bound_int(self) -> int:
        return int(round(self.bound * (1 << self.cfg.frac_bits)))

    @property
    def half_int(self) -> int:  # 0.5 in (a,b)
        return 1 << (self.cfg.frac_bits - 1)

    @property
    def one_int(self) -> int:  # 1.0 in (a,b)
        return 1 << self.cfg.frac_bits


def hs_star_int_arithmetic(x_int: Array, spec: HardSigmoidStarSpec) -> Array:
    """``arithmetic`` method: truncating shift + add, then saturation selects.

    The linear segment is clamped to [0, 1] so configurations whose
    slope*bound exceeds 0.5 stay monotone (hardware output saturation)."""
    x_int = x_int.astype(jnp.int32)
    lin = trunc_shift_right(x_int, spec.slope_shift) + spec.half_int
    lin = jnp.clip(lin, 0, spec.one_int)
    y = jnp.where(x_int < -spec.bound_int, 0,
                  jnp.where(x_int >= spec.bound_int, spec.one_int, lin))
    return saturate(y, spec.cfg)


@functools.lru_cache(maxsize=None)
def _full_table_np(spec: HardSigmoidStarSpec) -> np.ndarray:
    """Output code for every representable input code (host-side, cached)."""
    xs = np.arange(spec.cfg.int_min, spec.cfg.int_max + 1, dtype=np.int32)
    lin = np.clip((xs >> spec.slope_shift) + spec.half_int, 0, spec.one_int)
    y = np.where(xs < -spec.bound_int, 0,
                 np.where(xs >= spec.bound_int, spec.one_int, lin))
    return np.clip(y, spec.cfg.int_min, spec.cfg.int_max).astype(np.int32)


def one_to_one_table(spec: HardSigmoidStarSpec) -> np.ndarray:
    """The ``1to1`` LUT over all 2**b inputs (saturated regions folded in)."""
    return _full_table_np(spec)


def num_1to1_entries(spec: HardSigmoidStarSpec) -> int:
    """Number of *non-trivial* LUT entries the FPGA must store (the linear
    region); the paper reports 96 for (4,8)."""
    return 2 * spec.bound_int  # inputs in [-bound, bound)


def step_table(spec: HardSigmoidStarSpec) -> Tuple[np.ndarray, np.ndarray]:
    """The ``step`` method's merged table.

    Returns (thresholds, outputs): ``y(x) = outputs[sum(x >= thresholds)]``.
    len(outputs) is the paper's "entry count" — 14 for (4,8).
    """
    table = _full_table_np(spec)
    xs = np.arange(spec.cfg.int_min, spec.cfg.int_max + 1, dtype=np.int32)
    change = np.nonzero(np.diff(table))[0] + 1  # indices where output changes
    thresholds = xs[change]
    outputs = np.concatenate([table[:1], table[change]])
    return thresholds.astype(np.int32), outputs.astype(np.int32)


def num_step_entries(spec: HardSigmoidStarSpec) -> int:
    _, outputs = step_table(spec)
    return len(outputs)


def hs_star_int_1to1(x_int: Array, spec: HardSigmoidStarSpec) -> Array:
    table = jnp.asarray(one_to_one_table(spec))
    idx = (x_int.astype(jnp.int32) - spec.cfg.int_min).astype(jnp.int32)
    return jnp.take(table, idx, axis=0)


def hs_star_int_step(x_int: Array, spec: HardSigmoidStarSpec) -> Array:
    thresholds, outputs = step_table(spec)
    thresholds = jnp.asarray(thresholds)
    outputs = jnp.asarray(outputs)
    x = x_int.astype(jnp.int32)
    # sum of comparators == the FPGA's cascaded-comparator mux.
    idx = jnp.sum(x[..., None] >= thresholds, axis=-1)
    return jnp.take(outputs, idx, axis=0)


def hs_star_int_step_unrolled(x_int: Array, spec: HardSigmoidStarSpec) -> Array:
    """``step`` method as a compile-time-unrolled comparator cascade.

    Bit-identical to :func:`hs_star_int_step` (same ``step_table``), but
    gather-free — the form the Pallas TPU kernel uses, where a LUT gather
    doesn't vectorise but a handful of compare+adds does (exactly the
    FPGA's cascaded-comparator structure)."""
    thresholds, outputs = step_table(spec)
    x = x_int.astype(jnp.int32)
    y = jnp.full_like(x, int(outputs[0]))
    for thr, prev, nxt in zip(thresholds, outputs[:-1], outputs[1:]):
        y = y + jnp.where(x >= int(thr), int(nxt) - int(prev), 0)
    return y


def hs_star_int(x_int: Array, spec: HardSigmoidStarSpec, method: str = "arithmetic") -> Array:
    if method == "arithmetic":
        return hs_star_int_arithmetic(x_int, spec)
    if method == "1to1":
        return hs_star_int_1to1(x_int, spec)
    if method == "step":
        return hs_star_int_step(x_int, spec)
    raise ValueError(f"unknown HardSigmoid* method {method!r}; "
                     f"expected one of {HARDSIGMOID_METHODS}")


# ---------------------------------------------------------------------------
# Integer domain — HardTanh
# ---------------------------------------------------------------------------

def hard_tanh_int(x_int: Array, cfg: FixedPointConfig,
                  min_val: float = -1.0, max_val: float = 1.0) -> Array:
    """Two fixed-point comparators (5 LUTs on the FPGA; 2 selects on the VPU)."""
    # Host-side threshold computation (round half up, saturate) so this is
    # trace-safe under jit/scan.
    def _q(v: float) -> int:
        code = int(np.floor(v * (1 << cfg.frac_bits) + 0.5))
        return int(np.clip(code, cfg.int_min, cfg.int_max))

    return jnp.clip(x_int.astype(jnp.int32), _q(min_val), _q(max_val))


# ---------------------------------------------------------------------------
# Integer domain — baseline [15]: 256-entry LUT Sigmoid / Tanh
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _lut_act_table_np(kind: str, cfg: FixedPointConfig) -> np.ndarray:
    xs = np.arange(cfg.int_min, cfg.int_max + 1, dtype=np.int32)
    xf = xs.astype(np.float64) * cfg.scale
    if kind == "sigmoid":
        yf = 1.0 / (1.0 + np.exp(-xf))
    elif kind == "tanh":
        yf = np.tanh(xf)
    else:
        raise ValueError(kind)
    y = np.floor(yf * (1 << cfg.frac_bits) + 0.5).astype(np.int32)
    return np.clip(y, cfg.int_min, cfg.int_max)


def lut_sigmoid_int(x_int: Array, cfg: FixedPointConfig) -> Array:
    """Baseline [15]: full-table sigmoid (2**b entries; 256 for b=8)."""
    table = jnp.asarray(_lut_act_table_np("sigmoid", cfg))
    return jnp.take(table, x_int.astype(jnp.int32) - cfg.int_min, axis=0)


def lut_tanh_int(x_int: Array, cfg: FixedPointConfig) -> Array:
    table = jnp.asarray(_lut_act_table_np("tanh", cfg))
    return jnp.take(table, x_int.astype(jnp.int32) - cfg.int_min, axis=0)
