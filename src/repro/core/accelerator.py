"""Parameterised accelerator configuration — the paper's Table 2 (C4),
re-targeted from Spartan-7 resources to the TPU v5e execution model.

FPGA meta-parameter            ->  TPU meta-parameter
  ALU_resource_type DSP|LUT    ->  compute_unit      mxu|vpu
  weight_resource_type
      BRAM|LUTRAM|AUTO         ->  weight_memory     vmem|hbm|auto
  HardSigmoid*_method          ->  hs_method         arithmetic|1to1|step
  HardTanh_threshold           ->  ht_min/ht_max
  ALU pipelining (C3)          ->  alu_mode          pipelined|per_step
  fixed-point format (a,b)     ->  fxp
  hidden_size / input_size /
  in_features / out_features   ->  (unchanged; QLSTMConfig)

``AcceleratorConfig`` is the SINGLE SOURCE OF TRUTH for the implementation
knobs (``hs_method``, ``ht_min``/``ht_max``, ``fxp``, ``alu_mode``,
``backend``).  ``QLSTMConfig``/``ActivationConfig`` retain mirror fields
for one deprecation release; ``resolve_model()`` merges the two, honouring
old-style model-side settings with a ``DeprecationWarning`` (see
docs/API.md for the deprecation table).

``plan()`` resolves AUTO decisions exactly like Vivado's BRAM->LUTRAM spill
in the paper's Fig. 4/5: weights live in VMEM while they fit the VMEM
budget, then spill to HBM streaming.  The plan selects the execution
backend (`repro/backends/`) and feeds the energy model (`core/energy.py`).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

from repro.core.fixed_point import FixedPointConfig, FXP_4_8
from repro.core.qlstm import ActivationConfig, QLSTMConfig

# TPU v5e per-core VMEM budget we allow the kernel to claim (bytes).  The
# physical VMEM is ~128 MiB; we keep headroom for pipeline buffers, like the
# paper keeps BRAM headroom for the dense layer.
VMEM_BUDGET_BYTES = 96 * 1024 * 1024

ALU_MODES = ("pipelined", "per_step")
HS_METHODS = ("arithmetic", "1to1", "step")
BACKENDS = ("auto", "ref", "pallas", "xla")


@dataclasses.dataclass(frozen=True, init=False)
class AcceleratorConfig:
    """Table-2 implementation meta-parameters (TPU form).

    ``backend`` picks the execution engine behind ``Accelerator.infer``:
    ``auto`` (plan-driven: fused Pallas kernel when the configuration
    supports it, else the XLA ``lax.scan`` datapath), or an explicit
    ``ref`` | ``pallas`` | ``xla`` override.

    ``pipelined_alu`` is accepted as a deprecated constructor alias for
    ``alu_mode`` (True -> pipelined, False -> per_step) and readable as a
    derived property; it is NOT a field, so ``dataclasses.replace`` round-
    trips cleanly through ``alu_mode`` alone.
    """

    compute_unit: str = "mxu"       # mxu (DSP) | vpu (LUT)
    weight_memory: str = "auto"     # vmem (BRAM) | hbm (LUTRAM) | auto
    hs_method: str = "step"         # arithmetic | 1to1 | step
    ht_min: float = -1.0
    ht_max: float = 1.0
    alu_mode: str = "pipelined"     # C3: pipelined (late rounding) | per_step
    fxp: FixedPointConfig = FXP_4_8
    vmem_budget: int = VMEM_BUDGET_BYTES
    backend: str = "auto"           # auto | ref | pallas | xla

    def __init__(self, compute_unit: str = "mxu", weight_memory: str = "auto",
                 hs_method: str = "step", ht_min: float = -1.0,
                 ht_max: float = 1.0, alu_mode: str = "pipelined",
                 fxp: FixedPointConfig = FXP_4_8,
                 vmem_budget: int = VMEM_BUDGET_BYTES, backend: str = "auto",
                 pipelined_alu: Optional[bool] = None):
        if pipelined_alu is not None:
            warnings.warn(
                "AcceleratorConfig(pipelined_alu=...) is deprecated; use "
                "alu_mode='pipelined'|'per_step'", DeprecationWarning,
                stacklevel=2)
            alu_mode = "pipelined" if pipelined_alu else "per_step"
        if compute_unit not in ("mxu", "vpu"):
            raise ValueError(f"compute_unit must be mxu|vpu, got {compute_unit}")
        if weight_memory not in ("vmem", "hbm", "auto"):
            raise ValueError("weight_memory must be vmem|hbm|auto")
        if hs_method not in HS_METHODS:
            raise ValueError(f"hs_method must be one of {HS_METHODS}")
        if alu_mode not in ALU_MODES:
            raise ValueError(f"alu_mode must be one of {ALU_MODES}")
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        for name, val in (("compute_unit", compute_unit),
                          ("weight_memory", weight_memory),
                          ("hs_method", hs_method), ("ht_min", ht_min),
                          ("ht_max", ht_max), ("alu_mode", alu_mode),
                          ("fxp", fxp), ("vmem_budget", vmem_budget),
                          ("backend", backend)):
            object.__setattr__(self, name, val)

    @property
    def pipelined_alu(self) -> bool:
        """Deprecated read alias: ``alu_mode == 'pipelined'``."""
        return self.alu_mode == "pipelined"


PAPER_DEFAULT = AcceleratorConfig()                      # "this work", col 5 of T4
PAPER_NO_MXU = AcceleratorConfig(compute_unit="vpu")     # DSP-free variant, col 6
BASELINE_15 = AcceleratorConfig(                         # Qian et al. [15]
    compute_unit="mxu", hs_method="1to1", alu_mode="per_step",
    fxp=FixedPointConfig(8, 16))


# ---------------------------------------------------------------------------
# Config unification: AcceleratorConfig is the source of truth
# ---------------------------------------------------------------------------

# (field on AcceleratorConfig, owner of the legacy mirror, legacy field name)
_MOVED_KNOBS = (
    ("fxp", "model", "fxp"),
    ("alu_mode", "model", "alu_mode"),
    ("hs_method", "acts", "hs_method"),
    ("ht_min", "acts", "ht_min"),
    ("ht_max", "acts", "ht_max"),
)


def _default_of(cls, field_name):
    for f in dataclasses.fields(cls):
        if f.name == field_name:
            if f.default is not dataclasses.MISSING:
                return f.default
            return f.default_factory()  # pragma: no cover
    raise KeyError(field_name)


def resolve_model(model: QLSTMConfig, accel: AcceleratorConfig,
                  warn: bool = True) -> QLSTMConfig:
    """Merge legacy model-side knobs into one consistent ``QLSTMConfig``.

    For each knob that moved to ``AcceleratorConfig`` (fxp, alu_mode,
    hs_method, ht_min/ht_max):

      * accelerator set (non-default)            -> accelerator wins,
      * only the legacy model/acts field set     -> it wins, with a
        ``DeprecationWarning`` (the one-release compatibility shim),
      * both set and disagreeing                 -> accelerator wins, with a
        warning naming the conflict.

    The returned config is what the datapaths (`forward_*`, backends,
    kernels) actually run."""
    resolved: Dict[str, object] = {}
    for acc_field, owner, legacy in _MOVED_KNOBS:
        acc_val = getattr(accel, acc_field)
        acc_dflt = _default_of(AcceleratorConfig, acc_field)
        if owner == "model":
            old_val = getattr(model, legacy)
            old_dflt = _default_of(QLSTMConfig, legacy)
        else:
            old_val = getattr(model.acts, legacy)
            old_dflt = _default_of(ActivationConfig, legacy)
        if acc_val == acc_dflt and old_val != old_dflt:
            if warn:
                warnings.warn(
                    f"setting {legacy!r} on "
                    f"{'QLSTMConfig' if owner == 'model' else 'ActivationConfig'}"
                    f" is deprecated; set AcceleratorConfig.{acc_field} "
                    f"instead (honouring the legacy value {old_val!r} for "
                    f"this release)", DeprecationWarning, stacklevel=3)
            resolved[acc_field] = old_val
        else:
            if (warn and acc_val != acc_dflt and old_val != old_dflt
                    and old_val != acc_val):
                warnings.warn(
                    f"{legacy!r} set to {old_val!r} on the model config but "
                    f"{acc_val!r} on AcceleratorConfig; the accelerator "
                    f"value wins", DeprecationWarning, stacklevel=3)
            resolved[acc_field] = acc_val

    acts = dataclasses.replace(model.acts, hs_method=resolved["hs_method"],
                               ht_min=resolved["ht_min"],
                               ht_max=resolved["ht_max"])
    return dataclasses.replace(model, acts=acts, fxp=resolved["fxp"],
                               alu_mode=resolved["alu_mode"])


def sync_accelerator(model: QLSTMConfig,
                     accel: AcceleratorConfig) -> AcceleratorConfig:
    """The inverse direction: an AcceleratorConfig whose moved knobs match a
    (possibly legacy-style) resolved model — what plan()/the energy model
    should score."""
    m = resolve_model(model, accel, warn=False)
    return dataclasses.replace(accel, fxp=m.fxp, alu_mode=m.alu_mode,
                               hs_method=m.acts.hs_method,
                               ht_min=m.acts.ht_min, ht_max=m.acts.ht_max)


def weight_bytes(model: QLSTMConfig, acc: AcceleratorConfig) -> int:
    """Bytes of quantised weights+biases the accelerator must hold, for
    whatever cell ``model.cell`` names (dispatched through the
    ``repro.cells`` registry)."""
    # Lazy import: repro.cells -> cells.lstm -> repro.kernels -> this module.
    from repro import cells
    return cells.get(model.cell).weight_bytes(model, acc)


def lstm_weight_bytes(model: QLSTMConfig, acc: AcceleratorConfig) -> int:
    """Back-compat alias of :func:`weight_bytes` (pre-cell-registry name);
    still correct for every cell, not just LSTM."""
    return weight_bytes(model, acc)


def resolve_weight_memory(model: QLSTMConfig, acc: AcceleratorConfig) -> str:
    """AUTO spill decision (Fig 4/5 analogue)."""
    if acc.weight_memory != "auto":
        return acc.weight_memory
    return "vmem" if weight_bytes(model, acc) <= acc.vmem_budget else "hbm"


def resolve_backend(model: QLSTMConfig, acc: AcceleratorConfig) -> str:
    """Plan-driven backend choice (the explicit override passes through).

    A fused Pallas kernel is used when the model's cell HAS one
    (``CellSpec.supports_fused`` is set — today only the LSTM) and the
    configuration is the point it implements (the paper's pipelined ALU
    with the hard activations); anything else (per-step ALU baseline, LUT
    acts, GRU/rGLRU cells) runs on the XLA ``lax.scan`` datapath."""
    if acc.backend != "auto":
        return acc.backend
    from repro import cells  # lazy: repro.cells imports this module
    spec = cells.get(model.cell)
    fused_ok = (spec.supports_fused is not None
                and spec.supports_fused(model, acc) is None)
    return "pallas" if fused_ok else "xla"


def resolve_stateful_backend(model: QLSTMConfig,
                             acc: AcceleratorConfig) -> str:
    """Backend choice for the cross-window STATEFUL path (`repro.serving`).

    Identical to the stateless resolution: every registered engine —
    including the fused Pallas kernel, whose per-layer (h, c) VMEM scratch
    is seeded from the carried state and returned after the last step —
    implements ``run_stateful``, so the serving hot path runs on the same
    engine ``plan()['backend']`` picks (docs/API.md §Backends documents
    the full selection order).  Kept as its own resolution point so a
    future stateless-only engine can be substituted away here again;
    `backends.select_stateful` raises if an explicitly requested engine
    can't carry state."""
    return resolve_backend(model, acc)


def resolve_state_residency(model: QLSTMConfig,
                            acc: AcceleratorConfig) -> str:
    """Where the serving tier keeps per-stream carries (the cell's
    ``(state_arity, hidden)`` rows per layer): ``device`` | ``host``.

    The fused Pallas kernel owns an in-kernel slot gather/scatter path
    (``kernels/qlstm_cell.qlstm_seq_slot_pallas``), so when it is the
    resolved stateful engine the carry table lives in device memory and
    the host ships only slot ids per wave — the paper's state-next-to-
    compute residency argument.  Everything else defaults to the host-side
    LRU ``StateStore`` (``repro.serving.state``); an explicit
    ``ServingConfig(state_residency='device')`` can still force the
    device table onto ``ref``/``xla`` through their XLA-level slot
    adapters."""
    return ("device" if resolve_stateful_backend(model, acc) == "pallas"
            else "host")


def plan(model: QLSTMConfig, acc: AcceleratorConfig) -> Dict:
    """Resolve every implementation decision for (model, accelerator).

    Returned dict drives backend dispatch and the energy/footprint report —
    the TPU analogue of the paper's Vivado configuration point."""
    from repro import cells  # lazy: repro.cells imports this module
    model = resolve_model(model, acc, warn=False)
    acc = sync_accelerator(model, acc)
    wmem = resolve_weight_memory(model, acc)
    wbytes = weight_bytes(model, acc)
    return {
        # Which recurrent cell the datapath runs, and the per-stream carry
        # shape (num_layers, state_arity, hidden) its spec declares —
        # serving keys every state table on this, never on a hardcoded
        # LSTM (L, 2, H).
        "cell": model.cell,
        "state_shape": cells.state_shape(model),
        "compute_unit": acc.compute_unit,
        "weight_memory": wmem,
        "weight_bytes": wbytes,
        "vmem_resident": wmem == "vmem",
        "hs_method": acc.hs_method,
        "pipelined_alu": acc.alu_mode == "pipelined",
        "alu_mode": acc.alu_mode,
        "fxp": acc.fxp,
        "backend": resolve_backend(model, acc),
        # The engine repro.serving uses for cross-window (h, c) carry —
        # currently always equal to "backend" (every engine is stateful;
        # see resolve_stateful_backend), kept as its own key so serving
        # code has one stable place to ask.
        "stateful_backend": resolve_stateful_backend(model, acc),
        # Where serving keeps per-stream carries: "device" (slot table on
        # the accelerator, in-kernel gather/scatter) when the fused pallas
        # kernel serves the stateful path, else "host" (the LRU StateStore).
        "state_residency": resolve_state_residency(model, acc),
        # MXU tiles are 128x128: tiny LSTMs under-fill them, exactly like
        # tiny models under-fill DSP columns.  Report the padding waste.
        "mxu_fill_fraction": _mxu_fill(model) if acc.compute_unit == "mxu" else None,
    }


def _mxu_fill(model: QLSTMConfig) -> float:
    m, h = model.layer_in_dim(0), model.hidden_size
    k, n = m + h, 4 * h
    pad = lambda d: -(-d // 128) * 128
    return (k * n) / (pad(k) * pad(n))
