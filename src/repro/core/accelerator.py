"""Parameterised accelerator configuration — the paper's Table 2 (C4),
re-targeted from Spartan-7 resources to the TPU v5e execution model.

FPGA meta-parameter            ->  TPU meta-parameter
  ALU_resource_type DSP|LUT    ->  compute_unit      mxu|vpu
  weight_resource_type
      BRAM|LUTRAM|AUTO         ->  weight_memory     vmem|hbm|auto
  HardSigmoid*_method          ->  hs_method         arithmetic|1to1|step
  HardTanh_threshold           ->  ht_min/ht_max
  hidden_size / input_size /
  in_features / out_features   ->  (unchanged; QLSTMConfig)

``plan()`` resolves AUTO decisions exactly like Vivado's BRAM->LUTRAM spill
in the paper's Fig. 4/5: weights live in VMEM while they fit the VMEM
budget, then spill to HBM streaming.  The plan feeds the Pallas kernel
(`kernels/qlstm_cell.py`) and the energy model (`core/energy.py`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.fixed_point import FixedPointConfig, FXP_4_8
from repro.core.qlstm import QLSTMConfig

# TPU v5e per-core VMEM budget we allow the kernel to claim (bytes).  The
# physical VMEM is ~128 MiB; we keep headroom for pipeline buffers, like the
# paper keeps BRAM headroom for the dense layer.
VMEM_BUDGET_BYTES = 96 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Table-2 implementation meta-parameters (TPU form)."""

    compute_unit: str = "mxu"       # mxu (DSP) | vpu (LUT)
    weight_memory: str = "auto"     # vmem (BRAM) | hbm (LUTRAM) | auto
    hs_method: str = "step"         # arithmetic | 1to1 | step
    ht_min: float = -1.0
    ht_max: float = 1.0
    pipelined_alu: bool = True      # C3: late-rounding pipelined MAC
    fxp: FixedPointConfig = FXP_4_8
    vmem_budget: int = VMEM_BUDGET_BYTES

    def __post_init__(self):
        if self.compute_unit not in ("mxu", "vpu"):
            raise ValueError(f"compute_unit must be mxu|vpu, got {self.compute_unit}")
        if self.weight_memory not in ("vmem", "hbm", "auto"):
            raise ValueError(f"weight_memory must be vmem|hbm|auto")


PAPER_DEFAULT = AcceleratorConfig()                      # "this work", col 5 of T4
PAPER_NO_MXU = AcceleratorConfig(compute_unit="vpu")     # DSP-free variant, col 6
BASELINE_15 = AcceleratorConfig(                         # Qian et al. [15]
    compute_unit="mxu", hs_method="1to1", pipelined_alu=False,
    fxp=FixedPointConfig(8, 16))


def lstm_weight_bytes(model: QLSTMConfig, acc: AcceleratorConfig) -> int:
    """Bytes of quantised weights+biases the accelerator must hold."""
    itemsize = (acc.fxp.total_bits + 7) // 8
    wide_itemsize = 2 * itemsize
    total = 0
    for li in range(model.num_layers):
        m, h = model.layer_in_dim(li), model.hidden_size
        total += (m + h) * 4 * h * itemsize + 4 * h * wide_itemsize
    total += model.hidden_size * model.out_features * itemsize
    total += model.out_features * wide_itemsize
    return total


def resolve_weight_memory(model: QLSTMConfig, acc: AcceleratorConfig) -> str:
    """AUTO spill decision (Fig 4/5 analogue)."""
    if acc.weight_memory != "auto":
        return acc.weight_memory
    return "vmem" if lstm_weight_bytes(model, acc) <= acc.vmem_budget else "hbm"


def plan(model: QLSTMConfig, acc: AcceleratorConfig) -> Dict:
    """Resolve every implementation decision for (model, accelerator).

    Returned dict drives kernel dispatch and the energy/footprint report —
    the TPU analogue of the paper's Vivado configuration point."""
    wmem = resolve_weight_memory(model, acc)
    wbytes = lstm_weight_bytes(model, acc)
    return {
        "compute_unit": acc.compute_unit,
        "weight_memory": wmem,
        "weight_bytes": wbytes,
        "vmem_resident": wmem == "vmem",
        "hs_method": acc.hs_method,
        "pipelined_alu": acc.pipelined_alu,
        "alu_mode": "pipelined" if acc.pipelined_alu else "per_step",
        "fxp": acc.fxp,
        # MXU tiles are 128x128: tiny LSTMs under-fill them, exactly like
        # tiny models under-fill DSP columns.  Report the padding waste.
        "mxu_fill_fraction": _mxu_fill(model) if acc.compute_unit == "mxu" else None,
    }


def _mxu_fill(model: QLSTMConfig) -> float:
    m, h = model.layer_in_dim(0), model.hidden_size
    k, n = m + h, 4 * h
    pad = lambda d: -(-d // 128) * 128
    return (k * n) / (pad(k) * pad(n))
