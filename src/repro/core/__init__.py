# The paper's primary contribution — the parameterised quantised-execution
# core: fixed-point datapath (C1), hard activations (C2), pipelined-ALU
# semantics (C3), accelerator meta-parameters (C4), energy model (C5).
from repro.core.fixed_point import (  # noqa: F401
    FixedPointConfig, FXP_4_8, FXP_6_8, FXP_8_10, FXP_8_16,
    quantize, dequantize, fake_quant, requantize,
)
from repro.core.hard_act import (  # noqa: F401
    hard_tanh, hard_sigmoid, hard_sigmoid_star, hard_silu, hard_gelu,
    HardSigmoidStarSpec, hs_star_int, HARDSIGMOID_METHODS,
)
from repro.core.quant import QuantConfig, QTensor, NO_QUANT, W8, W8A8  # noqa: F401
from repro.core.qlstm import (  # noqa: F401
    QLSTMConfig, ActivationConfig, PAPER_ACTS, BASELINE_ACTS, FLOAT_ACTS,
    init_params, quantize_params, forward_float, forward_qat, forward_int,
    ops_per_inference,
)
from repro.core.accelerator import (  # noqa: F401
    AcceleratorConfig, PAPER_DEFAULT, PAPER_NO_MXU, BASELINE_15, plan,
)
