"""The paper's primary contribution: the parameterised quantised-execution
core.

  * ``fixed_point``  — C1: the (a, b) fixed-point datapath.  Bit-exact
    integer simulation (round-half-up ``f_round``, truncating slope shift,
    saturating adds) shared by the oracle, the Pallas kernels, and QAT.
  * ``hard_act``     — C2: HardSigmoid* (three bit-identical integer
    methods: arithmetic / 1to1 / step, plus the Pallas-safe unrolled step
    cascade) and HardTanh, with the baseline's 256-entry LUT activations.
  * ``qlstm``        — the model and its three datapaths: ``forward_float``
    (training), ``forward_qat`` (STE fake-quant at every hardware rounding
    point), ``forward_int`` (bit-exact integer oracle; pipelined C3 or
    per-step baseline ALU).
  * ``accelerator``  — C4: Table-2 implementation meta-parameters
    (``AcceleratorConfig`` — the single source of truth for ``fxp``,
    ``alu_mode``, ``hs_method``, ``ht_min``/``ht_max``, ``backend``),
    ``resolve_model`` (the one-release deprecation shim for the legacy
    model-side mirrors), and ``plan()`` (VMEM/HBM residency, MXU/VPU
    dispatch, backend selection).
  * ``energy``       — C5: the TPU-v5e power/energy model behind
    ``Accelerator.report()`` (Table-4 structure: static/dynamic split,
    GOP/s, GOP/s/W).

Lifecycle on top of this core (see docs/API.md): ``repro.build(model,
accel)`` -> ``train_qat`` -> ``quantize`` -> ``infer``/``serve``/``report``,
with execution engines in ``repro/backends/`` (``ref`` | ``pallas`` |
``xla``) selected by ``plan()``.
"""
from repro.core.fixed_point import (  # noqa: F401
    FixedPointConfig, FXP_4_8, FXP_6_8, FXP_8_10, FXP_8_16,
    quantize, dequantize, fake_quant, requantize,
)
from repro.core.hard_act import (  # noqa: F401
    hard_tanh, hard_sigmoid, hard_sigmoid_star, hard_silu, hard_gelu,
    HardSigmoidStarSpec, hs_star_int, HARDSIGMOID_METHODS,
)
from repro.core.quant import QuantConfig, QTensor, NO_QUANT, W8, W8A8  # noqa: F401
from repro.core.qlstm import (  # noqa: F401
    QLSTMConfig, ActivationConfig, PAPER_ACTS, BASELINE_ACTS, FLOAT_ACTS,
    init_params, quantize_params, forward_float, forward_qat, forward_int,
    ops_per_inference,
)
from repro.core.accelerator import (  # noqa: F401
    AcceleratorConfig, PAPER_DEFAULT, PAPER_NO_MXU, BASELINE_15,
    plan, resolve_model, sync_accelerator, resolve_backend,
)
