"""Fixed-point arithmetic — the paper's (a, b) quantisation datapath (contribution C1).

Paper notation: ``(a, b)`` = ``a`` fractional bits out of ``b`` total bits.
The standard configuration is ``(4, 8)``; the baseline [15] used ``(8, 16)``.

Everything here simulates the FPGA integer datapath bit-exactly in int32
carriers (hardware width is enforced by saturation), so that

  * the pure-jnp reference (``kernels/ref.py``),
  * the Pallas TPU kernels (``kernels/qlstm_cell.py`` etc.), and
  * the QAT fake-quant graph (``training/qat.py``)

all agree to the last bit / last LSB.

Rounding conventions (documented because they are part of the paper's
hardware semantics):

  * ``f_round`` (Algorithm 1 line 5 / pipeline stage S5): *round half up*
    — ``(v + 2**(s-1)) >> s`` with arithmetic shift — the cheap FPGA rounder.
  * The HardSigmoid* slope division (``x / 8``) uses a *plain arithmetic
    right shift* (truncation toward −∞).  This choice is what reproduces the
    paper's own table sizes: 96 one-to-one LUT entries and 14 step entries
    for the (4, 8) configuration (see ``core/hard_act.py`` and
    ``tests/test_hard_act.py::test_paper_table_entry_counts``).
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

Array = jax.Array
ArrayLike = Union[Array, float, int]


@dataclasses.dataclass(frozen=True)
class FixedPointConfig:
    """The paper's ``(a, b)`` fixed-point format.

    Attributes:
      frac_bits:  ``a`` — number of fractional bits.
      total_bits: ``b`` — total width in bits (including sign).
      signed:     two's-complement when True.
    """

    frac_bits: int
    total_bits: int
    signed: bool = True

    def __post_init__(self):
        if self.total_bits < 2 or self.total_bits > 31:
            raise ValueError(f"total_bits must be in [2, 31], got {self.total_bits}")
        if self.frac_bits < 0 or self.frac_bits > self.total_bits:
            raise ValueError(f"frac_bits must be in [0, total_bits]")

    # --- integer range -----------------------------------------------------
    @property
    def int_min(self) -> int:
        return -(1 << (self.total_bits - 1)) if self.signed else 0

    @property
    def int_max(self) -> int:
        return (1 << (self.total_bits - 1)) - 1 if self.signed else (1 << self.total_bits) - 1

    @property
    def scale(self) -> float:
        """Value of one LSB: 2**-a."""
        return 2.0 ** (-self.frac_bits)

    @property
    def min_value(self) -> float:
        return self.int_min * self.scale

    @property
    def max_value(self) -> float:
        return self.int_max * self.scale

    @property
    def num_values(self) -> int:
        return 1 << self.total_bits

    # --- dtype selection ---------------------------------------------------
    @property
    def storage_dtype(self):
        """Narrowest native dtype that stores the integer code."""
        if self.total_bits <= 8:
            return jnp.int8
        if self.total_bits <= 16:
            return jnp.int16
        return jnp.int32

    def __str__(self) -> str:  # paper's "(a,b)" notation
        return f"({self.frac_bits},{self.total_bits})"


# Canonical configurations used throughout the paper.
FXP_4_8 = FixedPointConfig(4, 8)       # this work's standard
FXP_6_8 = FixedPointConfig(6, 8)       # Table 1 variant
FXP_8_10 = FixedPointConfig(8, 10)     # Table 1 variant
FXP_8_16 = FixedPointConfig(8, 16)     # baseline [15]
FXP_8_16_ACC = FixedPointConfig(8, 16)  # product/accumulator format of (4,8)x(4,8)
FXP_8_32_ACC = FixedPointConfig(8, 32 - 1)  # wide TPU accumulator (int32 carrier)


# ---------------------------------------------------------------------------
# Integer-domain primitives (bit-exact hardware semantics)
# ---------------------------------------------------------------------------

def saturate(v: Array, cfg: FixedPointConfig) -> Array:
    """Clamp an int32 carrier to the cfg's representable integer range."""
    return jnp.clip(v, cfg.int_min, cfg.int_max)


def round_shift_right(v: Array, shift: int) -> Array:
    """Round-half-up arithmetic right shift: the paper's ``f_round`` core.

    ``(v + 2**(shift-1)) >> shift``.  For shift == 0 it is the identity.
    """
    if shift == 0:
        return v
    return (v + (1 << (shift - 1))) >> shift


def trunc_shift_right(v: Array, shift: int) -> Array:
    """Plain arithmetic right shift (truncation toward −∞)."""
    if shift == 0:
        return v
    return v >> shift


def requantize(v: Array, src: FixedPointConfig, dst: FixedPointConfig,
               rounding: str = "half_up") -> Array:
    """f_round: convert integer codes between fixed-point formats.

    E.g. the paper's ``mul16 (8,16) -> mul8 (4,8)`` is
    ``requantize(v, FXP_8_16, FXP_4_8)``.
    """
    shift = src.frac_bits - dst.frac_bits
    if shift < 0:
        v = v << (-shift)
    elif rounding == "half_up":
        v = round_shift_right(v, shift)
    elif rounding == "trunc":
        v = trunc_shift_right(v, shift)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    return saturate(v, dst)


# ---------------------------------------------------------------------------
# Float <-> fixed-point conversion
# ---------------------------------------------------------------------------

def quantize(x: ArrayLike, cfg: FixedPointConfig, rounding: str = "half_up") -> Array:
    """Float -> integer code (int32 carrier), saturating."""
    x = jnp.asarray(x, jnp.float32)
    scaled = x * (1 << cfg.frac_bits)
    if rounding == "half_up":
        v = jnp.floor(scaled + 0.5)
    elif rounding == "nearest_even":
        v = jnp.round(scaled)
    elif rounding == "trunc":
        v = jnp.trunc(scaled)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    return saturate(v.astype(jnp.int32), cfg)


def dequantize(v: Array, cfg: FixedPointConfig) -> Array:
    """Integer code -> float."""
    return v.astype(jnp.float32) * cfg.scale


def quantize_to_storage(x: ArrayLike, cfg: FixedPointConfig) -> Array:
    """Float -> integer code in the narrowest native dtype (int8/int16/int32)."""
    return quantize(x, cfg).astype(cfg.storage_dtype)


def fake_quant(x: Array, cfg: FixedPointConfig) -> Array:
    """Straight-through-estimator fake quantisation (QAT building block).

    Forward: dequantize(quantize(x)); backward: identity inside the
    representable range (gradients pass through; saturation clips them the
    same way the forward clips values).
    """
    q = dequantize(quantize(x, cfg), cfg)
    # Clip the STE pass-through so gradients vanish outside the range
    # (standard QAT practice; matches hardware saturation).
    xc = jnp.clip(x, cfg.min_value, cfg.max_value)
    return xc + jax.lax.stop_gradient(q - xc)


# ---------------------------------------------------------------------------
# Fixed-point multiply / MAC (Algorithm 1 semantics)
# ---------------------------------------------------------------------------

def product_config(a: FixedPointConfig, b: FixedPointConfig) -> FixedPointConfig:
    """Format of a full-precision product: fracs add, widths add.

    (4,8)x(4,8) -> (8,16), as in Algorithm 1 line 4."""
    return FixedPointConfig(a.frac_bits + b.frac_bits,
                            min(a.total_bits + b.total_bits, 31))


def fxp_mul(x: Array, w: Array, cfg_x: FixedPointConfig, cfg_w: FixedPointConfig) -> Array:
    """Integer product in the widened format (no rounding — exact)."""
    return x.astype(jnp.int32) * w.astype(jnp.int32)


def fxp_mac_per_step_rounding(x: Array, w: Array, cfg: FixedPointConfig) -> Array:
    """Algorithm 1 *as printed*: round every product back to (a,b) before
    accumulating.  This is the NON-pipelined baseline datapath.

    x: (..., N) int codes, w: (..., N) int codes -> (...,) accumulated code in
    cfg (saturating at each add, as a b-bit accumulator would).
    """
    prod_cfg = product_config(cfg, cfg)

    def body(acc, xw):
        xi, wi = xw
        m16 = fxp_mul(xi, wi, cfg, cfg)
        m8 = requantize(m16, prod_cfg, cfg)
        return saturate(acc + m8, cfg), None

    xs = jnp.moveaxis(x.astype(jnp.int32), -1, 0)
    ws = jnp.moveaxis(w.astype(jnp.int32), -1, 0)
    acc0 = jnp.zeros(jnp.broadcast_shapes(xs.shape[1:], ws.shape[1:]), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def fxp_mac_late_rounding(x: Array, w: Array, cfg: FixedPointConfig,
                          acc_bits: int = 32) -> Array:
    """The pipelined-ALU datapath (S1–S5): accumulate products at FULL width,
    round ONCE at the end (pipeline stage S5).  This is both faster in
    hardware and more accurate; it is also exactly what an MXU int8 matmul
    with an int32 accumulator computes, which is why the Pallas kernel can be
    bit-exact against this reference.

    Returns the accumulated code in ``cfg`` (rounded + saturated once).
    """
    prod_cfg = product_config(cfg, cfg)
    acc = jnp.sum(x.astype(jnp.int32) * w.astype(jnp.int32), axis=-1)
    if acc_bits < 32:
        wide = FixedPointConfig(prod_cfg.frac_bits, acc_bits)
        acc = saturate(acc, wide)
    return requantize(acc, prod_cfg, cfg)


def fxp_matvec_late_rounding(x: Array, w: Array, bias: Array,
                             cfg: FixedPointConfig) -> Array:
    """Integer matmul + bias with late rounding: ``round(x @ w + bias_wide)``.

    x: (..., K) codes in cfg; w: (K, N) codes in cfg;
    bias: (N,) codes in the *product* format (2a frac bits) so it adds into
    the wide accumulator before the single rounding — the hardware keeps the
    bias at accumulator precision.
    """
    prod_cfg = product_config(cfg, cfg)
    acc = jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    acc = acc + bias.astype(jnp.int32)
    return requantize(acc, prod_cfg, cfg)
