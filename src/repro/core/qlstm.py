"""The paper's model: quantised LSTM (+ dense head) with three datapaths.

  1. ``forward_float``   — float training/eval path; activation functions are
     selectable (exact Sigmoid/Tanh, the baseline's 256-entry LUT semantics,
     or the paper's HardSigmoid*/HardTanh).
  2. ``forward_qat``     — float path with straight-through fake-quant
     inserted at every point the hardware rounds (Quantisation-Aware
     Training, §6.1 of the paper).
  3. ``forward_int``     — bit-exact integer simulation of the accelerator
     datapath.  ``alu_mode="pipelined"`` is the paper's 5-stage ALU with
     LATE rounding (stage S5: accumulate wide, round once);
     ``alu_mode="per_step"`` is Algorithm 1 as printed (round every product
     back to (a,b) — the baseline [15] datapath).

``forward_int`` is the oracle the Pallas kernel
(`kernels/qlstm_cell.py`) must match bit-exactly.

Model structure (paper §3/§5.3): ``num_layers`` LSTM layers (hidden size K)
followed by one dense layer K -> P.  Gate order is [i, f, g, o].
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import hard_act
from repro.core.fixed_point import FixedPointConfig, FXP_4_8

Array = jax.Array
Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ActivationConfig:
    """Which activation implementations the cell uses (paper §4.2).

    ``hs_method`` / ``ht_min`` / ``ht_max`` are deprecated mirrors: the
    canonical home is ``AcceleratorConfig`` (see
    ``core.accelerator.resolve_model`` and docs/API.md); they are honoured
    here for one release."""

    gate: str = "hard_sigmoid_star"   # sigmoid | lut_sigmoid | hard_sigmoid_star
    cell: str = "hard_tanh"           # tanh | lut_tanh | hard_tanh
    hs_method: str = "step"           # DEPRECATED -> AcceleratorConfig.hs_method
    hs_slope_shift: int = 3           # slope = 2**-3 = 0.125
    hs_bound: float = 3.0
    ht_min: float = -1.0              # DEPRECATED -> AcceleratorConfig.ht_min
    ht_max: float = 1.0               # DEPRECATED -> AcceleratorConfig.ht_max

    def hs_spec(self, cfg: FixedPointConfig) -> hard_act.HardSigmoidStarSpec:
        return hard_act.HardSigmoidStarSpec(cfg, self.hs_slope_shift, self.hs_bound)


PAPER_ACTS = ActivationConfig()
BASELINE_ACTS = ActivationConfig(gate="lut_sigmoid", cell="lut_tanh")
FLOAT_ACTS = ActivationConfig(gate="sigmoid", cell="tanh")


@dataclasses.dataclass(frozen=True)
class QLSTMConfig:
    """The paper's Table-2 functional meta-parameters.

    ``fxp`` and ``alu_mode`` are deprecated mirrors of the canonical
    ``AcceleratorConfig`` fields, honoured for one release
    (``core.accelerator.resolve_model``; docs/API.md)."""

    input_size: int = 1           # M
    hidden_size: int = 20         # K
    num_layers: int = 1
    out_features: int = 1         # P
    seq_len: int = 6              # N (PeMS-4W window used by [15])
    acts: ActivationConfig = PAPER_ACTS
    fxp: FixedPointConfig = FXP_4_8   # DEPRECATED -> AcceleratorConfig.fxp
    alu_mode: str = "pipelined"   # DEPRECATED -> AcceleratorConfig.alu_mode
    # Which quantised recurrent cell the accelerator runs: any id in the
    # ``repro.cells`` registry ("lstm" | "gru" | "rglru").  The cell spec
    # owns the param tree, the state shape, and the datapaths; everything
    # downstream (backends, serving, explorer) is cell-agnostic.
    cell: str = "lstm"

    def layer_in_dim(self, layer: int) -> int:
        return self.input_size if layer == 0 else self.hidden_size


# ---------------------------------------------------------------------------
# Parameter init / quantisation
# ---------------------------------------------------------------------------

def init_params(cfg: QLSTMConfig, key: Array, dtype=jnp.float32) -> Params:
    layers = []
    for li in range(cfg.num_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        s = 1.0 / jnp.sqrt(h)
        b = jnp.zeros((4 * h,), dtype)
        # forget-gate bias init at 1.0 (standard LSTM practice)
        b = b.at[h:2 * h].set(1.0)
        layers.append({
            "w_x": jax.random.uniform(k1, (m, 4 * h), dtype, -s, s),
            "w_h": jax.random.uniform(k2, (h, 4 * h), dtype, -s, s),
            "b": b,
        })
    key, kd = jax.random.split(key)
    s = 1.0 / jnp.sqrt(cfg.hidden_size)
    dense = {
        "w": jax.random.uniform(kd, (cfg.hidden_size, cfg.out_features), dtype, -s, s),
        "b": jnp.zeros((cfg.out_features,), dtype),
    }
    return {"layers": layers, "dense": dense}


def quantize_params(params: Params, cfg: QLSTMConfig) -> Params:
    """Float master weights -> integer codes for the hardware datapath.

    Weights are stored in (a,b); biases at the wide PRODUCT format (2a frac
    bits) so they add into the accumulator before the single late rounding —
    exactly what the accelerator's bias registers hold."""
    c = cfg.fxp
    wide = fxp.product_config(c, c)

    def q_layer(p):
        return {
            "w_x": fxp.quantize(p["w_x"], c),
            "w_h": fxp.quantize(p["w_h"], c),
            "b": fxp.quantize(p["b"], wide),
        }

    return {
        "layers": [q_layer(p) for p in params["layers"]],
        "dense": {
            "w": fxp.quantize(params["dense"]["w"], c),
            "b": fxp.quantize(params["dense"]["b"], wide),
        },
    }


# ---------------------------------------------------------------------------
# Float / QAT forward
# ---------------------------------------------------------------------------

def _float_gate_act(acts: ActivationConfig, cfg: FixedPointConfig,
                    fq: bool = False):
    if acts.gate == "sigmoid":
        return jax.nn.sigmoid
    if acts.gate == "lut_sigmoid":
        # float semantics of the baseline LUT == exact sigmoid (the LUT is
        # its quantisation); QAT handles the rounding.
        return jax.nn.sigmoid
    if acts.gate == "hard_sigmoid_star":
        slope = 2.0 ** (-acts.hs_slope_shift)
        if not fq:
            return lambda x: hard_act.hard_sigmoid_star(x, slope, acts.hs_bound)

        # QAT: simulate the hardware's TRUNCATING shift (x_int >> k) with a
        # straight-through floor, so training sees the exact deployment
        # nonlinearity (the ElasticAI-Creator behaviour the paper trains
        # with).  y = (floor(x_int / 2^k) + half) * 2^-a.
        def tq_gate(x):
            sf = float(1 << cfg.frac_bits)
            x_int = x * sf  # fake_quant already snapped x to the grid
            lin_i = jnp.floor(x_int * slope)
            lin_i = x_int * slope + jax.lax.stop_gradient(lin_i - x_int * slope)
            y = (lin_i + (1 << (cfg.frac_bits - 1))) / sf
            return jnp.where(x < -acts.hs_bound, 0.0,
                             jnp.where(x >= acts.hs_bound, 1.0, y))

        return tq_gate
    raise ValueError(acts.gate)


def _float_cell_act(acts: ActivationConfig):
    if acts.cell in ("tanh", "lut_tanh"):
        return jnp.tanh
    if acts.cell == "hard_tanh":
        return lambda x: hard_act.hard_tanh(x, acts.ht_min, acts.ht_max)
    raise ValueError(acts.cell)


def _cell_step_float(p, x_t, h, c, cfg: QLSTMConfig, fq: bool):
    """One LSTM cell step.  fq=True inserts STE fake-quant at every hardware
    rounding point (QAT)."""
    fp = cfg.fxp
    q = (lambda t: fxp.fake_quant(t, fp)) if fq else (lambda t: t)
    gate = _float_gate_act(cfg.acts, fp, fq=fq)
    cellact = _float_cell_act(cfg.acts)

    w_x = q(p["w_x"])
    w_h = q(p["w_h"])
    pre = x_t @ w_x + h @ w_h + p["b"]
    pre = q(pre)  # the MAC's single late rounding (S5)
    h4 = cfg.hidden_size
    i, f, g, o = jnp.split(pre, 4, axis=-1)
    i, f, o = gate(i), gate(f), gate(o)
    g = cellact(g)
    if fq:
        i, f, g, o = map(q, (i, f, g, o))
    c_new = q(f * c + i * g)
    h_new = q(o * cellact(c_new))
    return h_new, c_new


def _forward(params: Params, x: Array, cfg: QLSTMConfig, fq: bool):
    """x: (batch, seq, input_size) -> (batch, out_features)."""
    b = x.shape[0]
    h_t = x
    for li, p in enumerate(params["layers"]):
        h0 = jnp.zeros((b, cfg.hidden_size), x.dtype)
        c0 = jnp.zeros((b, cfg.hidden_size), x.dtype)

        def step(carry, x_t, p=p):
            h, c = carry
            h, c = _cell_step_float(p, x_t, h, c, cfg, fq)
            return (h, c), h

        (h_last, _), hs = jax.lax.scan(step, (h0, c0), jnp.swapaxes(h_t, 0, 1))
        h_t = jnp.swapaxes(hs, 0, 1)
    q = (lambda t: fxp.fake_quant(t, cfg.fxp)) if fq else (lambda t: t)
    dw = q(params["dense"]["w"])
    y = h_last @ dw + params["dense"]["b"]
    return q(y)


def forward_float(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    return _forward(params, x, cfg, fq=False)


def forward_qat(params: Params, x: Array, cfg: QLSTMConfig) -> Array:
    return _forward(params, x, cfg, fq=True)


# ---------------------------------------------------------------------------
# Integer forward — the hardware oracle
# ---------------------------------------------------------------------------

def _int_gate_act(x_int, cfg: QLSTMConfig):
    fp = cfg.fxp
    if cfg.acts.gate == "hard_sigmoid_star":
        return hard_act.hs_star_int(x_int, cfg.acts.hs_spec(fp), cfg.acts.hs_method)
    if cfg.acts.gate in ("lut_sigmoid", "sigmoid"):
        return hard_act.lut_sigmoid_int(x_int, fp)
    raise ValueError(cfg.acts.gate)


def _int_cell_act(x_int, cfg: QLSTMConfig):
    fp = cfg.fxp
    if cfg.acts.cell == "hard_tanh":
        return hard_act.hard_tanh_int(x_int, fp, cfg.acts.ht_min, cfg.acts.ht_max)
    if cfg.acts.cell in ("lut_tanh", "tanh"):
        return hard_act.lut_tanh_int(x_int, fp)
    raise ValueError(cfg.acts.cell)


def _int_mac(x_int, w_int, b_wide, cfg: QLSTMConfig):
    """Gate pre-activation MAC, by ALU mode (C3)."""
    fp = cfg.fxp
    if cfg.alu_mode == "pipelined":
        return fxp.fxp_matvec_late_rounding(x_int, w_int, b_wide, fp)
    # per_step: Algorithm 1 as printed — round each product, saturating adds.
    acc = _per_step_matvec(x_int, w_int, cfg)
    prod = fxp.product_config(fp, fp)
    b8 = fxp.requantize(b_wide.astype(jnp.int32), prod, fp)
    return fxp.saturate(acc + b8, fp)


def _per_step_matvec(x_int, w_int, cfg: QLSTMConfig):
    """(..., K) x (K, N) with per-product rounding and a saturating (a,b)
    accumulator — the non-pipelined baseline MAC."""
    fp = cfg.fxp
    prod = fxp.product_config(fp, fp)

    def body(acc, kw):
        xk, wk = kw  # xk: (..., 1), wk: (N,)
        m = xk.astype(jnp.int32) * wk.astype(jnp.int32)[None, :]
        m8 = fxp.requantize(m, prod, fp)
        return fxp.saturate(acc + m8, fp), None

    xs = jnp.moveaxis(x_int.astype(jnp.int32)[..., None], -2, 0)  # (K, ..., 1)
    ws = w_int.astype(jnp.int32)  # (K, N)
    acc0 = jnp.zeros(x_int.shape[:-1] + (w_int.shape[-1],), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (xs, ws))
    return acc


def _elem_mul_round(a_int, b_int, cfg: QLSTMConfig):
    fp = cfg.fxp
    prod = fxp.product_config(fp, fp)
    return fxp.requantize(a_int.astype(jnp.int32) * b_int.astype(jnp.int32), prod, fp)


# Public aliases of the integer datapath primitives, shared by the other
# quantised cells in ``repro.cells`` (GRU, rGLRU): one MAC (both ALU
# modes) and one set of integer activations for the whole cell zoo, so
# the S5 rounding contract cannot drift between cells.
int_gate_act = _int_gate_act
int_cell_act = _int_cell_act
int_mac = _int_mac
elem_mul_round = _elem_mul_round


def _cell_step_int(p, x_t, h, c, cfg: QLSTMConfig):
    fp = cfg.fxp
    prod = fxp.product_config(fp, fp)
    pre = _int_mac(jnp.concatenate([x_t, h], axis=-1),
                   jnp.concatenate([p["w_x"], p["w_h"]], axis=-2),
                   p["b"], cfg)
    i, f, g, o = jnp.split(pre, 4, axis=-1)
    i = _int_gate_act(i, cfg)
    f = _int_gate_act(f, cfg)
    o = _int_gate_act(o, cfg)
    g = _int_cell_act(g, cfg)
    # c = f*c + i*g : both products at wide precision, add, round ONCE (S5).
    wide = f.astype(jnp.int32) * c.astype(jnp.int32) + \
        i.astype(jnp.int32) * g.astype(jnp.int32)
    c_new = fxp.requantize(wide, prod, fp)
    h_new = _elem_mul_round(o, _int_cell_act(c_new, cfg), cfg)
    return h_new, c_new


# Per-layer LSTM carry on the integer datapath: a tuple over layers of
# (h, c) int32 code arrays of shape (batch, hidden_size).  This is the
# state ``repro.serving`` carries across windows of one client stream.
IntState = Tuple[Tuple[Array, Array], ...]


def init_int_state(cfg: QLSTMConfig, batch: int) -> IntState:
    """The reset carry: zero (h, c) int32 codes for every layer — exactly
    what the accelerator's state registers hold before the first window."""
    z = lambda: jnp.zeros((batch, cfg.hidden_size), jnp.int32)
    return tuple((z(), z()) for _ in range(cfg.num_layers))


def check_int_state(state: IntState, qparams: Params) -> None:
    """Reject a carry built for a different layer count — ``zip`` over
    layers would silently truncate and skip whole layers.  Shared by every
    stateful entry point (``forward_int_stateful``, the layered backends)."""
    if len(state) != len(qparams["layers"]):
        raise ValueError(
            f"state carries {len(state)} layer(s) but the model has "
            f"{len(qparams['layers'])}; build it with "
            f"init_int_state(cfg, batch) for THIS configuration")


def forward_int_stateful(qparams: Params, x_int: Array, cfg: QLSTMConfig,
                         state: IntState) -> Tuple[Array, IntState]:
    """Bit-exact accelerator datapath with an explicit cross-window carry.

    x_int: (batch, seq, input_size) integer codes in cfg.fxp; ``state`` is
    the per-layer (h, c) carry from the previous window (``init_int_state``
    for a fresh stream).  Returns ``(y_int, new_state)`` where y_int is
    (batch, out_features) codes and ``new_state`` is the carry after the
    last timestep.  Feeding a long sequence window-by-window through this
    function is bit-identical to one ``forward_int`` call on the
    concatenated sequence (the ``repro.serving`` stateful-streaming
    contract, pinned by ``tests/test_serving.py``)."""
    check_int_state(state, qparams)
    h_t = x_int.astype(jnp.int32)
    new_state = []
    h_last = None
    for p, (h0, c0) in zip(qparams["layers"], state):

        def step(carry, x_t, p=p):
            h, c = carry
            h, c = _cell_step_int(p, x_t, h, c, cfg)
            return (h, c), h

        (h_last, c_last), hs = jax.lax.scan(
            step, (h0.astype(jnp.int32), c0.astype(jnp.int32)),
            jnp.swapaxes(h_t, 0, 1))
        new_state.append((h_last, c_last))
        h_t = jnp.swapaxes(hs, 0, 1)
    y = _int_mac(h_last, qparams["dense"]["w"], qparams["dense"]["b"], cfg)
    return y, tuple(new_state)


def forward_int(qparams: Params, x_int: Array, cfg: QLSTMConfig) -> Array:
    """Bit-exact accelerator datapath.

    x_int: (batch, seq, input_size) integer codes in cfg.fxp.
    Returns integer codes (batch, out_features) in cfg.fxp.
    """
    y, _ = forward_int_stateful(qparams, x_int, cfg,
                                init_int_state(cfg, x_int.shape[0]))
    return y


# ---------------------------------------------------------------------------
# Operation counting (paper's GOP accounting, §4 Eq. 7)
# ---------------------------------------------------------------------------

def ops_per_inference(cfg: QLSTMConfig) -> int:
    """Equivalent operations per inference (multiply+add each count as 1 op,
    so a MAC is 2 ops) — the convention behind the paper's GOP/s numbers."""
    total = 0
    for li in range(cfg.num_layers):
        m, h = cfg.layer_in_dim(li), cfg.hidden_size
        per_step = 2 * 4 * h * (m + h)   # gate MACs
        per_step += 4 * h                # + bias adds
        per_step += 2 * 3 * h + h        # f*c, i*g, o*tanh(c) muls + one add
        per_step += 4 * h                # activations (1 op each)
        total += cfg.seq_len * per_step
    total += 2 * cfg.hidden_size * cfg.out_features + cfg.out_features
    return total
