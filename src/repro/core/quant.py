"""Tensor-level int8 quantisation — the paper's C1 generalised to LM scale.

The paper quantises a whole LSTM datapath to (4,8) fixed point with
power-of-two scales so that requantisation is a shift.  Scaled up to the
assigned LM architectures this becomes:

  * W8A8 symmetric int8 matmuls with int32 accumulation (MXU-native),
  * per-channel (weights) / per-tensor (activations) scales,
  * optional POWER-OF-TWO scales (`p2=True`) — the paper-faithful mode in
    which every requantisation lowers to a shift,
  * int8 KV-cache quantisation for decode (C1 beyond the paper),
  * straight-through fake-quant for QAT.

These utilities are pure jnp; the Pallas kernel (`kernels/quant_matmul.py`)
implements the same semantics with explicit VMEM tiling.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array

INT8_QMAX = 127.0


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantisation policy for a module / the whole model.

    mode:
      "none"  — full precision.
      "w8"    — weight-only int8 (decode-friendly; halves/quarters HBM traffic).
      "w8a8"  — weights and activations int8; matmuls run on the int8 MXU path.
    p2_scale: round scales to powers of two (paper-faithful; requant = shift).
    per_channel: per-output-channel weight scales.
    quantize_kv: int8 KV cache (decode shapes).
    """

    mode: str = "none"
    p2_scale: bool = True
    per_channel: bool = True
    quantize_kv: bool = False
    stochastic: bool = False  # placeholder for stochastic rounding on TPU

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def act_quant(self) -> bool:
        return self.mode == "w8a8"


NO_QUANT = QuantConfig("none")
W8 = QuantConfig("w8")
W8A8 = QuantConfig("w8a8")


class QTensor(NamedTuple):
    """A symmetric-quantised tensor: values * scale ≈ original."""

    values: Array  # int8
    scale: Array   # f32, broadcastable against values

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self) -> Array:
        return self.values.astype(jnp.float32) * self.scale


def _p2_round_scale(scale: Array) -> Array:
    """Round a positive scale UP to the next power of two (never clips)."""
    return jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-30))))


def compute_scale(x: Array, axis: Optional[Sequence[int]] = None,
                  p2: bool = True, qmax: float = INT8_QMAX) -> Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-12) / qmax
    return _p2_round_scale(scale) if p2 else scale


def quantize_tensor(x: Array, axis: Optional[Sequence[int]] = None,
                    p2: bool = True) -> QTensor:
    """Symmetric int8 quantisation. ``axis`` = reduction axes for the scale
    (None -> per-tensor). Round-half-up, saturating — same conventions as
    ``core.fixed_point``."""
    scale = compute_scale(x, axis=axis, p2=p2)
    v = jnp.clip(jnp.floor(x / scale + 0.5), -128, 127).astype(jnp.int8)
    return QTensor(v, scale.astype(jnp.float32))


def quantize_weight(w: Array, cfg: QuantConfig, out_axis: int = -1) -> QTensor:
    """Per-output-channel (or per-tensor) weight quantisation."""
    if cfg.per_channel:
        axes = tuple(i for i in range(w.ndim) if i != (out_axis % w.ndim))
        return quantize_tensor(w, axis=axes, p2=cfg.p2_scale)
    return quantize_tensor(w, axis=None, p2=cfg.p2_scale)


def fake_quant_tensor(x: Array, axis: Optional[Sequence[int]] = None,
                      p2: bool = True) -> Array:
    """STE fake quantisation for QAT: forward = dequant(quant(x)),
    backward = identity (with saturation clipping)."""
    scale = jax.lax.stop_gradient(compute_scale(x, axis=axis, p2=p2))
    q = jnp.clip(jnp.floor(x / scale + 0.5), -128, 127) * scale
    xc = jnp.clip(x, -128.0 * scale, 127.0 * scale)
    return xc + jax.lax.stop_gradient(q - xc)


# ---------------------------------------------------------------------------
# Quantised matmul (pure-jnp semantics; Pallas kernel mirrors this)
# ---------------------------------------------------------------------------

def qmatmul(x: Array, wq: QTensor, cfg: QuantConfig) -> Array:
    """x @ w with the paper's datapath, by mode.

    w8a8: quantise x per-tensor, int8xint8 -> int32 accumulate (late
          rounding, C3), dequantise once at the end.
    w8:   dequantise weights into the matmul (weight-only compression).
    """
    if cfg.mode == "w8a8":
        xq = quantize_tensor(x, axis=None, p2=cfg.p2_scale)
        acc = jax.lax.dot_general(
            xq.values.astype(jnp.int32), wq.values.astype(jnp.int32),
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32) * (xq.scale * wq.scale)
    # w8: float matmul against dequantised weights
    return jnp.dot(x, wq.dequantize().astype(x.dtype))


def fq_matmul(x: Array, w: Array, cfg: QuantConfig) -> Array:
    """QAT-time matmul: fake-quantise weights (and activations for w8a8),
    compute in float.  Differentiable; converges to the integer semantics."""
    if not cfg.enabled:
        return jnp.dot(x, w)
    wf = fake_quant_tensor(w, axis=tuple(range(w.ndim - 1)), p2=cfg.p2_scale) \
        if cfg.per_channel else fake_quant_tensor(w, p2=cfg.p2_scale)
    xf = fake_quant_tensor(x, p2=cfg.p2_scale) if cfg.act_quant else x
    return jnp.dot(xf, wf.astype(x.dtype))


# ---------------------------------------------------------------------------
# KV-cache quantisation (C1 applied to decode memory traffic)
# ---------------------------------------------------------------------------

def quantize_kv(kv: Array) -> QTensor:
    """Per-head int8 KV quantisation: reduce over every axis except heads
    (assumed axis -2: [..., seq, heads, head_dim] -> per-head scale)."""
    axes = tuple(i for i in range(kv.ndim) if i != kv.ndim - 2)
    return quantize_tensor(kv, axis=axes, p2=True)


def dequantize_kv(kvq: QTensor, dtype=jnp.bfloat16) -> Array:
    return kvq.dequantize().astype(dtype)
