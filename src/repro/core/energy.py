"""TPU v5e performance & energy model — the paper's evaluation method (C5)
re-expressed for TPUs.

The paper scores configurations by GOP/s (throughput) and GOP/s/W (energy
efficiency), splitting power into STATIC (leakage — burns regardless of
work) and DYNAMIC (switching — proportional to activity).  The TPU analogue:

  P_total(t) = P_STATIC + E_dynamic / t
  E_dynamic  = e_mxu|vpu * ops  +  e_hbm * hbm_bytes  +  e_ici * ici_bytes

Roofline terms (the §Roofline deliverable) use the hardware constants below
(task-specified: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Energy constants are documented engineering estimates (no public per-op
energy exists for v5e); they are chosen so a compute-bound bf16 run draws
~160 W and a memory-bound run ~140 W — consistent with published v5e system
figures.  All *relative* comparisons (MXU vs VPU, int8 vs bf16, quantised vs
not — the paper's Table 4 structure) are robust to the absolute calibration,
and the constants live in one place so they can be re-calibrated.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# --- Roofline peaks (task-specified) ---------------------------------------
PEAK_BF16_FLOPS = 197e12          # per chip
PEAK_INT8_OPS = 394e12            # MXU int8 = 2x bf16
PEAK_VPU_FLOPS = 1.9e12           # 8x128 lanes * 2 (fma) * ~940 MHz — estimate
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link
ICI_LINKS = 4                     # v5e: 4 ICI links per chip (2D torus)

# --- Energy model constants (documented estimates) -------------------------
P_STATIC_W = 60.0                 # idle/leakage per chip
E_MXU_BF16_J_PER_FLOP = 0.50e-12
E_MXU_INT8_J_PER_OP = 0.25e-12    # narrow multipliers switch less — C1's point
E_VPU_J_PER_FLOP = 2.0e-12        # vector datapath, no systolic reuse
E_HBM_J_PER_BYTE = 100e-12
E_ICI_J_PER_BYTE = 30e-12


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per device)."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: terms overlap perfectly -> max()."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_s_serial(self) -> float:
        """Upper-bound step time: no overlap -> sum()."""
        return self.compute_s + self.memory_s + self.collective_s

    def asdict(self) -> Dict:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "bound": self.bound,
                "step_s": self.step_s}


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   unit: str = "mxu", dtype: str = "bf16",
                   ici_links: int = ICI_LINKS) -> RooflineTerms:
    """Per-device terms from per-device HLO counts (see launch/dryrun.py)."""
    if unit == "vpu":
        peak = PEAK_VPU_FLOPS
    elif dtype == "int8":
        peak = PEAK_INT8_OPS
    else:
        peak = PEAK_BF16_FLOPS
    return RooflineTerms(
        compute_s=flops / peak,
        memory_s=hbm_bytes / HBM_BW,
        collective_s=collective_bytes / (ICI_BW_PER_LINK * ici_links),
    )


def dynamic_energy_j(flops: float, hbm_bytes: float, ici_bytes: float = 0.0,
                     unit: str = "mxu", dtype: str = "bf16") -> float:
    if unit == "vpu":
        e_op = E_VPU_J_PER_FLOP
    elif dtype == "int8":
        e_op = E_MXU_INT8_J_PER_OP
    else:
        e_op = E_MXU_BF16_J_PER_FLOP
    return e_op * flops + E_HBM_J_PER_BYTE * hbm_bytes + E_ICI_J_PER_BYTE * ici_bytes


def power_report(flops: float, hbm_bytes: float, ici_bytes: float,
                 latency_s: float, unit: str = "mxu",
                 dtype: str = "bf16") -> Dict:
    """The paper's Table-4 row: static/dynamic/total power, energy/inference,
    throughput and energy efficiency."""
    e_dyn = dynamic_energy_j(flops, hbm_bytes, ici_bytes, unit, dtype)
    e_static = P_STATIC_W * latency_s
    p_dyn = e_dyn / latency_s if latency_s > 0 else 0.0
    gops = flops / latency_s / 1e9 if latency_s > 0 else 0.0
    p_total = P_STATIC_W + p_dyn
    return {
        "static_w": P_STATIC_W,
        "dynamic_w": p_dyn,
        "total_w": p_total,
        "latency_s": latency_s,
        "energy_j": e_dyn + e_static,
        "throughput_gops": gops,
        "gops_per_watt": gops / p_total if p_total > 0 else 0.0,
    }


def model_flops_train(n_params: float, n_tokens: float,
                      n_active_params: Optional[float] = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) — §Roofline."""
    n = n_active_params if n_active_params is not None else n_params
    return 6.0 * n * n_tokens


def model_flops_decode(n_params: float, n_tokens: float,
                       n_active_params: Optional[float] = None) -> float:
    """2*N per generated token (forward only)."""
    n = n_active_params if n_active_params is not None else n_params
    return 2.0 * n * n_tokens
