"""Fault-tolerant training loop.

Large-scale posture (DESIGN.md §5):
  * resume-from-latest on start (bit-reproducible with the step-keyed
    pipeline),
  * SIGTERM/SIGINT => synchronous checkpoint-and-exit (preemption handling),
  * async keep-k checkpoints off the step path,
  * straggler watchdog: EMA step-time tracker flags slow steps (on real
    fleets this feeds the remediation hook — here it logs and can shrink
    the microbatch via the hook),
  * works on any mesh — elastic restarts re-shard the checkpoint.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.training import checkpoint as ckpt_lib

Array = jax.Array


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep: int = 3
    log_every: int = 20
    straggler_factor: float = 2.0   # step > factor * EMA => straggler
    ema_alpha: float = 0.1


class StragglerWatchdog:
    """EMA step-time monitor (the single-host analogue of per-host heartbeat
    monitoring; the remediation hook is where a fleet controller would
    reassign shards or exclude the slow host)."""

    def __init__(self, factor: float, alpha: float,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.events = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ema is not None and dt > self.factor * self.ema:
            is_straggler = True
            self.events.append((step, dt, self.ema))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        # stragglers don't poison the EMA
        if self.ema is None:
            self.ema = dt
        elif not is_straggler:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class Trainer:
    def __init__(self, train_step, state, batch_fn, loop: LoopConfig,
                 log: Callable[[str], None] = print):
        """train_step: jitted (state, batch) -> (state, metrics);
        batch_fn(step) -> device batch."""
        self.train_step = train_step
        self.state = state
        self.batch_fn = batch_fn
        self.loop = loop
        self.log = log
        self.watchdog = StragglerWatchdog(loop.straggler_factor, loop.ema_alpha)
        self.ckpt = (ckpt_lib.AsyncCheckpointer(loop.ckpt_dir, loop.keep)
                     if loop.ckpt_dir else None)
        self._preempted = False
        self.history: list = []

    # --- preemption --------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self.log(f"[trainer] signal {signum}: checkpoint-and-exit")
            self._preempted = True

        self._old = {s: signal.signal(s, handler)
                     for s in (signal.SIGTERM, signal.SIGINT)}

    def _restore_signals(self):
        for s, h in getattr(self, "_old", {}).items():
            signal.signal(s, h)

    # --- resume ------------------------------------------------------------
    def maybe_resume(self, shardings=None) -> int:
        if not self.loop.ckpt_dir:
            return 0
        last = ckpt_lib.latest_step(self.loop.ckpt_dir)
        if last is None:
            return 0
        self.state = ckpt_lib.restore(self.loop.ckpt_dir, self.state,
                                      step=last, shardings=shardings)
        self.log(f"[trainer] resumed from step {last}")
        return last

    # --- main loop ---------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> Dict[str, Any]:
        self._install_signals()
        step = int(np.asarray(self.state["step"])) if start_step is None \
            else start_step
        try:
            while step < self.loop.total_steps and not self._preempted:
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                step += 1
                self.watchdog.observe(step, dt)
                if step % self.loop.log_every == 0 or step == 1:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    self.history.append({"step": step, "dt": dt, **m})
                    self.log(f"[step {step}] loss={m['loss']:.5f} "
                             f"lr={m.get('lr', 0):.2e} {dt*1e3:.0f}ms")
                if self.ckpt and step % self.loop.ckpt_every == 0:
                    self.ckpt.save_async(self.state, step)
            if self.ckpt:
                # final/preemption checkpoint is synchronous — must land
                self.ckpt.wait()
                ckpt_lib.save(self.loop.ckpt_dir, self.state, step,
                              self.loop.keep)
        finally:
            self._restore_signals()
        return {"step": step, "preempted": self._preempted,
                "stragglers": self.watchdog.events, "history": self.history}
