"""Fault-tolerant checkpointing (no orbax in this environment — built from
scratch):

  * ATOMIC: write to ``<dir>/tmp.<step>`` then os.rename — a crash mid-save
    never corrupts the latest good checkpoint.
  * ASYNC: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes to disk off the step path.
  * KEEP-K: bounded retention.
  * ELASTIC: arrays are saved UNSHARDED (logical); ``restore`` re-shards
    onto whatever mesh/sharding the restarted job provides — a job can come
    back on a different device count (DESIGN.md §5).
  * Multi-host posture: each process would write only its addressable
    shards under ``proc<k>/`` and process 0 the metadata; in this
    single-process container that collapses to one writer, but the layout
    and the save/restore protocol are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "$"  # path separator inside npz keys ('/' is not portable in npz)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(ckpt_dir: str, state, step: int, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(host)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomicity boundary
    _cleanup(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-on-step-path, write-off-step-path."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save_async(self, state, step: int):
        self.wait()
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}

        def _write():
            try:
                os.makedirs(self.ckpt_dir, exist_ok=True)
                tmp = os.path.join(self.ckpt_dir, f"tmp.{step}")
                final = os.path.join(self.ckpt_dir, f"step_{step:010d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump({"step": step, "keys": sorted(host)}, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                _cleanup(self.ckpt_dir, self.keep)
            except Exception as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error:
            raise self.last_error


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like_state, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like_state``.

    ``shardings`` (optional pytree of NamedSharding matching like_state)
    re-shards onto the CURRENT mesh — the elastic-resize path."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        host = {k: z[k] for k in z.files}

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like_state)
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_p))
    out = []
    for (pth, like), sh in zip(leaves_p, sh_leaves):
        key = _SEP.join(_path_str(p) for p in pth)
        if key not in host:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = host[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
