"""Gradient-communication compression (C1 applied to collectives).

Modes (DESIGN.md §5):
  * "none"  — f32 gradient flow.
  * "bf16"  — gradients cast to bf16 before cross-microbatch accumulation
              and the (XLA-inserted) data-parallel reduce — halves DP
              collective bytes; visible in the dry-run HLO (§Perf verifies).
  * "int8"  — error-feedback int8: g_q = round(g/s) with per-leaf power-of-2
              scale; residual (g - s*g_q) is carried in optimizer-side state
              and added back next step.  4x DP collective bytes reduction.

Under single-controller pjit the all-reduce placement is XLA's; casting the
gradient values *is* the mechanism that changes the collective's dtype.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress(grads, mode: str, err_state: Optional[Any] = None
             ) -> Tuple[Any, Optional[Any]]:
    """Returns (compressed-then-decompressed grads, new error state).

    The returned grads carry the quantisation applied BEFORE the DP
    reduction, so the wire format (and HLO collective dtype) matches."""
    if mode == "none":
        return grads, err_state
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), err_state
    if mode == "int8":
        def q(g, e):
            gf = g.astype(jnp.float32) + e
            amax = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30)
            s = jnp.exp2(jnp.ceil(jnp.log2(amax / 127.0)))
            gq = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
            deq = gq.astype(jnp.float32) * s
            return deq, gf - deq

        pairs = jax.tree.map(q, grads, err_state)
        new_g = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda t: t[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e
    raise ValueError(f"unknown compression mode {mode!r}")
