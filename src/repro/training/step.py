"""Jittable step builders: train_step (grad-accum microbatching + AdamW +
optional gradient compression) and serve steps (prefill / decode).

These are the functions the multi-pod dry-run lowers and the real trainer
executes — one code path for both (the dry-run is the launch config's
compile-time proof, not a separate model).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as T
from repro.models.modules import scan_
from repro.training import compress as C
from repro.training.optimizer import OptConfig, apply_updates, init_opt_state

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    grad_compress: str = "none"   # none | bf16 | int8
    accum_dtype: str = "float32"  # cross-microbatch accumulator
    # Cast fp32 master params to compute dtype ONCE per step, before use —
    # so FSDP all-gathers move bf16, not fp32 (2x collective reduction;
    # verified in §Perf).
    cast_params_once: bool = True


def init_train_state(params, plan: TrainPlan) -> Dict[str, Any]:
    state = {"params": params, "opt": init_opt_state(params, plan.opt),
             "step": jnp.zeros((), jnp.int32)}
    if plan.grad_compress == "int8":
        state["grad_err"] = C.init_error_state(params)
    return state


def make_train_step(cfg: ModelConfig, plan: TrainPlan):
    """Returns train_step(state, batch) -> (state, metrics).

    Microbatching: the global batch's leading dim is split into
    plan.microbatches chunks scanned sequentially — bounding activation
    memory and letting XLA overlap each chunk's DP grad reduction with the
    next chunk's compute."""

    def loss_fn(params, micro):
        if plan.cast_params_once:
            from repro.models.modules import cast_tree
            params = cast_tree(params, jnp.dtype(cfg.dtype))
        loss, metrics = T.forward_train(params, micro, cfg)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        nm = plan.microbatches

        def split(x):
            b = x.shape[0]
            return x.reshape(nm, b // nm, *x.shape[1:])

        # position_ids lead with the mrope axis — split on axis 1
        micros = {}
        for k, v in batch.items():
            if k == "position_ids":
                micros[k] = jnp.moveaxis(
                    v.reshape(v.shape[0], nm, v.shape[1] // nm, *v.shape[2:]),
                    1, 0)
            else:
                micros[k] = split(v)

        # bf16 wire format requires the deferred DP reduce to see bf16
        # values, so the accumulator follows the compression dtype.
        acc_dtype = jnp.bfloat16 if plan.grad_compress == "bf16" \
            else jnp.dtype(plan.accum_dtype)

        def micro_step(carry, micro):
            gsum, lsum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, micro)
            if plan.grad_compress == "bf16":
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(acc_dtype), gsum, grads)
            return (gsum, lsum + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
        (gsum, lsum), metrics = scan_(micro_step, (g0, 0.0), micros)
        grads = jax.tree.map(lambda g: g / nm, gsum)

        new_err = None
        if plan.grad_compress == "int8":
            grads, new_err = C.compress(grads, "int8", state["grad_err"])

        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], plan.opt)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_err is not None:
            new_state["grad_err"] = new_err
        out_metrics = {"loss": lsum / nm, **opt_metrics,
                       "ce": metrics["ce"].mean(), "aux": metrics["aux"].mean()}
        return new_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return T.forward_prefill(params, batch, cfg)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch):
        logits, cache = T.forward_decode(params, cache, batch, cfg)
        token = jnp.argmax(logits[:, -1], axis=-1)
        return token, cache
    return decode_step
