from repro.training.optimizer import OptConfig, init_opt_state, apply_updates  # noqa: F401
from repro.training.step import TrainPlan, init_train_state, make_train_step  # noqa: F401
